"""two-tower-retrieval  [recsys] embed_dim=256 tower_mlp=1024-512-256
interaction=dot, sampled-softmax retrieval.  [RecSys'19 (YouTube)]
"""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="two-tower-retrieval",
    model="two_tower",
    n_sparse=0,
    field_vocab_sizes=(),
    embed_dim=256,
    tower_mlp=(1024, 512, 256),
    n_items=10_000_000,
    n_users=50_000_000,
    num_subspaces=16,
)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name="two-tower-smoke", model="two_tower", n_sparse=0,
        field_vocab_sizes=(), embed_dim=32, tower_mlp=(64, 32),
        n_items=30_000, n_users=50_000, num_subspaces=8)
