"""gemma3-27b  [dense] 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import LMConfig
from repro.configs.lm_common import lm_embedding

CONFIG = LMConfig(
    name="gemma3-27b",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    sliding_window=1024,
    local_global_pattern=5,       # 5 local : 1 global
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    act="gelu",
    param_dtype="bfloat16",
    embedding=lm_embedding(262144, 5376),
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="gemma3-27b-smoke",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=512, sliding_window=8, local_global_pattern=5,
        act="gelu", dtype="float32", remat=False, xent_chunk=8,
        embedding=lm_embedding(512, 64, num_subspaces=4),
    )
