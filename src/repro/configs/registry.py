"""Architecture registry: --arch <id> resolution for the launchers.

Each entry: (family, config module).  LM cells marked ``skip`` in
SHAPE_SKIPS are documented inapplicabilities (DESIGN.md §4).
"""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES

ARCHS: Dict[str, Tuple[str, str]] = {
    # arch id            family    config module
    "gemma3-27b":        ("lm", "repro.configs.gemma3_27b"),
    "gemma3-4b":         ("lm", "repro.configs.gemma3_4b"),
    "stablelm-3b":       ("lm", "repro.configs.stablelm_3b"),
    "qwen3-moe-30b-a3b": ("lm", "repro.configs.qwen3_moe_30b_a3b"),
    "mixtral-8x7b":      ("lm", "repro.configs.mixtral_8x7b"),
    "mace":              ("gnn", "repro.configs.mace"),
    "autoint":           ("recsys", "repro.configs.autoint"),
    "two-tower-retrieval": ("recsys", "repro.configs.two_tower_retrieval"),
    "deepfm":            ("recsys", "repro.configs.deepfm"),
    "bst":               ("recsys", "repro.configs.bst"),
}

# (arch, shape) cells skipped with documented reasons (DESIGN.md §4).
SHAPE_SKIPS: Dict[Tuple[str, str], str] = {
    ("stablelm-3b", "long_500k"):
        "pure full attention — every layer would hold the full 500k KV; "
        "no sub-quadratic mechanism in the published config",
    ("qwen3-moe-30b-a3b", "long_500k"):
        "pure full attention — same reasoning as stablelm-3b",
}


def get_arch(arch_id: str, smoke: bool = False):
    """Returns (family, config). smoke=True -> reduced config."""
    family, module_name = ARCHS[arch_id]
    mod = importlib.import_module(module_name)
    cfg = mod.smoke_config() if smoke else mod.CONFIG
    return family, cfg


def shapes_for(arch_id: str):
    family, _ = ARCHS[arch_id]
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
            "recsys": RECSYS_SHAPES}[family]


def all_cells(include_skipped: bool = False):
    """Every (arch, shape) pair in the assignment; 40 total, 38 runnable."""
    for arch in ARCHS:
        for shape in shapes_for(arch):
            skip = SHAPE_SKIPS.get((arch, shape.name))
            if skip and not include_skipped:
                continue
            yield arch, shape, skip
