"""bst  [recsys] embed_dim=32 seq_len=20 n_blocks=1 n_heads=8
mlp=1024-512-256 interaction=transformer-seq (Behavior Sequence
Transformer, Alibaba).  [arXiv:1905.06874; paper]
"""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="bst",
    model="bst",
    n_sparse=0,
    field_vocab_sizes=(),
    embed_dim=32,
    seq_len=20,
    n_blocks=1,
    bst_heads=8,
    tower_mlp=(1024, 512, 256),
    n_items=10_000_000,
)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name="bst-smoke", model="bst", n_sparse=0, field_vocab_sizes=(),
        embed_dim=32, seq_len=10, n_blocks=1, bst_heads=4,
        tower_mlp=(64, 32), n_items=30_000)
