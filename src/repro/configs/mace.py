"""mace  [gnn] n_layers=2 d_hidden=128 l_max=2 correlation_order=3
n_rbf=8 equivariance=E(3)-ACE.  [arXiv:2206.07697; paper]

MGQE inapplicable (species vocab ~100 — DESIGN.md §4).
"""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="mace",
    num_layers=2,
    d_hidden=128,
    l_max=2,
    correlation_order=3,
    n_rbf=8,
    num_species=100,
    d_readout=16,
)


def smoke_config() -> GNNConfig:
    return GNNConfig(name="mace-smoke", num_layers=2, d_hidden=16, l_max=2,
                     correlation_order=3, n_rbf=4, num_species=10,
                     d_readout=4)
