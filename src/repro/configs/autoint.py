"""autoint  [recsys] n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2
d_attn=32 interaction=self-attn.  [arXiv:1810.11921; paper]
"""
from repro.configs.base import RecsysConfig
from repro.data.synthetic import criteo_field_vocabs

CONFIG = RecsysConfig(
    name="autoint",
    model="autoint",
    n_sparse=39,
    embed_dim=16,
    field_vocab_sizes=criteo_field_vocabs(39),
    n_attn_layers=3,
    n_attn_heads=2,
    d_attn=32,
)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name="autoint-smoke", model="autoint", n_sparse=6, embed_dim=16,
        field_vocab_sizes=(50_000, 20_000, 500, 500, 100, 100),
        n_attn_layers=2, n_attn_heads=2, d_attn=16)
