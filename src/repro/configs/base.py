"""Config dataclasses for all architecture families + input-shape specs.

One frozen dataclass per family; every assigned architecture file in
this package exports ``CONFIG`` (full-scale, dry-run only) and
``smoke_config()`` (reduced, runs a real step on CPU).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.types import EmbeddingConfig


# ----------------------------------------------------------------------
# LM family
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention pattern ------------------------------------------------
    sliding_window: Optional[int] = None   # window for local/SWA layers
    local_global_pattern: int = 0          # gemma3: 5 locals per global; 0 = uniform
    rope_theta: float = 10_000.0           # uniform / local-layer theta
    rope_theta_global: float = 1_000_000.0  # global-layer theta (pattern models)

    # MoE ---------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    # shard_map grouped dispatch (GShard) instead of the global-buffer
    # formulation — §Perf hillclimb; needs an ambient mesh at trace time
    moe_shard_map: bool = False

    # embedding compression (the paper's technique) ----------------------
    embedding: Optional[EmbeddingConfig] = None  # None -> plain full table
    embed_kind: str = "mgqe"               # used when building default cfg

    # numerics / training ------------------------------------------------
    # GQA KV-head replication for TP meshes wider than num_kv_heads:
    # repeat K/V up to num_heads inside layer_forward so attention
    # shards on the q-head axis; wk/wv stay replicated.  Avoids the
    # sub-head resharding storm when kv_heads < model-axis (§Perf).
    attn_kv_repeat: bool = False

    act: str = "gelu"
    dtype: str = "bfloat16"                # activation dtype
    param_dtype: str = "float32"           # bf16 for the >=27B archs
    fsdp_params: bool = False              # shard stacked weights over data
    remat: bool = True
    # "layer": checkpoint every layer (baseline); "group": checkpoint
    # blocks of layers — saves 1/blk of the activations at ~2x block
    # transient recompute (§Perf hillclimb)
    remat_granularity: str = "layer"
    remat_block: int = 0                   # 0 = auto (~sqrt(L))
    attention_block: int = 1024            # KV chunk for chunked attention
    attention_impl: str = "auto"           # auto | dense | chunked
    xent_chunk: int = 512                  # seq chunk for vocab softmax
    # serving
    split_local_global_cache: bool = False  # beyond-paper memory opt

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_pattern(self) -> bool:
        return self.local_global_pattern > 0

    def param_count(self) -> int:
        """Approximate dense parameter count N (for MODEL_FLOPS = 6ND)."""
        hd = self.resolved_head_dim
        attn = self.d_model * hd * (self.num_heads * 2 + self.num_kv_heads * 2)
        if self.is_moe:
            ffn = 3 * self.d_model * self.d_ff * self.num_experts \
                + self.d_model * self.num_experts
        else:
            ffn = 3 * self.d_model * self.d_ff
        per_layer = attn + ffn + 2 * self.d_model
        emb = self.vocab_size * self.d_model
        head = self.vocab_size * self.d_model
        return self.num_layers * per_layer + emb + head

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        hd = self.resolved_head_dim
        attn = self.d_model * hd * (self.num_heads * 2 + self.num_kv_heads * 2)
        ffn = 3 * self.d_model * self.d_ff * self.num_experts_per_tok \
            + self.d_model * self.num_experts
        per_layer = attn + ffn + 2 * self.d_model
        return (self.num_layers * per_layer
                + 2 * self.vocab_size * self.d_model)


# ----------------------------------------------------------------------
# GNN (MACE)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    num_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation_order: int = 3
    n_rbf: int = 8
    r_cut: float = 5.0
    num_species: int = 100
    d_readout: int = 16
    dtype: str = "float32"


# ----------------------------------------------------------------------
# RecSys family
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    model: str                      # autoint | deepfm | two_tower | bst
    n_sparse: int = 39
    embed_dim: int = 16
    field_vocab_sizes: Tuple[int, ...] = ()   # len n_sparse
    # embedding compression spec applied to *large* fields
    embed_kind: str = "mgqe"
    mgqe_min_vocab: int = 10_000    # fields smaller than this stay full
    # kernel backend for serving decode / bag pooling (auto | pallas |
    # xla | interpret); $REPRO_KERNEL_BACKEND overrides — DESIGN.md §5
    kernel_backend: str = "auto"
    # shard_map model-parallel row gathers (§Perf hillclimb)
    sharded_embedding: bool = False
    num_subspaces: int = 8
    num_centroids: int = 256
    tier_head_fraction: float = 0.1
    tier_tail_centroids: int = 64
    # autoint
    n_attn_layers: int = 3
    n_attn_heads: int = 2
    d_attn: int = 32
    # deepfm / bst / two-tower MLPs
    mlp_dims: Tuple[int, ...] = (400, 400, 400)
    # two-tower
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    n_items: int = 10_000_000       # retrieval corpus size
    n_users: int = 50_000_000
    # bst
    seq_len: int = 20
    n_blocks: int = 1
    bst_heads: int = 8
    dtype: str = "float32"


# ----------------------------------------------------------------------
# Input-shape specs (assigned cells)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode | graph_full | graph_mini
                         # | rec_train | rec_serve | rec_retrieval
    # LM
    seq_len: int = 0
    global_batch: int = 0
    # GNN
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_graphs: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    # recsys
    batch: int = 0
    n_candidates: int = 0


LM_SHAPES = (
    ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "graph_full", n_nodes=2708, n_edges=10556,
              d_feat=1433),
    ShapeSpec("minibatch_lg", "graph_mini", n_nodes=232965,
              n_edges=114615892, batch_nodes=1024, fanout=(15, 10)),
    ShapeSpec("ogb_products", "graph_full", n_nodes=2449029,
              n_edges=61859140, d_feat=100),
    ShapeSpec("molecule", "graph_batched", n_nodes=30, n_edges=64,
              batch_graphs=128),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "rec_train", batch=65536),
    ShapeSpec("serve_p99", "rec_serve", batch=512),
    ShapeSpec("serve_bulk", "rec_serve", batch=262144),
    ShapeSpec("retrieval_cand", "rec_retrieval", batch=1,
              n_candidates=1_000_000),
)
