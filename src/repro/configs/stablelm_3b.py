"""stablelm-3b  [dense] 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304 — pure full attention (long_500k cell skipped, DESIGN.md §4).
[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.configs.base import LMConfig
from repro.configs.lm_common import lm_embedding

CONFIG = LMConfig(
    name="stablelm-3b",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    act="silu",
    embedding=lm_embedding(50304, 2560),
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="stablelm-3b-smoke",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, d_ff=96,
        vocab_size=512, act="silu", dtype="float32", remat=False,
        xent_chunk=8, embedding=lm_embedding(512, 64, num_subspaces=4),
    )
