"""gemma3-4b  [dense] 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import LMConfig
from repro.configs.lm_common import lm_embedding

CONFIG = LMConfig(
    name="gemma3-4b",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    sliding_window=1024,
    local_global_pattern=5,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    act="gelu",
    embedding=lm_embedding(262144, 2560),
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="gemma3-4b-smoke",
        num_layers=7, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=512, sliding_window=8, local_global_pattern=5,
        act="gelu", dtype="float32", remat=False, xent_chunk=8,
        embedding=lm_embedding(512, 64, num_subspaces=4),
    )
