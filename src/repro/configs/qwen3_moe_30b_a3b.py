"""qwen3-moe-30b-a3b  [moe] 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8 — pure full attention
(long_500k cell skipped, DESIGN.md §4).  [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.configs.base import LMConfig
from repro.configs.lm_common import lm_embedding

CONFIG = LMConfig(
    name="qwen3-moe-30b-a3b",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    num_experts=128,
    num_experts_per_tok=8,
    act="silu",
    param_dtype="bfloat16",
    embedding=lm_embedding(151936, 2048),
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen3-moe-smoke",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=32,
        vocab_size=512, num_experts=8, num_experts_per_tok=2,
        act="silu", dtype="float32", remat=False, xent_chunk=8,
        embedding=lm_embedding(512, 64, num_subspaces=4),
    )
