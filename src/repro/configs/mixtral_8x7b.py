"""mixtral-8x7b  [moe] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, SWA 4096 (bounded cache => long_500k
runs).  [arXiv:2401.04088; hf]
"""
from repro.configs.base import LMConfig
from repro.configs.lm_common import lm_embedding

CONFIG = LMConfig(
    name="mixtral-8x7b",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    act="silu",
    param_dtype="bfloat16",
    embedding=lm_embedding(32000, 4096),
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="mixtral-smoke",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=96,
        vocab_size=512, sliding_window=8, num_experts=4,
        num_experts_per_tok=2, act="silu", dtype="float32", remat=False,
        xent_chunk=8, embedding=lm_embedding(512, 64, num_subspaces=4),
    )
