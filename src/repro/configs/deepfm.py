"""deepfm  [recsys] n_sparse=39 embed_dim=10 mlp=400-400-400
interaction=fm.  [arXiv:1703.04247; paper]
"""
from repro.configs.base import RecsysConfig
from repro.data.synthetic import criteo_field_vocabs

CONFIG = RecsysConfig(
    name="deepfm",
    model="deepfm",
    n_sparse=39,
    embed_dim=10,
    field_vocab_sizes=criteo_field_vocabs(39),
    mlp_dims=(400, 400, 400),
    num_subspaces=5,   # embed_dim=10 must divide D
)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name="deepfm-smoke", model="deepfm", n_sparse=6, embed_dim=10,
        field_vocab_sizes=(50_000, 20_000, 500, 500, 100, 100),
        mlp_dims=(32, 32), num_subspaces=5)
