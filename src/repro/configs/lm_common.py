"""Shared helpers for LM arch configs: default MGQE spec for the token
embedding (the paper's technique applied to the LM vocab)."""
from __future__ import annotations

from repro.core.types import EmbeddingConfig


def lm_embedding(vocab_size: int, d_model: int, kind: str = "mgqe",
                 num_subspaces: int = 8) -> EmbeddingConfig:
    """Paper defaults (§3.4): K=256, two tiers (top 10% head), tail K=64."""
    if kind in ("dpq", "mgqe"):
        extra = dict(num_subspaces=num_subspaces, num_centroids=256)
        if kind == "mgqe":
            head = max(1, vocab_size // 10)
            extra.update(tier_boundaries=(head,),
                         tier_num_centroids=(256, 64))
        return EmbeddingConfig(vocab_size=vocab_size, dim=d_model, kind=kind,
                               **extra)
    return EmbeddingConfig(vocab_size=vocab_size, dim=d_model, kind=kind)
