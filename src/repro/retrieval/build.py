"""Streaming index-build driver (DESIGN.md §12).

The one-shot build paths are O(corpus) in device memory three ways:
the codebook fits materialize ``(N, nlist)`` / ``(D, N, K)`` score
matrices, ``coarse_assign`` + ``encode_corpus`` run over all N rows at
once, and the whole corpus lives on device for the duration.  This
driver bounds all three for corpora that only fit in host memory:

  * **sampled fit** — codebooks (coarse k-means + PQ) are fitted on a
    ``cfg.train_sample``-row sample (without replacement, key-derived);
    fit temporaries scale with the sample, not the corpus;
  * **blocked encode** — ``coarse_assign`` / ``encode_corpus`` run over
    fixed ``cfg.encode_block``-row blocks through ONE jitted step
    (static shapes, last block zero-padded and sliced on the host),
    outputs accumulated in host numpy;
  * **host outputs** — the assembled list tables come back as host
    numpy; placement (device_put / host-staged split / sharding) is the
    serving engine's call, so build peak memory never includes the
    O(corpus) artifact.

Streamed == one-shot bit-for-bit at equal sample settings by
construction: both run the SAME code path (one shot is a single block
covering N), and both ``coarse_assign`` (row-wise argmin) and
``dpq_assign`` are row-independent, so the block boundary cannot
change any row's code.  ``tests/test_retrieval_scale.py`` holds the
property over arbitrary chunk sizes.

``BuildStats.peak_device_bytes`` tracks the bytes this driver stages
to device at once (sample upload + per-block I/O + codebooks); the
analytic ``device_bound_bytes`` is derived from the config alone —
independent of N — and gates the scale bench (``peak_device_ok``).
XLA fit temporaries are additionally O(sample·max(nlist, D·K)), also
corpus-independent.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class BuildStats:
    """Accounting for one streamed build (DESIGN.md §12)."""

    n: int = 0                   # corpus rows
    d: int = 0                   # vector width
    sample_rows: int = 0         # rows the codebooks were fitted on
    block_rows: int = 0          # rows per encode block
    blocks: int = 0              # encode blocks run
    seconds: float = 0.0         # wall time of the whole build
    peak_device_bytes: int = 0   # max bytes staged to device at once
    device_bound_bytes: int = 0  # analytic config-derived bound
    # layout accounting (IVF only; zeros for flat kinds)
    list_count_max: int = 0      # longest coarse list
    list_count_mean: float = 0.0
    list_cap: int = 0            # per-list slot cap after quantile
    max_chain: int = 0           # longest spill chain
    lists_ext: int = 0           # extended list count (base + spill)

    @property
    def peak_device_ok(self) -> bool:
        """Did staged device memory stay within the config-derived
        (corpus-independent) bound?"""
        return self.peak_device_bytes <= self.device_bound_bytes

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self) | {
            "peak_device_ok": self.peak_device_ok}


def training_sample(key: jax.Array, vectors_np: np.ndarray,
                    sample: int) -> np.ndarray:
    """Without-replacement row sample for the codebook fits.

    ``sample`` of 0 (or >= N) means the full corpus.  Indices are
    sorted so the sample preserves corpus order — keyed only by
    ``key``/``sample``, never by the block size, which keeps the
    streamed-vs-one-shot parity property exact.
    """
    n = vectors_np.shape[0]
    if not sample or sample >= n:
        return vectors_np
    idx = np.sort(np.asarray(
        jax.random.choice(key, n, (int(sample),), replace=False)))
    return vectors_np[idx]


def blocked_map(step: Callable, vectors_np: np.ndarray, block: int,
                ) -> Tuple[Tuple[np.ndarray, ...], int, int]:
    """Run a jitted per-row map over fixed-size row blocks.

    ``step`` maps a ``(block, d)`` device array to a tuple of per-row
    outputs; the last partial block is zero-padded (static shapes ->
    one compilation) and its outputs sliced host-side.  Returns the
    host-concatenated outputs, the block count, and the peak staged
    device bytes (input + outputs of the widest block).
    """
    n = vectors_np.shape[0]
    block = min(block, n) if block else n
    jstep = jax.jit(step)
    outs: list = []
    blocks = 0
    peak = 0
    for start in range(0, n, block):
        stop = min(start + block, n)
        blk = vectors_np[start:stop]
        if stop - start < block:   # zero-pad the tail block
            pad = np.zeros((block - (stop - start),) + blk.shape[1:],
                           blk.dtype)
            blk = np.concatenate([blk, pad])
        dev = jnp.asarray(blk)
        res = jstep(dev)
        res = res if isinstance(res, tuple) else (res,)
        peak = max(peak, int(dev.nbytes) + sum(int(r.nbytes) for r in res))
        outs.append(tuple(np.asarray(r)[:stop - start] for r in res))
        blocks += 1
    cat = tuple(np.concatenate([o[j] for o in outs])
                for j in range(len(outs[0])))
    return cat, blocks, peak


def _device_bound_bytes(sample_rows: int, block: int, d: int,
                        out_bytes_per_row: int,
                        codebook_bytes: int) -> int:
    """Config-derived staging bound: sample upload + block I/O +
    codebooks, with 2x slack for transient double-buffering.  No term
    depends on the corpus size."""
    sample_bytes = sample_rows * d * 4
    block_bytes = block * (d * 4 + out_bytes_per_row)
    return 2 * (sample_bytes + block_bytes + codebook_bytes) + (1 << 20)


def build_flat_artifact(key: jax.Array, vectors,
                        cfg) -> Tuple[Dict, BuildStats]:
    """Streamed ``flat_pq`` build: sampled fit + blocked encode.

    Returns ``({codes, centroids}, BuildStats)`` with ``codes`` as
    host numpy (the caller/engine owns placement).
    """
    from repro.retrieval import flat_pq

    t0 = time.perf_counter()
    vec_np = np.asarray(vectors)     # zero-copy when already host numpy
    n, d = vec_np.shape
    k_sample, k_fit = jax.random.split(key)
    train_np = training_sample(k_sample, vec_np, cfg.train_sample)
    cent = flat_pq.fit_pq(k_fit, jnp.asarray(train_np),
                          cfg.num_subspaces, cfg.num_centroids, cfg.iters)
    code_dtype = np.uint8 if cfg.num_centroids <= 256 else np.int32

    def step(blk):
        return flat_pq.encode_corpus(blk, cent,
                                     backend=cfg.kernel_backend)

    (codes_np,), blocks, peak = blocked_map(
        step, vec_np, cfg.encode_block)
    block = min(cfg.encode_block, n) if cfg.encode_block else n
    stats = BuildStats(
        n=n, d=d, sample_rows=train_np.shape[0], block_rows=block,
        blocks=blocks,
        peak_device_bytes=peak + train_np.nbytes + int(cent.nbytes),
        device_bound_bytes=_device_bound_bytes(
            train_np.shape[0], block, d,
            out_bytes_per_row=4 * cfg.num_subspaces,
            codebook_bytes=int(cent.nbytes)))
    artifact = {"codes": codes_np.astype(code_dtype),
                "centroids": cent}
    stats.seconds = time.perf_counter() - t0
    return artifact, stats


def build_ivf_artifact(key: jax.Array, vectors,
                       cfg) -> Tuple[Dict, BuildStats]:
    """Streamed ``ivf_pq`` build: sampled coarse + PQ fit, blocked
    assign + encode, bounded chained list layout.

    Returns ``({coarse, centroids, list_chain, list_codes, list_ids},
    BuildStats)`` with the list tables as host numpy.
    """
    from repro.retrieval import flat_pq
    from repro.retrieval.ivf_pq import (bounded_list_layout, coarse_assign,
                                        coarse_kmeans)

    t0 = time.perf_counter()
    vec_np = np.asarray(vectors)
    n, d = vec_np.shape
    if n < cfg.nlist:
        raise ValueError(
            f"corpus of {n} vectors cannot fill nlist={cfg.nlist} "
            f"coarse cells")
    k_sample, k_coarse, k_pq = jax.random.split(key, 3)
    train_np = training_sample(k_sample, vec_np, cfg.train_sample)
    if train_np.shape[0] < cfg.nlist:
        raise ValueError(
            f"train_sample={train_np.shape[0]} cannot seed "
            f"nlist={cfg.nlist} coarse cells")
    train = jnp.asarray(train_np)
    coarse = coarse_kmeans(k_coarse, train, cfg.nlist,
                           iters=cfg.coarse_iters)
    if cfg.ivf_residual:
        t_assign = coarse_assign(train, coarse)
        to_code = train - jnp.take(coarse, t_assign, axis=0)
    else:
        to_code = train
    cent = flat_pq.fit_pq(k_pq, to_code, cfg.num_subspaces,
                          cfg.num_centroids, cfg.iters)
    code_dtype = np.uint8 if cfg.num_centroids <= 256 else np.int32

    def step(blk):
        a = coarse_assign(blk, coarse)
        tc = blk - jnp.take(coarse, a, axis=0) \
            if cfg.ivf_residual else blk
        return a, flat_pq.encode_corpus(tc, cent,
                                        backend=cfg.kernel_backend)

    (assign_np, codes_np), blocks, peak = blocked_map(
        step, vec_np, cfg.encode_block)
    layout = bounded_list_layout(
        assign_np, codes_np.astype(code_dtype), cfg.nlist,
        cfg.list_cap_quantile)
    counts = np.bincount(assign_np, minlength=cfg.nlist)
    block = min(cfg.encode_block, n) if cfg.encode_block else n
    codebook_bytes = int(coarse.nbytes) + int(cent.nbytes)
    stats = BuildStats(
        n=n, d=d, sample_rows=train_np.shape[0], block_rows=block,
        blocks=blocks,
        peak_device_bytes=peak + train_np.nbytes + codebook_bytes,
        device_bound_bytes=_device_bound_bytes(
            train_np.shape[0], block, d,
            out_bytes_per_row=4 + 4 * cfg.num_subspaces,
            codebook_bytes=codebook_bytes),
        list_count_max=int(counts.max()),
        list_count_mean=float(counts.mean()),
        list_cap=layout["list_codes"].shape[1],
        max_chain=layout["list_chain"].shape[1],
        lists_ext=layout["list_codes"].shape[0])
    artifact = {"coarse": coarse, "centroids": cent, **layout}
    stats.seconds = time.perf_counter() - t0
    return artifact, stats
