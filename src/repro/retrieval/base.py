"""Retrieval index protocol + registry (DESIGN.md §8).

An *index* is one way of organizing a PQ-coded corpus for batched
top-k retrieval: the exact flat scan (``flat_pq.py``), an IVF-style
coarse partition (``ivf_pq.py``), or whatever the ANN literature
suggests next.  Each index is ONE class registered under its
``IndexConfig.kind`` string:

    @register_index("ivf_pq")
    class IVFPQ(Index):
        ...

Every integration layer resolves indexes through this registry instead
of branching on kind strings — :class:`repro.models.recsys.two_tower.
TwoTower` builds/queries through it, the
:class:`repro.launch.engine.RetrievalEngine` serves through it, the
sharded top-k (``retrieval/sharded.py``) and its placement rules
(``sharding/rules.py``) distribute through it, and the README support
matrix (``tools/gen_tables.py``) enumerates it — adding an index kind
is a one-file change, exactly like the scheme registry it mirrors
(``core/schemes/``, DESIGN.md §7).

The lifecycle is two-phase:

  * ``build(key, vectors)`` — offline: corpus vectors -> artifact dict
    (codes + codebooks + whatever partition metadata the kind needs);
  * ``search(artifact, queries, k)`` — online: a BATCH of queries
    (B, d) -> ``(scores (B, k), ids (B, k))`` in one pass, through the
    dispatched ``pq_score`` kernel family.

Top-k ordering contract (all kinds, all backends, sharded or not):
entries sorted by (score desc, id asc); slots with fewer than ``k``
valid candidates carry ``score = -inf, id = INVALID_ID``
(``retrieval/topk.py`` owns the merge that enforces it).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple, Type

import jax

from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Declarative description of one retrieval index.

    ``num_subspaces``/``num_centroids``/``iters`` parameterize the PQ
    codec (shared by every kind); ``nlist``/``nprobe``/``coarse_iters``
    only matter to IVF kinds.  ``block_n`` is the candidate-block size
    of the fused scoring kernels; ``kernel_backend`` pins the dispatch
    backend (None/auto = resolve per DESIGN.md §5).

    The scale knobs (DESIGN.md §12) bound BUILD device memory for
    corpora that do not fit on device: ``train_sample`` fits the
    coarse/PQ codebooks on a row sample instead of the full corpus,
    ``encode_block`` runs assignment + encoding over fixed-size row
    blocks (host-accumulated), ``list_cap_quantile`` caps the padded
    IVF list tables at a count quantile (overflow spills into chained
    lists), and ``host_staged`` keeps the list tables in host memory at
    serve time, staging only probed lists per flush.
    """

    kind: str = "flat_pq"
    num_subspaces: int = 8
    num_centroids: int = 256
    iters: int = 10
    nlist: int = 64
    nprobe: int = 8
    coarse_iters: int = 10
    # PQ-code residuals against the coarse centroid instead of the raw
    # vectors.  Off by default: for dot-product (MIPS) retrieval the
    # residual trick multiplies the per-subspace mode count by nlist
    # (each cell shifts the subspace marginal differently), which COSTS
    # recall at fixed K unless the corpus is L2-normalized — the same
    # reason FAISS inner-product IVFPQ runs by_residual=False.
    ivf_residual: bool = False
    block_n: int = 1024
    kernel_backend: Optional[str] = None
    # ---- streaming-build / at-scale knobs (DESIGN.md §12) ----
    train_sample: int = 0       # rows to fit codebooks on; 0 = full corpus
    encode_block: int = 0       # rows per assign/encode block; 0 = one shot
    list_cap_quantile: float = 0.95  # IVF list cap at this count quantile
    host_staged: bool = False   # serve list tables from host memory

    def __post_init__(self):
        if self.train_sample < 0 or self.encode_block < 0:
            raise ValueError(
                f"train_sample/encode_block must be >= 0, got "
                f"{self.train_sample}/{self.encode_block}")
        if not 0.0 < self.list_cap_quantile <= 1.0:
            raise ValueError(
                f"list_cap_quantile must be in (0, 1], got "
                f"{self.list_cap_quantile}")
        cls = index_class(self.kind)   # raises on unknown kinds
        cls.validate(self)


def suggest_nlist(n: int, nprobe: int = 1) -> int:
    """Default IVF partition count for an ``n``-row corpus.

    nlist ≈ √N keeps probed work ∝ nprobe·√N and list length ≈ √N —
    the classic IVF balance point (a fixed cap like 64 leaves a 10M
    corpus probing 156k-row lists).  Clamped so the result stays a
    valid config: at least ``nprobe`` (nprobe ≤ nlist) and at most
    ``n`` (every cell needs a seed vector).
    """
    nlist = int(round(math.sqrt(max(n, 1))))
    return max(1, min(n, max(nprobe, nlist)))


class Index:
    """Protocol every retrieval index implements.

    Required overrides: ``build`` / ``search`` (plus ``validate`` /
    ``probe_config`` classmethods where the defaults don't fit).
    ``rows_leaves`` names the artifact keys whose leading dim is
    O(corpus) — those are row-sharded over the model mesh axis when
    the index is distributed; everything else is replicated.
    ``local_topk`` is the per-shard hook the sharded driver
    (``retrieval/sharded.py``) fans out, mirroring
    ``QuantizedScheme.decode`` on the scheme side.
    """

    kind: str = "?"                    # set by @register_index
    # artifact dict keys sharded on dim 0 when distributed; () means
    # the kind cannot be distributed.
    rows_leaves: Tuple[str, ...] = ()

    def __init__(self, cfg: IndexConfig):
        self.cfg = cfg

    # ------------------------------------------------------- class hooks
    @classmethod
    def validate(cls, cfg: IndexConfig) -> None:
        """Kind-specific config validation (IndexConfig.__post_init__
        calls this through the registry)."""

    @classmethod
    def probe_config(cls) -> IndexConfig:
        """A tiny IndexConfig for capability probing / conformance
        (build -> search must run in milliseconds)."""
        return IndexConfig(kind=cls.kind, num_subspaces=4,
                           num_centroids=8, iters=2, nlist=4, nprobe=2,
                           coarse_iters=2, block_n=64)

    # --------------------------------------------------------- required
    def build(self, key: jax.Array, vectors: jax.Array) -> Dict:
        """Offline: corpus vectors (N, d) -> serving artifact dict."""
        raise NotImplementedError

    def search(self, artifact: Dict, queries: jax.Array,
               k: int) -> Tuple[jax.Array, jax.Array]:
        """Batched top-k: queries (B, d) -> (scores (B, k), ids (B, k))."""
        raise NotImplementedError

    # ---------------------------------------------------------- derived
    @property
    def supports_sharded(self) -> bool:
        return bool(self.rows_leaves)

    # host-staged serving (DESIGN.md §12): kinds that can keep their
    # O(corpus) leaves in host memory and stage only the rows a flush
    # probes override this to True and implement search_host_staged.
    supports_host_staged: bool = False

    def host_leaves(self) -> Tuple[str, ...]:
        """Artifact keys that stay host-resident under host-staged
        serving — by default the O(corpus) row tables."""
        return self.rows_leaves

    def search_host_staged(self, artifact: Dict, queries: jax.Array,
                           k: int) -> Tuple[jax.Array, jax.Array]:
        """Like ``search`` but ``host_leaves()`` entries of ``artifact``
        are host numpy arrays; implementations stage only the probed
        rows to device.  Must return bit-identical results to
        ``search`` on the same artifact."""
        raise NotImplementedError(
            f"index kind {self.kind!r} has no host-staged serve path")

    def artifact_shard_specs(self, artifact: Dict,
                             model_axis: str = "model") -> Dict:
        """PartitionSpec pytree: ``rows_leaves`` row-sharded over
        ``model_axis``, everything else replicated (DESIGN.md §8)."""
        if not self.supports_sharded:
            raise ValueError(
                f"index kind {self.kind!r} cannot be distributed")
        return {
            name: P(model_axis, *((None,) * (jax.numpy.ndim(leaf) - 1)))
            if name in self.rows_leaves else P()
            for name, leaf in artifact.items()}

    def local_topk(self, artifact: Dict, queries: jax.Array, k: int, *,
                   shard: jax.Array, num_shards: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Per-shard top-k over the LOCAL artifact rows ->
        ``(scores, tiebreak, ids)``, each (B, k).  Ids must be GLOBAL
        corpus ids; ``tiebreak`` is the kind's shard-invariant
        equal-score ordering key (corpus id for flat scans, global
        candidate position for IVF — retrieval/topk.py) so the
        driver's merge reproduces the single-device order bit-for-bit.
        Runs inside the sharded driver's shard_map body — ``shard`` is
        this device's index along the model axis."""
        raise NotImplementedError


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Index]] = {}


def register_index(kind: str):
    """Class decorator: register an Index under its kind string."""
    def deco(cls: Type[Index]) -> Type[Index]:
        prev = _REGISTRY.get(kind)
        if prev is not None and prev is not cls:
            raise ValueError(
                f"index kind {kind!r} already registered to {prev}")
        cls.kind = kind
        _REGISTRY[kind] = cls
        return cls
    return deco


def registered_index_kinds() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def index_class(kind: str) -> Type[Index]:
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise KeyError(
            f"unknown index kind {kind!r}; registered indexes: "
            f"{', '.join(registered_index_kinds()) or '(none)'}") from None


def get_index(cfg: IndexConfig) -> Index:
    """Resolve a config to its index instance."""
    return index_class(cfg.kind)(cfg)
