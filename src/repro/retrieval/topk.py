"""Deterministic top-k selection and merging for retrieval.

One contract everywhere (fused kernel, XLA reference, IVF probe
scoring, sharded per-shard merge): candidates sort by **(score desc,
tiebreak asc)** under a per-index tiebreak key — the corpus id for the
flat kinds (ids ascend along the scored axis, so ``lax.top_k``'s
earliest-position rule already implements it), the global candidate
position for IVF (every shard sees the same probe layout) — and slots
beyond the number of valid candidates carry ``(-inf, INVALID_ID)``.

That total order is what makes the sharded merge BIT-IDENTICAL to the
single-device scan: per-candidate scores do not depend on block or
shard boundaries, and merging per-shard top-k lists under a total
order on (score, tiebreak) pairs is associative, truncation included
(each shard contributes at most k of the global top-k).

``lax.top_k`` does the big O(N) selections (XLA lowers it to a partial
selection — ~30x faster than a full sort on CPU); the explicit
two-key ``lax.sort`` in :func:`merge_topk` only ever runs on the tiny
(B, shards·k) merge.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.pq_score import INVALID_ID


def _pad_last(x: jax.Array, pad: int, value) -> jax.Array:
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths, constant_values=value)


def merge_topk(scores: jax.Array, ids: jax.Array, k: int,
               tiebreak: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """(…, S) candidate pairs -> the top ``k`` under (score desc,
    tiebreak asc); ``tiebreak`` defaults to ``ids``.

    ``ids`` ride along as payload (three-operand stable sort).  Accepts
    any number of leading batch dims; pads with ``(-inf, INVALID_ID)``
    when S < k.  Use for merging per-shard or per-probe partial top-k
    lists — candidates with equal scores resolve by the tiebreak key,
    never by memory layout.
    """
    s = scores.astype(jnp.float32)
    i = ids.astype(jnp.int32)
    tb = i if tiebreak is None else tiebreak.astype(jnp.int32)
    pad = k - s.shape[-1]
    if pad > 0:
        s = _pad_last(s, pad, -jnp.inf)
        i = _pad_last(i, pad, INVALID_ID)
        tb = _pad_last(tb, pad, INVALID_ID)
    neg, _, out_i = jax.lax.sort((-s, tb, i), num_keys=2, dimension=-1)
    return -neg[..., :k], out_i[..., :k]


def topk_by_position(scores: jax.Array, ids: jax.Array, k: int
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """``lax.top_k`` over the last axis carrying explicit ids along:
    -> (scores, positions, ids), all (…, k), ordered by (score desc,
    position asc).  The returned positions are the tiebreak key for a
    later :func:`merge_topk`; padding (S < k) carries
    ``(-inf, INVALID_ID, INVALID_ID)``.
    """
    s = scores.astype(jnp.float32)
    i = ids.astype(jnp.int32)
    n = s.shape[-1]
    pos = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32), s.shape)
    pad = k - n
    if pad > 0:
        s = _pad_last(s, pad, -jnp.inf)
        i = _pad_last(i, pad, INVALID_ID)
        pos = _pad_last(pos, pad, INVALID_ID)
    top_s, sel = jax.lax.top_k(s, k)
    return (top_s, jnp.take_along_axis(pos, sel, axis=-1),
            jnp.take_along_axis(i, sel, axis=-1))


__all__ = ["INVALID_ID", "merge_topk", "topk_by_position"]
