"""IVF-PQ — coarse k-means partition + per-list PQ codes.

The classic large-catalogue trade (Jegou et al.; RecJPQ and the
embedding-compression survey both frame it as the endgame for
quantized recsys corpora): cluster the corpus into ``nlist`` coarse
cells and at query time score only the ``nprobe`` most promising
cells, reading ~``nprobe/nlist`` of the code bytes the flat scan
reads.  Probed candidates score by the usual LUT summation; with
``ivf_residual=True`` the codes quantize residuals against the cell
centroid and the coarse dot product is added back —

    score(i) = <q, c_coarse[list(i)]>  +  sum_d lut[d, codes[i, d]]

exact for the dot product up to PQ error either way.  One LUT build
per query (the codebook is global, so the LUT is shared across probed
lists); ``nprobe`` controls the recall/bytes dial.  Residual coding
defaults OFF for this dot-product workload — see ``IndexConfig``.

Storage layout (DESIGN.md §12): probing must stay a static-shape
gather, but padding every list to the LONGEST list blows memory by
the max/mean list ratio on Zipf-skewed corpora.  Lists are instead
capped at the ``list_cap_quantile`` count quantile; rows past the cap
spill into chained extension lists appended after index ``nlist`` in
the extended tables —

  ``list_codes (nlist_ext, cap, D)`` uint8,
  ``list_ids   (nlist_ext, cap)``   int32 (GLOBAL corpus ids,
                                          ``INVALID_ID`` padding),
  ``list_chain (nlist, max_chain)`` int32 — per base list, its full
      chain of extended-list ids (-1 padded); row 0 is the base list
      itself, so ``max_chain`` is static from the leaf SHAPE (the
      artifact arrives as tracers under the serving jit).

Probing gathers the (B, P) probed base lists' chains in one
``jnp.take`` then their slots — (B, P, C, cap), still static-shape.
Total storage is ≈ N + nlist·cap slots regardless of skew;
``list_cap_quantile=1.0`` reproduces the old pad-to-max layout
(max_chain == 1, no spill lists).  ``nlist_ext`` is padded with empty
lists to a multiple of ``nlist`` so row-sharding divisibility is
unchanged.  Building streams through ``retrieval/build.py`` (sampled
codebook fit, blocked assign+encode, host numpy accumulation);
searching is pure JAX.

Distribution: extended lists are row-sharded over the model mesh axis
(``rows_leaves``); the tiny coarse table and the chain table are
replicated, so every shard agrees on which extended lists each query
probes and scores only the ones it owns (``local_topk``) — the
tiebreak is the candidate's position in the replicated
(probe × chain × slot) layout, so the sharded merge is bit-identical
(retrieval/sharded.py, DESIGN.md §8).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pq_score import (INVALID_ID, build_lut_batch,
                                    pq_score_batched_ref)
from repro.retrieval import flat_pq
from repro.retrieval.base import Index, IndexConfig, register_index
from repro.retrieval.topk import topk_by_position

# host-staged serving pads the staged-list count to a multiple of this
# so the scoring jit sees a bounded set of shapes (retraces O(log U))
_STAGE_PAD = 64


def coarse_kmeans(key: jax.Array, vectors: jax.Array, nlist: int,
                  iters: int = 10) -> jax.Array:
    """Euclidean Lloyd's over full-width vectors -> (nlist, d) centers.

    Reuses the per-subspace k-means with ONE subspace of width d."""
    return flat_pq.fit_pq(key, vectors, num_subspaces=1,
                          num_centroids=nlist, iters=iters)[0]


def coarse_assign(vectors: jax.Array, coarse: jax.Array) -> jax.Array:
    """Nearest coarse centroid per vector (euclidean), (N,) int32."""
    dots = vectors @ coarse.T                          # (N, nlist)
    c_sq = jnp.sum(jnp.square(coarse), axis=-1)        # (nlist,)
    return jnp.argmin(c_sq[None, :] - 2 * dots, axis=-1).astype(jnp.int32)


def bounded_list_layout(assign_np: np.ndarray, codes_np: np.ndarray,
                        nlist: int, cap_quantile: float) -> Dict:
    """Host-side bucketing into the quantile-capped chained layout.

    Returns host numpy ``{list_chain, list_codes, list_ids}`` (see the
    module docstring for shapes).  Within a base list, corpus ids
    ascend along the chain (stable sort), matching the old layout's
    per-list order.
    """
    n = assign_np.shape[0]
    counts = np.bincount(assign_np, minlength=nlist)
    if cap_quantile >= 1.0:
        cap = max(int(counts.max()), 1)
    else:
        cap = max(int(np.ceil(np.quantile(counts, cap_quantile))), 1)
    chunks = np.maximum(1, -(-counts // cap))      # ceil; >= 1 per list
    max_chain = int(chunks.max())
    n_spill = int((chunks - 1).sum())
    # pad with empty lists to a multiple of nlist: row-sharding keeps
    # dividing wherever nlist did
    n_ext = -(-(nlist + n_spill) // nlist) * nlist
    spill_start = nlist + np.concatenate(
        [[0], np.cumsum(chunks - 1)[:-1]])
    chain = np.full((nlist, max_chain), -1, np.int32)
    chain[:, 0] = np.arange(nlist)
    for j in range(1, max_chain):
        has = chunks > j
        chain[has, j] = spill_start[has] + (j - 1)

    order = np.argsort(assign_np, kind="stable")   # ids ascend per list
    starts = np.zeros(nlist, np.int64)
    starts[1:] = np.cumsum(counts)[:-1]
    slot = np.arange(n) - starts[assign_np[order]]
    ext = chain[assign_np[order], slot // cap]     # extended-list row
    within = slot % cap
    D = codes_np.shape[1]
    list_codes = np.zeros((n_ext, cap, D), codes_np.dtype)
    list_ids = np.full((n_ext, cap), INVALID_ID, np.int32)
    list_codes[ext, within] = codes_np[order]
    list_ids[ext, within] = order
    return {"list_chain": chain, "list_codes": list_codes,
            "list_ids": list_ids}


@register_index("ivf_pq")
class IVFPQ(Index):
    """nprobe-controlled probing over a coarse partition of PQ codes."""

    rows_leaves = ("list_codes", "list_ids")
    supports_host_staged = True

    def host_leaves(self) -> Tuple[str, ...]:
        # the chain expands on the host in host-staged mode — keep it
        # host-resident alongside the row tables
        return self.rows_leaves + ("list_chain",)

    def __init__(self, cfg: IndexConfig):
        super().__init__(cfg)
        self._staged_fns = None      # lazy jits for host-staged serving
        self.staged_bytes = 0        # total bytes staged to device

    @classmethod
    def validate(cls, cfg: IndexConfig) -> None:
        if cfg.nlist < 1:
            raise ValueError(f"ivf_pq needs nlist >= 1, got {cfg.nlist}")
        if not 1 <= cfg.nprobe <= cfg.nlist:
            raise ValueError(
                f"ivf_pq needs 1 <= nprobe <= nlist, got "
                f"nprobe={cfg.nprobe} nlist={cfg.nlist}")

    # ------------------------------------------------------------ build
    def build(self, key: jax.Array, vectors: jax.Array) -> Dict:
        """Build via the streaming driver (retrieval/build.py) and
        device-put the result — the classic on-device artifact.  Use
        ``build.build_ivf_artifact`` directly to keep the list tables
        in host memory (host-staged serving / sharded placement)."""
        from repro.retrieval.build import build_ivf_artifact
        artifact, _ = build_ivf_artifact(key, vectors, self.cfg)
        return {name: jnp.asarray(leaf)
                for name, leaf in artifact.items()}

    # ----------------------------------------------------------- search
    def _probe(self, artifact: Dict, queries: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
        """Top-nprobe coarse cells per query: (scores, list ids),
        both (B, nprobe).  The coarse table is replicated, so every
        shard computes the identical probe set."""
        coarse_scores = queries @ artifact["coarse"].T      # (B, nlist)
        return jax.lax.top_k(coarse_scores, self.cfg.nprobe)

    def _expand_chain(self, artifact: Dict, lists: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
        """(B, P) probed base lists -> (B, P, C) extended-list ids via
        the replicated chain table, plus the live mask (chain padding
        is -1).  Dead slots clamp to row 0 and are masked downstream."""
        chain = jnp.take(artifact["list_chain"], lists, axis=0)
        live = chain >= 0
        return jnp.where(live, chain, 0), live

    def _score_probed(self, artifact: Dict, queries: jax.Array,
                      probe_s: jax.Array, chain: jax.Array,
                      hit: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Score the (B, P, C) probed extended lists -> flat
        (B, P·C·cap) candidate (scores, global ids); ``hit`` masks
        chain padding and rows this caller does not own (sharded /
        staged paths) to (-inf, INVALID_ID)."""
        luts = build_lut_batch(queries, artifact["centroids"]
                               ).astype(jnp.float32)        # (B, D, K)
        codes = jnp.take(artifact["list_codes"], chain, axis=0)
        ids = jnp.take(artifact["list_ids"], chain, axis=0)
        b, p, c, cap, n_sub = codes.shape
        # per-query LUT gather over its own probed rows — a
        # (B, P·C·cap, D) gather, not the shared-code-stream kernel
        # (each query reads different rows); vmapped jnp fuses under jit
        cand_scores = jax.vmap(pq_score_batched_ref)(
            luts[:, None], codes.reshape(b, p * c * cap, n_sub)
        ).reshape(b, p, c, cap)
        if self.cfg.ivf_residual:
            cand_scores = cand_scores + probe_s[:, :, None, None]
        valid = (ids != INVALID_ID) & hit[..., None]
        cand_scores = jnp.where(valid, cand_scores, -jnp.inf)
        ids = jnp.where(valid, ids, INVALID_ID)
        return (cand_scores.reshape(b, p * c * cap),
                ids.reshape(b, p * c * cap))

    def search(self, artifact: Dict, queries: jax.Array,
               k: int) -> Tuple[jax.Array, jax.Array]:
        probe_s, lists = self._probe(artifact, queries)
        chain, live = self._expand_chain(artifact, lists)
        s, i = self._score_probed(artifact, queries, probe_s, chain, live)
        # position tiebreak: candidate layout (probe x chain x slot) is
        # identical on every shard, so this order is shard-invariant
        top_s, _, top_i = topk_by_position(s, i, k)
        return top_s, top_i

    def local_topk(self, artifact: Dict, queries: jax.Array, k: int, *,
                   shard: jax.Array, num_shards: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        lists_local = artifact["list_codes"].shape[0]
        probe_s, lists = self._probe(artifact, queries)
        chain, live = self._expand_chain(artifact, lists)  # GLOBAL ids
        local = chain - shard * lists_local
        hit = live & (local >= 0) & (local < lists_local)
        local = jnp.clip(local, 0, lists_local - 1)
        s, i = self._score_probed(artifact, queries, probe_s, local, hit)
        return topk_by_position(s, i, k)

    # ------------------------------------------------------ host-staged
    def search_host_staged(self, artifact: Dict, queries: jax.Array,
                           k: int) -> Tuple[jax.Array, jax.Array]:
        """Serve with the list tables host-resident (DESIGN.md §12).

        Probing runs on device (the coarse table is tiny); the probed
        base lists' chains expand on the host, and only the unique
        probed extended lists are gathered from host memory and staged
        to device — upload ∝ B·nprobe·max_chain·cap, never O(corpus).
        Scoring reuses ``_score_probed`` with the staged tables and
        the probe-layout positions, so results are bit-identical to
        ``search`` on the device-resident artifact.
        """
        codes_h = np.asarray(artifact["list_codes"])
        ids_h = np.asarray(artifact["list_ids"])
        chain_h = np.asarray(artifact["list_chain"])
        probe, score = self._staged_jits()
        probe_s, lists = probe(artifact["coarse"], queries)
        chain = chain_h[np.asarray(lists)]             # (B, P, C)
        live = chain >= 0
        uniq, inv = np.unique(np.where(live, chain, 0),
                              return_inverse=True)
        u = len(uniq)
        u_pad = -(-u // _STAGE_PAD) * _STAGE_PAD
        staged_codes = np.zeros((u_pad,) + codes_h.shape[1:],
                                codes_h.dtype)
        staged_codes[:u] = codes_h[uniq]
        staged_ids = np.full((u_pad,) + ids_h.shape[1:], INVALID_ID,
                             np.int32)
        staged_ids[:u] = ids_h[uniq]
        slots = inv.reshape(chain.shape).astype(np.int32)
        self.staged_bytes += staged_codes.nbytes + staged_ids.nbytes
        return score(artifact["centroids"], queries, probe_s,
                     jnp.asarray(staged_codes), jnp.asarray(staged_ids),
                     jnp.asarray(slots), jnp.asarray(live), k)

    def _staged_jits(self):
        if self._staged_fns is None:
            def _score(cent, q, probe_s, codes, ids, slots, live, k):
                staged = {"centroids": cent, "list_codes": codes,
                          "list_ids": ids}
                s, i = self._score_probed(staged, q, probe_s, slots,
                                          live)
                top_s, _, top_i = topk_by_position(s, i, k)
                return top_s, top_i

            self._staged_fns = (
                jax.jit(lambda coarse, q: jax.lax.top_k(
                    q @ coarse.T, self.cfg.nprobe)),
                jax.jit(_score, static_argnames="k"))
        return self._staged_fns
