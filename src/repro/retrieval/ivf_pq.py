"""IVF-PQ — coarse k-means partition + per-list PQ codes.

The classic large-catalogue trade (Jegou et al.; RecJPQ and the
embedding-compression survey both frame it as the endgame for
quantized recsys corpora): cluster the corpus into ``nlist`` coarse
cells and at query time score only the ``nprobe`` most promising
cells, reading ~``nprobe/nlist`` of the code bytes the flat scan
reads.  Probed candidates score by the usual LUT summation; with
``ivf_residual=True`` the codes quantize residuals against the cell
centroid and the coarse dot product is added back —

    score(i) = <q, c_coarse[list(i)]>  +  sum_d lut[d, codes[i, d]]

exact for the dot product up to PQ error either way.  One LUT build
per query (the codebook is global, so the LUT is shared across probed
lists); ``nprobe`` controls the recall/bytes dial.  Residual coding
defaults OFF for this dot-product workload — see ``IndexConfig``.

Storage layout: lists are padded to the longest list so probing is a
static-shape gather — ``list_codes (nlist, L, D)`` uint8 and
``list_ids (nlist, L)`` int32 carrying GLOBAL corpus ids
(``INVALID_ID`` in the padding).  Building runs on the host (numpy
bucketing) — it is the offline step; searching is pure JAX.

Distribution: lists are row-sharded over the model mesh axis
(``rows_leaves``); the tiny coarse table is replicated, so every shard
agrees on which lists each query probes and scores only the probed
lists it owns (``local_topk``) — the sharded driver merges the
per-shard (B, k) partials (retrieval/sharded.py, DESIGN.md §8).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pq_score import (INVALID_ID, build_lut_batch,
                                    pq_score_batched_ref)
from repro.retrieval import flat_pq
from repro.retrieval.base import Index, IndexConfig, register_index
from repro.retrieval.topk import topk_by_position


def coarse_kmeans(key: jax.Array, vectors: jax.Array, nlist: int,
                  iters: int = 10) -> jax.Array:
    """Euclidean Lloyd's over full-width vectors -> (nlist, d) centers.

    Reuses the per-subspace k-means with ONE subspace of width d."""
    return flat_pq.fit_pq(key, vectors, num_subspaces=1,
                          num_centroids=nlist, iters=iters)[0]


def coarse_assign(vectors: jax.Array, coarse: jax.Array) -> jax.Array:
    """Nearest coarse centroid per vector (euclidean), (N,) int32."""
    dots = vectors @ coarse.T                          # (N, nlist)
    c_sq = jnp.sum(jnp.square(coarse), axis=-1)        # (nlist,)
    return jnp.argmin(c_sq[None, :] - 2 * dots, axis=-1).astype(jnp.int32)


@register_index("ivf_pq")
class IVFPQ(Index):
    """nprobe-controlled probing over a coarse partition of PQ codes."""

    rows_leaves = ("list_codes", "list_ids")

    @classmethod
    def validate(cls, cfg: IndexConfig) -> None:
        if cfg.nlist < 1:
            raise ValueError(f"ivf_pq needs nlist >= 1, got {cfg.nlist}")
        if not 1 <= cfg.nprobe <= cfg.nlist:
            raise ValueError(
                f"ivf_pq needs 1 <= nprobe <= nlist, got "
                f"nprobe={cfg.nprobe} nlist={cfg.nlist}")

    # ------------------------------------------------------------ build
    def build(self, key: jax.Array, vectors: jax.Array) -> Dict:
        cfg = self.cfg
        n, d = vectors.shape
        if n < cfg.nlist:
            raise ValueError(
                f"corpus of {n} vectors cannot fill nlist={cfg.nlist} "
                f"coarse cells")
        k_coarse, k_pq = jax.random.split(key)
        coarse = coarse_kmeans(k_coarse, vectors, cfg.nlist,
                               iters=cfg.coarse_iters)
        assign = coarse_assign(vectors, coarse)
        to_code = vectors - jnp.take(coarse, assign, axis=0) \
            if cfg.ivf_residual else vectors
        cent = flat_pq.fit_pq(k_pq, to_code, cfg.num_subspaces,
                              cfg.num_centroids, cfg.iters)
        codes = flat_pq.encode_corpus(to_code, cent,
                                      backend=cfg.kernel_backend)
        code_dtype = np.uint8 if cfg.num_centroids <= 256 else np.int32

        # host-side bucketing into padded per-list tables (offline step)
        assign_np = np.asarray(assign)
        codes_np = np.asarray(codes).astype(code_dtype)
        counts = np.bincount(assign_np, minlength=cfg.nlist)
        cap = max(int(counts.max()), 1)
        order = np.argsort(assign_np, kind="stable")   # ids ascend per list
        starts = np.zeros(cfg.nlist, np.int64)
        starts[1:] = np.cumsum(counts)[:-1]
        slot = np.arange(n) - starts[assign_np[order]]
        list_codes = np.zeros((cfg.nlist, cap, cfg.num_subspaces),
                              code_dtype)
        list_ids = np.full((cfg.nlist, cap), INVALID_ID, np.int32)
        list_codes[assign_np[order], slot] = codes_np[order]
        list_ids[assign_np[order], slot] = order
        return {"coarse": coarse,
                "centroids": cent,
                "list_codes": jnp.asarray(list_codes),
                "list_ids": jnp.asarray(list_ids)}

    # ----------------------------------------------------------- search
    def _probe(self, artifact: Dict, queries: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
        """Top-nprobe coarse cells per query: (scores, list ids),
        both (B, nprobe).  The coarse table is replicated, so every
        shard computes the identical probe set."""
        coarse_scores = queries @ artifact["coarse"].T      # (B, nlist)
        return jax.lax.top_k(coarse_scores, self.cfg.nprobe)

    def _score_probed(self, artifact: Dict, queries: jax.Array,
                      probe_s: jax.Array, lists: jax.Array,
                      hit: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Score the (B, nprobe) probed lists -> flat (B, nprobe*L)
        candidate (scores, global ids); ``hit`` masks probes this
        caller does not own (sharded path) to (-inf, INVALID_ID)."""
        luts = build_lut_batch(queries, artifact["centroids"]
                               ).astype(jnp.float32)        # (B, D, K)
        codes = jnp.take(artifact["list_codes"], lists, axis=0)
        ids = jnp.take(artifact["list_ids"], lists, axis=0)  # (B, P, L)
        b, p, cap, n_sub = codes.shape
        # per-query LUT gather over its own probed rows — a (B, P·L, D)
        # gather, not the shared-code-stream kernel (each query reads
        # different rows); vmapped jnp stays fused under jit
        cand_scores = jax.vmap(pq_score_batched_ref)(
            luts[:, None], codes.reshape(b, p * cap, n_sub)
        ).reshape(b, p, cap)
        if self.cfg.ivf_residual:
            cand_scores = cand_scores + probe_s[:, :, None]  # coarse term
        valid = (ids != INVALID_ID) & hit[:, :, None]
        cand_scores = jnp.where(valid, cand_scores, -jnp.inf)
        ids = jnp.where(valid, ids, INVALID_ID)
        return cand_scores.reshape(b, p * cap), ids.reshape(b, p * cap)

    def search(self, artifact: Dict, queries: jax.Array,
               k: int) -> Tuple[jax.Array, jax.Array]:
        probe_s, lists = self._probe(artifact, queries)
        hit = jnp.ones(lists.shape, bool)
        s, i = self._score_probed(artifact, queries, probe_s, lists, hit)
        # position tiebreak: candidate layout (probe slot x list slot)
        # is identical on every shard, so this order is shard-invariant
        top_s, _, top_i = topk_by_position(s, i, k)
        return top_s, top_i

    def local_topk(self, artifact: Dict, queries: jax.Array, k: int, *,
                   shard: jax.Array, num_shards: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        lists_local = artifact["list_codes"].shape[0]
        probe_s, lists = self._probe(artifact, queries)  # GLOBAL list ids
        local = lists - shard * lists_local
        hit = (local >= 0) & (local < lists_local)
        local = jnp.clip(local, 0, lists_local - 1)
        s, i = self._score_probed(artifact, queries, probe_s, local, hit)
        return topk_by_position(s, i, k)
