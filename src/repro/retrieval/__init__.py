"""Quantized retrieval subsystem (DESIGN.md §8).

Batched top-k candidate retrieval over PQ-coded corpora: an
:class:`~repro.retrieval.base.Index` protocol with a plugin registry
(mirroring ``core/schemes/``) and two kinds —

  ``flat_pq``  exact batched ADC scan (fused ``pq_topk`` kernel)
  ``ivf_pq``   coarse k-means partition + per-list PQ residual codes,
               ``nprobe``-controlled probing

plus deterministic top-k merging (``topk.py``), row-sharded
distributed search (``sharded.py``), and the streamed build driver for
corpora that do not fit on device (``build.py``, DESIGN.md §12).
Serve through :class:`repro.launch.engine.RetrievalEngine`.
"""
from repro.retrieval import flat_pq, ivf_pq  # noqa: F401  (register kinds)
from repro.retrieval.base import (Index, IndexConfig, get_index,
                                  index_class, register_index,
                                  registered_index_kinds, suggest_nlist)
from repro.retrieval.build import (BuildStats, build_flat_artifact,
                                   build_ivf_artifact)
from repro.retrieval.flat_pq import FlatPQ
from repro.retrieval.ivf_pq import IVFPQ
from repro.retrieval.sharded import sharded_topk
from repro.retrieval.topk import INVALID_ID, merge_topk, topk_by_position

__all__ = ["BuildStats", "FlatPQ", "IVFPQ", "INVALID_ID", "Index",
           "IndexConfig", "build_flat_artifact", "build_ivf_artifact",
           "get_index", "index_class", "merge_topk", "register_index",
           "registered_index_kinds", "sharded_topk", "suggest_nlist",
           "topk_by_position"]
