"""Exact ADC over a PQ-coded corpus — the ``flat_pq`` index kind.

The paper stops at compressing the *embedding table*.  For the
retrieval-scoring cell (B queries x 1M candidates) the same PQ
machinery compresses the *candidate tower outputs*: fit per-subspace
k-means over the corpus vectors once offline, store only codes, and
score queries by LUT summation — ``score(i) = sum_d <q_d,
c_codes[i,d]^(d)>`` — which is exact for the dot product up to
quantization error and never reconstructs a candidate vector.  (Jegou
et al.'s classic PQ-ADC, applied to the paper's quantized-embedding
serving story.)

The hot loop is the ``pq_topk`` / ``pq_score_batched`` Pallas kernel
family (one LUT build per query, ONE pass over the code stream for the
whole batch, block-wise fused top-k); this module owns the offline
corpus-coding step (Lloyd's k-means per subspace, pure JAX) and the
``flat_pq`` :class:`~repro.retrieval.base.Index` plugin around it.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dpq_assign import assign as dpq_assign_op
from repro.kernels.pq_score import (INVALID_ID, score_candidates,
                                    score_candidates_batched,
                                    topk_candidates)
from repro.retrieval.base import Index, IndexConfig, register_index


def fit_pq(key: jax.Array, vectors: jax.Array, num_subspaces: int,
           num_centroids: int, iters: int = 10) -> jax.Array:
    """Per-subspace k-means over corpus vectors.

    vectors (N, d) -> centroids (D, K, S), S = d / D.
    """
    n, d = vectors.shape
    if d % num_subspaces:
        raise ValueError(
            f"dim {d} does not divide into {num_subspaces} subspaces")
    s = d // num_subspaces
    x = vectors.reshape(n, num_subspaces, s).transpose(1, 0, 2)  # (D, N, S)

    # init: distinct random rows per subspace — sampling WITHOUT
    # replacement; duplicate seeds collapse into dead centroids that
    # Lloyd's update can never split, which measurably hurts recall.
    # (Tiny corpora with n < K must sample with replacement.)  One
    # vmapped draw covers all D subspaces — a host-side Python loop
    # here serialized trace time on large D.
    keys = jax.random.split(key, num_subspaces)
    idx = jax.vmap(lambda kk: jax.random.choice(
        kk, n, (num_centroids,), replace=n < num_centroids))(keys)
    cent = jnp.take_along_axis(x, idx[..., None], axis=1)        # (D, K, S)

    def step(cent, _):
        # assign: nearest centroid per subspace
        dots = jnp.einsum("dns,dks->dnk", x, cent)
        c_sq = jnp.sum(jnp.square(cent), axis=-1)                # (D, K)
        codes = jnp.argmin(c_sq[:, None, :] - 2 * dots, axis=-1)  # (D, N)
        onehot = jax.nn.one_hot(codes, cent.shape[1], dtype=x.dtype)
        counts = jnp.sum(onehot, axis=1)                         # (D, K)
        sums = jnp.einsum("dnk,dns->dks", onehot, x)
        new = jnp.where(counts[..., None] > 0,
                        sums / jnp.maximum(counts[..., None], 1.0), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    return cent


def encode_corpus(vectors: jax.Array, centroids: jax.Array,
                  backend: Optional[str] = None) -> jax.Array:
    """vectors (N, d) -> codes (N, D) int32 (dispatched dpq_assign)."""
    n, d = vectors.shape
    n_sub, _, s = centroids.shape
    e_sub = vectors.reshape(n, n_sub, s)
    return dpq_assign_op(e_sub, centroids, backend=backend)


def build_corpus_artifact(key: jax.Array, vectors: jax.Array,
                          num_subspaces: int = 8, num_centroids: int = 256,
                          iters: int = 10,
                          backend: Optional[str] = None) -> Dict:
    """Offline step: corpus vectors -> {codes, centroids} artifact."""
    cent = fit_pq(key, vectors, num_subspaces, num_centroids, iters)
    codes = encode_corpus(vectors, cent, backend=backend)
    dtype = jnp.uint8 if num_centroids <= 256 else jnp.int32
    return {"codes": codes.astype(dtype), "centroids": cent}


def adc_scores(artifact: Dict, query: jax.Array,
               backend: Optional[str] = None,
               block_n: Optional[int] = None) -> jax.Array:
    """query (d,) -> scores (N,) over the coded corpus.

    Scoring runs through the dispatched ``pq_score`` kernel — the LUT
    stays in VMEM on TPU; the XLA reference is the CPU fallback.  The
    codes go in at their stored dtype (uint8); widening happens inside
    the kernels, per block.  ``block_n=None`` resolves through the
    autotune cache (DESIGN.md §13).
    """
    return score_candidates(query, artifact["centroids"],
                            artifact["codes"],
                            block_n=block_n, backend=backend)


def reconstruction_mse(artifact: Dict, vectors: jax.Array) -> jax.Array:
    """Mean squared quantization error of the coded corpus."""
    from repro.kernels.mgqe_decode.ref import mgqe_decode_ref
    rec = mgqe_decode_ref(artifact["codes"], artifact["centroids"])
    return jnp.mean(jnp.square(rec - vectors))


@register_index("flat_pq")
class FlatPQ(Index):
    """Exact batched ADC scan: every candidate scored for every query.

    Recall vs the PQ-decoded corpus is 1.0 by construction (the scan
    IS the LUT summation of the decoded codes); the cost is O(B · N)
    LUT adds — ``ivf_pq`` trades a recall epsilon for a ~nlist/nprobe
    cut of that.
    """

    rows_leaves = ("codes",)

    @classmethod
    def validate(cls, cfg: IndexConfig) -> None:
        if cfg.num_subspaces < 1 or cfg.num_centroids < 2:
            raise ValueError(
                f"flat_pq needs num_subspaces >= 1 and num_centroids >= "
                f"2, got {cfg.num_subspaces}/{cfg.num_centroids}")

    def build(self, key: jax.Array, vectors: jax.Array) -> Dict:
        """Build via the streaming driver (retrieval/build.py):
        codebooks fitted on ``cfg.train_sample`` rows, encoding run in
        ``cfg.encode_block``-row blocks (0 = full corpus / one shot).
        Use ``build.build_flat_artifact`` directly to keep the code
        table in host memory."""
        from repro.retrieval.build import build_flat_artifact
        artifact, _ = build_flat_artifact(key, vectors, self.cfg)
        return {name: jnp.asarray(leaf)
                for name, leaf in artifact.items()}

    def scores(self, artifact: Dict, queries: jax.Array) -> jax.Array:
        """Full (B, N) score matrix — exactness oracle + small corpora."""
        return score_candidates_batched(
            queries, artifact["centroids"], artifact["codes"],
            block_n=self.cfg.block_n, backend=self.cfg.kernel_backend)

    def search(self, artifact: Dict, queries: jax.Array,
               k: int) -> Tuple[jax.Array, jax.Array]:
        return topk_candidates(
            queries, artifact["centroids"], artifact["codes"], k,
            block_n=self.cfg.block_n, backend=self.cfg.kernel_backend)

    def local_topk(self, artifact: Dict, queries: jax.Array, k: int, *,
                   shard: jax.Array, num_shards: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        rows_local = artifact["codes"].shape[0]
        s, i = self.search(artifact, queries, k)
        # shard-local row offsets -> global corpus ids (pad stays pad);
        # the id doubles as the flat kinds' tiebreak key
        gids = jnp.where(i == INVALID_ID, INVALID_ID,
                         i + shard * rows_local)
        return s, gids, gids
