"""Sharded batched top-k: distributed corpus rows, merged partials.

Corpus-sized artifact leaves (``Index.rows_leaves`` — flat codes, IVF
list tables) are row-sharded over the ``model`` mesh axis exactly like
the quantized code tables in ``sharding/quantized.py``; everything
else (codebooks, the coarse table) is KBs and replicated.  One
shard_map per search:

  forward: all-gather queries over the data axes (KBs) -> each model
           shard runs the index's OWN ``local_topk`` on the rows it
           holds (global ids, (B_global, k) partials) -> all-gather the
           partials over model -> two-key ``merge_topk`` -> slice the
           local data-shard batch back out.

Wire bytes per search: O(B · k · (model_n + 1) · 8) — scores + ids,
independent of the corpus size; versus O(B · N · 4) to centralize the
score matrix, or O(N · D) to move codes.  The merge is bit-identical
to the single-device scan: per-candidate scores do not depend on block
or shard boundaries, and the (score desc, id asc) total order makes
truncation-by-k associative (retrieval/topk.py).

Placement comes from the index registry
(``Index.artifact_shard_specs`` via ``sharding/rules.py``), so a new
index kind distributes with zero edits here — mirroring how the scheme
registry feeds ``sharding/quantized.py`` (DESIGN.md §6/§8).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.retrieval.base import Index
from repro.retrieval.topk import merge_topk
from repro.sharding.compat import shard_map
from repro.sharding.gather import _ambient_mesh, data_shard_index


def sharded_topk(index: Index, artifact: Dict, queries: jax.Array,
                 k: int, model_axis: str = "model",
                 mesh: Optional[jax.sharding.Mesh] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Distributed ``index.search``: queries (B, d) -> (scores (B, k),
    ids (B, k)) over row-sharded corpus artifacts.

    Falls back to the single-device search when no usable mesh is
    ambient or the row counts don't divide — call sites never branch.
    """
    mesh = mesh or _ambient_mesh()
    if mesh is None or mesh.size == 1 or model_axis not in mesh.axis_names:
        return index.search(artifact, queries, k)
    if not index.supports_sharded:
        raise ValueError(
            f"index kind {index.kind!r} cannot be distributed")

    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    model_n = mesh.shape[model_axis]
    data_n = int(np.prod([mesh.shape[a] for a in data_axes])) or 1
    rows = {name: artifact[name].shape[0] for name in index.rows_leaves}
    b, d = queries.shape
    if model_n == 1 or b == 0 or any(r % model_n for r in rows.values()):
        # indivisible corpora (or empty batches) take the exact path;
        # on an actually-sharded artifact XLA would all-gather the
        # codes here — correct but slow, so engines reject those
        # configurations up front.
        return index.search(artifact, queries, k)
    pad = (-b) % data_n
    if pad:
        queries = jnp.pad(queries, ((0, pad), (0, 0)))
    b_local = (b + pad) // data_n

    def body(art_loc, q_loc):
        q_all = q_loc
        if data_axes:
            q_all = jax.lax.all_gather(q_all, data_axes, tiled=True)
        shard = jax.lax.axis_index(model_axis)
        s, tb, i = index.local_topk(art_loc, q_all, k, shard=shard,
                                    num_shards=model_n)  # (B_global, k)
        # gather every shard's partial top-k and merge — O(B·k) wire
        bg = s.shape[0]

        def cat(x):
            x_all = jax.lax.all_gather(x, model_axis)    # (model_n, B, k)
            return jnp.moveaxis(x_all, 0, 1).reshape(bg, model_n * k)
        ms, mi = merge_topk(cat(s), cat(i), k, tiebreak=cat(tb))
        if data_axes:
            idx = data_shard_index(mesh, data_axes)
            ms = jax.lax.dynamic_slice_in_dim(ms, idx * b_local,
                                              b_local, axis=0)
            mi = jax.lax.dynamic_slice_in_dim(mi, idx * b_local,
                                              b_local, axis=0)
        return ms, mi

    art_specs = index.artifact_shard_specs(artifact, model_axis=model_axis)
    topk_sm = shard_map(
        body, mesh=mesh,
        in_specs=(art_specs, P(data_axes or None, None)),
        out_specs=(P(data_axes or None, None), P(data_axes or None, None)),
        check=False)
    scores, ids = topk_sm(artifact, queries)
    return scores[:b], ids[:b]


__all__ = ["sharded_topk"]
