"""PartitionSpec rules, applied by parameter *name* over pytrees.

Rules are keyed on the final path component and specify the spec for
the TRAILING dims of the leaf; leading stack dims (scan layer stacks,
pattern groups) are padded with None automatically.  This makes one
rule table cover the uniform (L, ...) and pattern (G, p, ...) layouts.

Sharding strategy (DESIGN.md §5):
  * TP over "model": attention projections, FFN hidden, expert dim (or
    d_ff when experts don't divide), vocab rows + lm_head columns.
  * DP over ("pod","data"): the batch.
  * ZeRO-1: Adam moments additionally sharded over "data" on their
    largest divisible dim (fp32 m/v would not fit replicated per DP
    rank for the 27B+ archs).
  * optional FSDP ("fsdp_params"): stacked layer weights also sharded
    over "data"; lax.scan slices then all-gather one layer at a time.
"""
from __future__ import annotations

import re
from typing import Any, Callable, Dict, List, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import GNNConfig, LMConfig, RecsysConfig


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _pad_spec(spec: Tuple, ndim: int) -> P:
    """Left-pad a trailing-dims spec with None up to ndim."""
    pad = ndim - len(spec)
    if pad < 0:
        raise ValueError(f"spec {spec} longer than ndim={ndim}")
    return P(*((None,) * pad + tuple(spec)))


def _path_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def spec_tree(template: Any,
              rules: List[Tuple[str, Callable[[Any], Tuple]]],
              default: Tuple = ()) -> Any:
    """Build a PartitionSpec pytree for ``template``.

    rules: list of (regex matched against the full path, fn(leaf) ->
    trailing-dims spec tuple).  First match wins.
    """
    def assign(path, leaf):
        name = _path_name(path)
        ndim = len(leaf.shape)
        for pattern, fn in rules:
            if re.search(pattern, name):
                return _pad_spec(tuple(fn(leaf)), ndim)
        return _pad_spec(tuple(default), ndim)

    return jax.tree_util.tree_map_with_path(assign, template)


def _divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


# ----------------------------------------------------------------------
# LM rules
# ----------------------------------------------------------------------

def lm_param_rules(cfg: LMConfig, mesh) -> List:
    model = mesh.shape["model"]
    data = "data"
    fsdp = cfg.fsdp_params

    def maybe_fsdp(spec: Tuple, leaf, fsdp_dim: int) -> Tuple:
        """Add data-axis sharding on dim ``fsdp_dim`` (within trailing
        spec) when FSDP is on and the dim divides."""
        if not fsdp:
            return spec
        spec = list(spec)
        if spec[fsdp_dim] is None and _divides(
                leaf.shape[len(leaf.shape) - len(spec) + fsdp_dim],
                mesh.shape["data"]):
            spec[fsdp_dim] = data
        return tuple(spec)

    def expert_spec(leaf, transpose: bool):
        # (E, d, f) or (E, f, d): shard E if divisible, else the ff dim
        e = leaf.shape[-3]
        if _divides(e, model):
            return maybe_fsdp(("model", None, None), leaf, 1)
        if transpose:                 # (E, f, d)
            return (None, "model", None)
        return (None, None, "model")  # (E, d, f)

    rules = [
        # embedding tables: rows over model
        (r"embed/emb$", lambda l: ("model", None)),
        (r"embed/centroids", lambda l: (None, None, None)),
        (r"embed/u$", lambda l: ("model", None)),
        (r"embed/v$", lambda l: (None, None)),
        # attention
        (r"/wq$", lambda l: maybe_fsdp((None, "model"), l, 0)),
        # kv-repeat mode: K/V are expanded to full head count inside the
        # layer, so wk/wv stay replicated (sharding their columns would
        # split sub-head and force per-layer resharding)
        (r"/wk$|/wv$", lambda l: maybe_fsdp(
            (None, None) if cfg.attn_kv_repeat
            else ((None, "model") if _divides(l.shape[-1], model)
                  else (None, None)), l, 0)),
        (r"/wo$", lambda l: maybe_fsdp(("model", None), l, 1)),
        # dense FFN
        (r"ffn/w_gate$|ffn/w_up$", lambda l: maybe_fsdp((None, "model"), l, 0)),
        (r"ffn/w_down$", lambda l: maybe_fsdp(("model", None), l, 1)),
        # MoE
        (r"moe/router$", lambda l: (None, None)),
        (r"moe/w_gate$|moe/w_up$", lambda l: expert_spec(l, False)),
        (r"moe/w_down$", lambda l: expert_spec(l, True)),
        # head / norms
        (r"lm_head$", lambda l: (None, "model")),
        (r"ln|norm", lambda l: ()),
    ]
    return rules


def lm_state_specs(cfg: LMConfig, mesh, params_template, opt_template):
    """(params_spec, opt_spec) — opt moments get ZeRO-1 data sharding."""
    rules = lm_param_rules(cfg, mesh)
    p_spec = spec_tree(params_template, rules)

    data_n = mesh.shape["data"]

    def zero1(path, leaf, spec):
        # moments: add "data" on the first dim where it divides & free
        if leaf.ndim == 0:
            return P()
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        used = {a for a in parts if a is not None}
        if "data" in used:
            return P(*parts)
        for i in range(leaf.ndim):
            if parts[i] is None and _divides(leaf.shape[i], data_n):
                parts[i] = "data"
                break
        return P(*parts)

    def build_opt(opt_t):
        out = {}
        for k, v in opt_t.items():
            if k == "step":
                out[k] = P()
            elif k in ("m", "v", "acc", "mom"):
                is_p = lambda x: isinstance(x, P)
                flat_p = jax.tree_util.tree_flatten_with_path(v)[0]
                spec_flat = jax.tree_util.tree_flatten(
                    p_spec, is_leaf=is_p)[0]
                specs = []
                for (path, leaf), sp in zip(flat_p, spec_flat):
                    specs.append(zero1(path, leaf, tuple(sp)))
                out[k] = jax.tree_util.tree_unflatten(
                    jax.tree_util.tree_structure(v), specs)
            else:
                out[k] = jax.tree.map(lambda _: P(), v)
        return out

    return p_spec, build_opt(opt_template)


def lm_batch_spec(multi_pod: bool) -> Dict:
    dp = dp_axes(multi_pod)
    return {"tokens": P(dp, None), "labels": P(dp, None)}


def lm_cache_spec(cfg: LMConfig, batch: int, mesh, multi_pod: bool,
                  cache_template) -> Any:
    """Cache sharding: batch over DP when it divides, else the sequence
    axis (SP for the B=1 long-context cell); kv heads over model when
    divisible, else sequence over model too."""
    dp = dp_axes(multi_pod)
    dp_n = int(np.prod([mesh.shape[a] for a in dp]))
    model = mesh.shape["model"]
    kv_ok = _divides(cfg.num_kv_heads, model)
    b_ok = _divides(batch, dp_n)

    def assign(path, leaf):
        ndim = len(leaf.shape)
        if ndim == 0:
            return P()
        # cache stacks are (k, v, kpos) tuples: tuple index 2 == kpos
        # with trailing dims (B, S); k/v trail with (B, S, kv, hd).
        idx = None
        for part in reversed(path):
            if hasattr(part, "idx"):
                idx = part.idx
                break
        is_kv = (idx is None or idx < 2)
        lead = ndim - (4 if is_kv else 2)
        parts = [None] * ndim
        if b_ok:
            parts[lead] = dp
            if not kv_ok and is_kv:
                parts[lead + 1] = "model"      # seq over model
            elif is_kv and kv_ok:
                parts[lead + 2] = "model"
        else:
            # B=1 (long-context): SP — shard the sequence over DP axes
            parts[lead + 1] = dp if _divides(leaf.shape[lead + 1], dp_n) \
                else None
            if is_kv and kv_ok:
                parts[lead + 2] = "model"
        return P(*parts)

    return jax.tree_util.tree_map_with_path(assign, cache_template)


# ----------------------------------------------------------------------
# GNN rules
# ----------------------------------------------------------------------

def gnn_param_rules(cfg: GNNConfig, mesh) -> List:
    model = mesh.shape["model"]
    c_ok = _divides(cfg.d_hidden, model)
    ch = "model" if c_ok else None
    return [
        (r"species_emb$", lambda l: (None, ch)),
        (r"feat_proj/w$", lambda l: (None, ch)),
        (r"radial/.*w$", lambda l: ()),          # small MLP: replicate
        (r"a_mix$|m1$|m2$|m3$", lambda l: (None, ch)),   # (L+1, C, C): shard out-C
        (r"u2$|u3$", lambda l: (ch, None)),
        (r"readout", lambda l: ()),
    ]


def gnn_graph_spec(multi_pod: bool) -> Dict:
    dp = dp_axes(multi_pod)
    return {
        "positions": P(dp, None),
        "species": P(dp),
        "node_feats": P(dp, None),
        "edge_index": P(None, dp),
        "graph_id": P(dp),
        "labels": P(dp),
        "energy": P(),
        "n_graphs": None,
    }


# ----------------------------------------------------------------------
# RecSys rules
# ----------------------------------------------------------------------

def recsys_param_rules(cfg: RecsysConfig, mesh) -> List:
    model = mesh.shape["model"]

    def table_spec(leaf):
        if leaf.shape[0] >= 16 * model and _divides(leaf.shape[0], model):
            return ("model", None)
        return (None, None)

    return [
        (r"emb$", table_spec),                 # full tables + dpq/mgqe emb
        (r"centroids", lambda l: ()),
        (r"codes$", lambda l: table_spec(l)),
        (r"/u$", table_spec),                  # lrf rows
        (r"pos_emb$", lambda l: ()),
        (r"mlp|tower|w_out|blocks|layers|router", lambda l: ()),
    ]


def recsys_batch_spec(batch_dict_template, multi_pod: bool) -> Any:
    dp = dp_axes(multi_pod)

    def assign(path, leaf):
        if len(leaf.shape) == 0:
            return P()
        return P(*((dp,) + (None,) * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(assign, batch_dict_template)


# ----------------------------------------------------------------------
# quantized serving artifacts (DESIGN.md §6)
# ----------------------------------------------------------------------

def quantized_artifact_specs(cfg, model_axis: str = "model"):
    """PartitionSpec pytree for a quantized serving artifact.

    Placement policy (sharding/quantized.py): code tables — the only
    O(vocab) leaves — are row-sharded over ``model_axis``; codebooks
    are KBs and replicated everywhere.  The hot-row decode-ahead block
    (``hot`` leaf, DESIGN.md §9) is replicated too: it is O(hot_rows),
    not O(vocab), and every data shard's flush gathers from it — the
    cold codes stay row-sharded underneath.  The tree is DERIVED from
    the scheme's own artifact spec (``Scheme.artifact_shard_specs``,
    core/schemes/base.py), so it matches
    ``Embedding.serving_artifact_struct()`` leaf-for-leaf and can be
    zipped against a real artifact for ``jax.device_put`` or passed
    whole as shard_map ``in_specs`` — any registered scheme with
    row-shardable codes (dpq, mgqe, rq, ...) is covered with no edits
    here.
    """
    from repro.core.schemes import get_scheme
    return get_scheme(cfg).artifact_shard_specs(model_axis=model_axis)


def shard_quantized_artifact(artifact, cfg, mesh, model_axis: str = "model"):
    """Place an exported artifact onto ``mesh``: codes row-sharded,
    codebooks replicated.  Returns the device-resident pytree."""
    specs = quantized_artifact_specs(cfg, model_axis=model_axis)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(artifact, shardings)


# ----------------------------------------------------------------------
# retrieval index artifacts (DESIGN.md §8)
# ----------------------------------------------------------------------

def retrieval_artifact_specs(index, artifact, model_axis: str = "model"):
    """PartitionSpec pytree for a retrieval index artifact.

    Same placement policy as the quantized tables above — the
    O(corpus) leaves (``Index.rows_leaves``: flat corpus codes, the
    bounded IVF list tables ``list_codes``/``list_ids`` including any
    spill lists) are row-sharded over ``model_axis``; codebooks, the
    coarse table, and the O(nlist) ``list_chain`` map are KBs and
    replicated — every shard needs the full chain to expand a probed
    cell into its spill lists.  DERIVED from the index plugin's own
    spec (``Index.artifact_shard_specs``, retrieval/base.py) so any
    registered kind is covered with no edits here.
    """
    return index.artifact_shard_specs(artifact, model_axis=model_axis)


def shard_retrieval_artifact(artifact, index, mesh,
                             model_axis: str = "model"):
    """Place a built index onto ``mesh``: corpus rows sharded,
    codebooks replicated.  Returns the device-resident pytree."""
    specs = retrieval_artifact_specs(index, artifact,
                                     model_axis=model_axis)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(artifact, shardings)


# ----------------------------------------------------------------------
# generic helpers
# ----------------------------------------------------------------------

def named(mesh, spec_tree_):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if isinstance(s, P) else P()),
        spec_tree_, is_leaf=lambda x: isinstance(x, P) or x is None)
