"""Distribution layer: PartitionSpec rule engine per arch family,
shard_map helpers — dense row gather (gather.py) and the sharded
quantized-table serving gather (quantized.py, DESIGN.md §6)."""
