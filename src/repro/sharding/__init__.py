"""Distribution layer: PartitionSpec rule engine per arch family,
shard_map helpers (mod-sharded embedding lookup, split-KV decode)."""
