"""Sharded quantized-table serving: distributed codes + replicated
codebooks (DESIGN.md §6).

MGQE's production story (paper §2, Fig. 1) is that after export only
integer codes ``(n, D)`` and tiny centroid tables remain.  The codes
are still O(vocab) — at billion-row vocabs they outgrow one chip's HBM
— so this module row-shards the *code* tables over the ``model`` mesh
axis exactly like ``sharding/gather.py`` row-shards dense tables, while
the codebooks (KBs each; they fit in VMEM, let alone HBM) are simply
replicated on every device.

The lookup is a shard_map with the same wire-cost shape as the dense
``row_gather`` path:

  forward: all-gather ids over the data axes (KBs) -> each model shard
           decodes the rows it owns through the *fused* decode kernel
           on its local code block (zeros elsewhere) -> psum over
           model of the (B_global, d) partials -> slice the local
           data-shard batch back out.

Wire bytes per lookup: O(B_global · d · 4), independent of vocab —
versus the table-sized all-reduces a naive pjit of ``take`` over a
row-sharded code table makes XLA emit.  There is no backward pass:
codes are a frozen export artifact.

Which schemes can be distributed, the per-scheme artifact placement,
and the per-shard local decode all come from the scheme registry
(``Scheme.supports_sharded_codes`` / ``artifact_shard_specs`` /
``QuantizedScheme.decode`` — core/schemes/), so the ServingEngine, the
benches, the tests, and any new scheme plugin all place and decode
artifacts the same way with zero edits here.  That routing is how the
rq scheme's single-pass fused ``rq_decode_stages`` decode (DESIGN.md
§11) reaches each shard with no sharding-layer changes: the per-shard
``scheme.decode(art_loc, local, ...)`` call below IS the fused path.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.schemes import get_scheme, registered_kinds, scheme_class
from repro.core.types import EmbeddingConfig
from repro.sharding.compat import shard_map
from repro.sharding.gather import _ambient_mesh, data_shard_index


def supports_sharding(kind: str, variant: str = "-") -> bool:
    """True when :func:`quantized_gather` can distribute this scheme's
    codes — the source of truth for the README support matrix
    (tools/gen_tables.py)."""
    del variant  # every variant of a shardable scheme is supported
    try:
        cls = scheme_class(kind)
    except KeyError:
        return False
    return cls.supports_sharded_codes


def sharded_variants():
    """(kind, variant) pairs the sharded gather supports — enumerated
    from the scheme registry."""
    return [(kind, v)
            for kind in registered_kinds()
            if supports_sharding(kind)
            for v in scheme_class(kind).variants()]


def _codes_rows(artifact: dict) -> int:
    """Vocab row count of the (possibly per-tier list of) code tables."""
    codes = artifact["codes"]
    if isinstance(codes, (list, tuple)):
        ns = {c.shape[0] for c in codes}
        if len(ns) != 1:
            raise ValueError(
                f"per-tier code tables disagree on vocab rows: {sorted(ns)}")
        return ns.pop()
    return codes.shape[0]


def quantized_gather(artifact: dict, ids: jax.Array, cfg: EmbeddingConfig,
                     model_axis: str = "model",
                     mesh: Optional[jax.sharding.Mesh] = None,
                     decode_block_b: Optional[int] = None) -> jax.Array:
    """Sharded serving decode: ``Embedding.serve`` for distributed codes.

    Falls back to the single-device fused decode when no usable mesh is
    ambient or the shapes don't divide (single-device tests, export
    tooling) — call sites never branch.

    ``decode_block_b`` is the batch block of each shard's local decode
    kernel.  The default ``None`` defers to the autotune cache
    (DESIGN.md §11) — the shard-local batch is the all-gathered global
    batch, a shape the engine's ``cfg.decode_block_b`` pin was never
    sized for (pinning it here bypassed the tuner and measured 8x
    slower in ``BENCH_kernels.json`` sharded_decode).  Pass an int to
    pin explicitly.
    """
    scheme = get_scheme(cfg)
    if not scheme.supports_sharded_codes:
        raise ValueError(f"cannot shard codes of kind={cfg.kind!r}")
    mesh = mesh or _ambient_mesh()
    if mesh is None or mesh.size == 1 or model_axis not in mesh.axis_names:
        return scheme.decode(artifact, ids)

    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    model_n = mesh.shape[model_axis]
    data_n = int(np.prod([mesh.shape[a] for a in data_axes]))
    v = _codes_rows(artifact)
    lead = ids.shape
    flat = int(np.prod(lead))
    if model_n == 1 or v % model_n or flat == 0:
        # NOTE: on an actually-sharded artifact this fallback makes
        # XLA all-gather the O(vocab) code table — correct but slow.
        # Only reachable for indivisible vocabs (the engine rejects
        # those up front) or empty batches; indivisible *batches* are
        # padded below instead of falling back.
        return scheme.decode(artifact, ids)
    # pad the flat batch up to the data-shard granularity (id 0 is
    # always valid) so odd request sizes keep the O(B·d) wire path
    flat_ids = ids.reshape(-1)
    pad = (-flat) % data_n
    if pad:
        flat_ids = jnp.pad(flat_ids, (0, pad))
    rows_local = v // model_n
    b_local = (flat + pad) // data_n
    d_out = cfg.dim

    def body(art_loc, ids_loc):
        ids_all = ids_loc
        if data_axes:
            ids_all = jax.lax.all_gather(ids_all, data_axes, tiled=True)
        shard = jax.lax.axis_index(model_axis)
        local = ids_all - shard * rows_local
        hit = (local >= 0) & (local < rows_local)
        local = jnp.clip(local, 0, rows_local - 1)
        # decode against the LOCAL code shard; any frequency-dependent
        # blending (MGQE tiers) keys on the GLOBAL id, not the shard
        # offset — the scheme's decode hook takes both
        rows = scheme.decode(art_loc, local, tier_ids=ids_all,
                             block_b=decode_block_b)  # (B_global, d)
        rows = rows * hit[:, None].astype(rows.dtype)
        full = jax.lax.psum(rows, model_axis)
        if data_axes:
            idx = data_shard_index(mesh, data_axes)
            full = jax.lax.dynamic_slice_in_dim(full, idx * b_local,
                                                b_local, axis=0)
        return full

    art_specs = scheme.artifact_shard_specs(model_axis=model_axis)
    gather_sm = shard_map(
        body, mesh=mesh,
        in_specs=(art_specs, P(data_axes or None)),
        out_specs=P(data_axes or None, None),
        check=False)
    out = gather_sm(artifact, flat_ids)[:flat]
    return out.reshape(lead + (d_out,))


__all__ = ["quantized_gather", "sharded_variants", "supports_sharding"]
