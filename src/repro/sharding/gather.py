"""Model-parallel embedding row gather via shard_map (§Perf hillclimb).

The naive pjit path for ``take(table, ids)`` with a row-sharded table
and a data-sharded batch makes XLA materialize / all-reduce *dense
table-sized* tensors in the backward (the two-tower train_batch
baseline shows ~100 s of collective term from exactly this).  The
shard_map formulation keeps everything proportional to the BATCH:

  forward:  all-gather ids over data (KBs) -> each model shard gathers
            the rows it owns (zeros elsewhere) -> psum over model of the
            (B_global, d) partials -> slice the local data-shard batch.
  backward: transpose of the psum+slice replays output grads to every
            model shard (one (B_global, d) all-gather-sized collective),
            and the scatter-add into the table shard is LOCAL.

Wire bytes per table per step: O(B_global * d), independent of vocab.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.compat import shard_map


def _ambient_mesh():
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def data_shard_index(mesh, data_axes) -> jax.Array:
    """Linearized index of this device's data shard — shard_map-body
    helper shared by the dense row gather and the quantized gather
    (sharding/quantized.py), so their batch-slice arithmetic is one
    implementation."""
    idx = jnp.int32(0)
    for a in data_axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def row_gather(table: jax.Array, ids: jax.Array,
               sharded: bool = False, model_axis: str = "model"
               ) -> jax.Array:
    """take(table, ids, axis=0) — shard_map path when ``sharded``.

    Falls back to plain take when no usable mesh is ambient or shapes
    don't divide (single-device tests, serving export, etc.).
    """
    if not sharded:
        return jnp.take(table, ids, axis=0)
    mesh = _ambient_mesh()
    if mesh is None or mesh.size == 1 or model_axis not in mesh.axis_names:
        return jnp.take(table, ids, axis=0)

    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    model_n = mesh.shape[model_axis]
    data_n = int(np.prod([mesh.shape[a] for a in data_axes]))
    v, d = table.shape
    lead = ids.shape
    flat = int(np.prod(lead))
    if v % model_n or flat % data_n:
        return jnp.take(table, ids, axis=0)
    rows_local = v // model_n
    b_local = flat // data_n

    def _local_ids(ids_loc):
        ids_all = ids_loc.reshape(-1)
        if data_axes:
            ids_all = jax.lax.all_gather(ids_all, data_axes, tiled=True)
        shard = jax.lax.axis_index(model_axis)
        local = ids_all - shard * rows_local
        hit = (local >= 0) & (local < rows_local)
        return jnp.clip(local, 0, rows_local - 1), hit

    def fwd_body(table_loc, ids_loc):
        local, hit = _local_ids(ids_loc)
        rows = jnp.take(table_loc, local, axis=0)
        rows = rows * hit[:, None].astype(rows.dtype)
        full = jax.lax.psum(rows, model_axis)          # (B_global, d)
        # slice this data shard's batch back out
        if data_axes:
            idx = data_shard_index(mesh, data_axes)
            full = jax.lax.dynamic_slice_in_dim(full, idx * b_local,
                                                b_local, axis=0)
        return full

    def bwd_body(ids_loc, dout_loc):
        """Table gradient computed COMPLETE on every shard: all-gather
        the (batch-sized) output grads over data, scatter-add into the
        local row shard.  Wire cost O(B_global x d) instead of the
        table-sized psum the generic transpose would emit — the whole
        point of this path (§Perf hillclimb C)."""
        local, hit = _local_ids(ids_loc)
        dout = dout_loc
        if data_axes:
            dout = jax.lax.all_gather(dout, data_axes, tiled=True)
        dt = jnp.zeros((rows_local, d), dout.dtype)
        dt = dt.at[local].add(dout * hit[:, None].astype(dout.dtype))
        return dt

    gather_sm = shard_map(
        fwd_body, mesh=mesh,
        in_specs=(P(model_axis, None), P(data_axes or None)),
        out_specs=P(data_axes or None, None),
        check=False)
    scatter_sm = shard_map(
        bwd_body, mesh=mesh,
        in_specs=(P(data_axes or None), P(data_axes or None, None)),
        out_specs=P(model_axis, None),      # identical across data: no psum
        check=False)

    @jax.custom_vjp
    def _gather(table, ids_flat):
        return gather_sm(table, ids_flat)

    def _fwd(table, ids_flat):
        return gather_sm(table, ids_flat), ids_flat

    def _bwd(ids_flat, dout):
        return scatter_sm(ids_flat, dout), None

    _gather.defvjp(_fwd, _bwd)
    out = _gather(table, ids.reshape(-1))
    return out.reshape(lead + (d,))
