"""jax version compatibility for shard_map.

``jax.shard_map`` (with ``check_vma=``) landed in jax 0.6; older
releases ship ``jax.experimental.shard_map.shard_map`` (with
``check_rep=``).  Everything in this repo goes through this wrapper so
both API generations work — CI floats on recent jax while pinned TPU
containers may lag.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Version-agnostic shard_map; ``check`` maps to check_vma/check_rep."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


__all__ = ["shard_map"]
