"""Pure-jnp oracle for the fused unpack-and-decode kernel.

Unpack the (B, W) packed words to (B, D) codes, then the same
per-subspace centroid gather as ``mgqe_decode_ref``.  Under one jit
XLA fuses the shift/mask unpack into the gather's index computation,
so this is also the honest XLA serving fallback — the unpacked (B, D)
codes for the *batch* live in registers/cache, and no O(n) unpacked
table is ever materialized.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.packed_decode.pack import unpack_codes


def packed_decode_ref(packed: jnp.ndarray, centroids: jnp.ndarray,
                      bits: int) -> jnp.ndarray:
    """packed (B, W) uint8; centroids (D, K, S) -> (B, D*S) float."""
    b = packed.shape[0]
    d, _, s = centroids.shape
    codes = unpack_codes(packed, bits, d)             # (B, D) uint8
    gathered = jnp.take_along_axis(
        centroids[None],                              # (1, D, K, S)
        codes.astype(jnp.int32)[..., None, None],     # (B, D, 1, 1)
        axis=2)                                       # (B, D, 1, S)
    return gathered[:, :, 0, :].reshape(b, d * s)
