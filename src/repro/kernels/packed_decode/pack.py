"""Bit-packing for sub-byte quantization codes (DESIGN.md §13).

A code table (..., D) whose entries fit in ``bits`` ∈ {2, 4, 8} bits is
stored as packed bytes (..., W) with ``W = ceil(D / (8 // bits))`` —
``8 // bits`` codes per byte, little-endian within the byte (code j of
a byte occupies bits ``[j*bits, (j+1)*bits)``).  The layout is chosen
so a byte-aligned slice of W is a byte-aligned slice of codes, which is
what lets the fused kernel tile the subspace axis without crossing
byte boundaries.

Both functions are pure jnp (trace-safe, shape-polymorphic over the
leading dims); ``pack_codes`` runs once at export time, while
``unpack_codes`` is the *reference* unpack — the serving path never
materializes it, the kernel unpacks per VMEM block instead
(``packed_decode.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

PACK_BITS = (2, 4, 8)


def packed_width(num_codes: int, bits: int) -> int:
    """Bytes needed to pack ``num_codes`` codes of ``bits`` bits each."""
    if bits not in PACK_BITS:
        raise ValueError(f"bits must be one of {PACK_BITS}, got {bits}")
    per_byte = 8 // bits
    return -(-num_codes // per_byte)


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """codes (..., D) int, values < 2**bits -> packed (..., W) uint8."""
    per_byte = 8 // bits
    d = codes.shape[-1]
    w = packed_width(d, bits)
    pad = w * per_byte - d
    c = codes.astype(jnp.uint8)
    if pad:
        c = jnp.pad(c, [(0, 0)] * (c.ndim - 1) + [(0, pad)])
    c = c.reshape(c.shape[:-1] + (w, per_byte)).astype(jnp.uint32)
    # explicit rank match (sanitizer lane runs rank_promotion='raise')
    shifts = (jnp.arange(per_byte, dtype=jnp.uint32) * bits).reshape(
        (1,) * (c.ndim - 1) + (per_byte,))
    word = jnp.sum(c << shifts, axis=-1)
    return word.astype(jnp.uint8)


def unpack_codes(packed: jax.Array, bits: int, num_codes: int) -> jax.Array:
    """packed (..., W) uint8 -> codes (..., num_codes) uint8.

    Inverse of :func:`pack_codes`; trailing pad codes are dropped.
    """
    per_byte = 8 // bits
    w = packed.shape[-1]
    if w != packed_width(num_codes, bits):
        raise ValueError(
            f"packed width {w} does not hold {num_codes} codes of "
            f"{bits} bits (want {packed_width(num_codes, bits)})")
    shifts = (jnp.arange(per_byte, dtype=jnp.int32) * bits).reshape(
        (1,) * packed.ndim + (per_byte,))
    codes = (packed.astype(jnp.int32)[..., None] >> shifts) & (2 ** bits - 1)
    codes = codes.reshape(packed.shape[:-1] + (w * per_byte,))
    return codes[..., :num_codes].astype(jnp.uint8)
