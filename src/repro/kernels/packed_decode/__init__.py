from repro.kernels.packed_decode.ops import (PACK_BITS, decode, pack_codes,
                                             packed_decode,
                                             packed_decode_ref,
                                             packed_width, unpack_codes)

__all__ = ["PACK_BITS", "decode", "pack_codes", "packed_decode",
           "packed_decode_ref", "packed_width", "unpack_codes"]
