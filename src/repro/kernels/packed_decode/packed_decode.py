"""Pallas TPU kernel: fused unpack-and-decode for bit-packed codes.

The ``mpe`` scheme (DESIGN.md §13) stores sub-byte quantization codes
bit-packed — (B, W) uint8 words holding ``8 // bits`` codes per byte —
so a bits=2 tail tier reads 4x fewer code bytes from HBM than the
uint8 layout.  Keeping that byte win requires the unpack to happen
*inside* the kernel: an O(n) host/HBM unpack copy before the decode
would read and write the unpacked table and forfeit the reduction.

Per grid step the kernel streams a (Bblk, Wblk) packed block into
VMEM, widens to int32, shifts/masks the byte lanes apart
(little-endian within the byte, matching ``pack.pack_codes``), and
feeds the recovered (Bblk, dblk) codes straight into the same one-hot
MXU matmul as ``mgqe_decode`` — shift/mask are VPU-friendly lane-wise
int ops, so the unpack rides along at register bandwidth.

Block layout: grid (B/block_b, D/block_d) with ``block_d`` in SUBSPACE
units.  A subspace tile maps to a byte tile only when it covers whole
bytes, so ``block_d`` must be a multiple of ``8 // bits`` and divide
D; anything else falls back to full width (mirrors ``rq_decode_stages``'s
block_d fallback).  At full width the packed block may carry up to
``8 // bits - 1`` pad codes in its last byte — the in-kernel slice to
the centroid count drops them.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.packed_decode.pack import PACK_BITS, packed_width


def _packed_decode_kernel(packed_ref, cent_ref, out_ref, *, bits):
    packed = packed_ref[...].astype(jnp.int32)        # (Bblk, Wblk)
    cent = cent_ref[...]                              # (dblk, K, S)
    dblk, k, _ = cent.shape
    per_byte = 8 // bits
    shifts = jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, per_byte), 2) * bits
    codes = (packed[:, :, None] >> shifts) & (2 ** bits - 1)
    codes = codes.reshape(codes.shape[0], -1)[:, :dblk]  # (Bblk, dblk)
    onehot = (codes[:, :, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2)
              ).astype(cent.dtype)                    # (Bblk, dblk, K)
    dec = jnp.einsum("bdk,dks->bds", onehot, cent,
                     preferred_element_type=jnp.float32)
    out_ref[...] = dec.reshape(dec.shape[0], -1).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bits", "block_b", "block_d",
                                    "interpret"))
def packed_decode(packed: jax.Array, centroids: jax.Array, bits: int,
                  block_b: int = 256, block_d: Optional[int] = None,
                  interpret: bool = False) -> jax.Array:
    """packed (B, W) uint8; centroids (D, K, S); W = ceil(D/(8//bits))
    -> (B, D*S) float32, decoding without ever materializing unpacked
    codes outside VMEM.

    block_b: rows per grid step (batch padded to it).  block_d: subspaces
    per grid step — must divide D and be a multiple of ``8 // bits``
    (byte-aligned tiles), else full width.  VMEM working set per step is
    the ``mgqe_decode`` one with codes at 1/(8//bits) the bytes:
    Bblk*Wblk packed + dblk*K*S*4 centroids + Bblk*dblk*K*4 onehot.
    """
    if bits not in PACK_BITS:
        raise ValueError(f"bits must be one of {PACK_BITS}, got {bits}")
    b, w = packed.shape
    n_sub, k, s = centroids.shape
    per_byte = 8 // bits
    if w != packed_width(n_sub, bits):
        raise ValueError(
            f"packed width {w} does not hold {n_sub} codes of {bits} "
            f"bits (want {packed_width(n_sub, bits)})")
    if block_d is None or n_sub % block_d or block_d % per_byte:
        block_d = n_sub
    wblk = w if block_d == n_sub else block_d // per_byte
    pad = (-b) % block_b
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_packed_decode_kernel, bits=bits),
        grid=((b + pad) // block_b, n_sub // block_d),
        in_specs=[
            pl.BlockSpec((block_b, wblk), lambda i, j: (i, j)),
            pl.BlockSpec((block_d, k, s), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_d * s),
                               lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b + pad, n_sub * s),
                                       centroids.dtype),
        interpret=interpret,
    )(packed, centroids)
    return out[:b]
