"""Public wrapper for the fused unpack-and-decode kernel.

``decode(packed, centroids, bits)`` routes through the kernel backend
dispatch layer like every other hot-path op; the packed (B, W) uint8
words are what cross the dispatch boundary — unpacking happens inside
each backend's kernel body (per VMEM block on pallas/interpret, fused
into the batch gather on xla), never as a standalone O(n) copy.  The
spy test in tests/test_packed_decode.py holds the call sites to this.

``bits`` is a positional arg, so it participates in the autotune shape
bucket — bits=2 and bits=8 tune independently (their byte/flop ratios
differ by 4x).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.dispatch import Tunable
from repro.kernels.packed_decode.pack import (PACK_BITS, pack_codes,
                                              packed_width, unpack_codes)
from repro.kernels.packed_decode.packed_decode import packed_decode
from repro.kernels.packed_decode.ref import packed_decode_ref

dispatch.register_op(
    "packed_decode",
    pallas=lambda packed, cent, bits, block_b=256, block_d=None:
        packed_decode(packed, cent, bits, block_b=block_b,
                      block_d=block_d),
    xla=lambda packed, cent, bits, block_b=256, block_d=None:
        packed_decode_ref(packed, cent, bits),
    interpret=lambda packed, cent, bits, block_b=256, block_d=None:
        packed_decode(packed, cent, bits, block_b=block_b,
                      block_d=block_d, interpret=True),
    tunables={"block_b": Tunable(256, (64, 128, 256, 512)),
              "block_d": Tunable(None, (None, 2, 4))},
)


def decode(packed: jax.Array, centroids: jax.Array, bits: int,
           block_b: Optional[int] = None,
           block_d: Optional[int] = None,
           backend: Optional[str] = None) -> jax.Array:
    """packed (B, W) uint8 -> embeddings (B, D*S) via the dispatched
    fused unpack-and-decode kernel."""
    return dispatch.dispatch("packed_decode", packed, centroids, bits,
                             block_b=block_b, block_d=block_d,
                             backend=backend)


__all__ = ["PACK_BITS", "decode", "pack_codes", "packed_decode",
           "packed_decode_ref", "packed_width", "unpack_codes"]
