"""Pallas TPU kernel: blocked causal/windowed flash attention (forward).

Standard flash-attention-2 structure adapted to the TPU grid model:

  grid = (B*Hkv*G, Sq/bq, Skv/bk)   -- kv blocks innermost so the
                                       (m, l, acc) running state lives
                                       in VMEM scratch across the kv loop
  q block   (bq, hd)   VMEM
  k,v block (bk, hd)   VMEM
  out block (bq, hd)   written once, on the last kv step

Causality + sliding window are positional: query block i covers
positions [i*bq, (i+1)*bq); key block j covers [j*bk, (j+1)*bk).
Blocks fully outside the visibility band are *skipped at trace time is
not possible (grid is static)* — instead masked fully; XLA's grid
skipping on TPU would use mask_info, kept simple here since the band
structure already bounds work for the windowed layers we lower.

MXU alignment: bq, bk multiples of 128; hd padded to 128 by the caller
(ops.py) when needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, window: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                        # (bq, hd)
    k = k_ref[0]                                        # (bk, hd)
    v = v_ref[0]
    hd = q.shape[-1]
    scale = hd ** -0.5
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    delta = qpos - kpos
    mask = (delta >= 0) & (delta < window)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * alpha
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == n_kv_blocks - 1)
    def _flush():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    window: int = 1 << 30, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q (B, Sq, H, hd); k, v (B, Skv, Hkv, hd) -> (B, Sq, H, hd).

    Causal with sliding window; positions are array indices (prefill /
    train layout).  H must be a multiple of Hkv (GQA).
    """
    b, sq, h, hd = q.shape
    _, skv, hkv, _ = k.shape
    if h % hkv:
        raise ValueError(f"query heads {h} not a multiple of kv heads {hkv}")
    g = h // hkv
    if sq % block_q:
        raise ValueError(f"seq_q {sq} not a multiple of block_q {block_q}")
    if skv % block_k:
        raise ValueError(f"seq_kv {skv} not a multiple of block_k {block_k}")

    # (B, S, H, hd) -> (B*H, S, hd) with kv head g-fold repeat folded in
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1
                    ).reshape(b * h, skv, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1
                    ).reshape(b * h, skv, hd)

    n_kv_blocks = skv // block_k
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k,
        window=window, n_kv_blocks=n_kv_blocks)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, sq // block_q, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
