from repro.kernels.flash_attention.ops import (attend, flash_attention,
                                               flash_attention_ref)

__all__ = ["attend", "flash_attention", "flash_attention_ref"]
