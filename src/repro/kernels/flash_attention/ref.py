"""Pure-jnp oracle for blocked causal/windowed GQA attention.

Mirrors repro.nn.attention.dense_attention with positional masking:
key visible iff 0 <= qpos - kpos < window.
"""
from __future__ import annotations

import jax.numpy as jnp

_NEG_INF = -1e30


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        window: int = 1 << 30) -> jnp.ndarray:
    """q (B, Sq, H, hd); k, v (B, Skv, Hkv, hd); H = Hkv * g.

    Causal: query i attends keys j with 0 <= i - j < window (positions
    are the indices — the oracle assumes q and k start at position 0).
    """
    b, sq, h, hd = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    delta = qpos - kpos
    mask = (delta >= 0) & (delta < window)
    scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(q.shape)
