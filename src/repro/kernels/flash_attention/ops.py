"""Public wrapper: flash attention with a recompute-based backward.

Forward routes through the kernel backend dispatch layer (Pallas on
TPU, jnp reference under XLA elsewhere, Pallas interpret on request);
the VJP recomputes attention with the pure-jnp oracle (flash backward
on TPU would mirror the forward's block structure — the recompute
fallback keeps training numerically exact at ~2x forward cost, the
standard remat trade).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import dispatch
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref

dispatch.register_op(
    "flash_attention",
    pallas=lambda q, k, v, window=1 << 30: flash_attention(
        q, k, v, window=window),
    xla=lambda q, k, v, window=1 << 30: flash_attention_ref(
        q, k, v, window=window),
    interpret=lambda q, k, v, window=1 << 30: flash_attention(
        q, k, v, window=window, interpret=True),
)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attend(q: jax.Array, k: jax.Array, v: jax.Array,
           window: int = 1 << 30) -> jax.Array:
    """Blocked causal/windowed GQA attention (train/prefill layout)."""
    return dispatch.dispatch("flash_attention", q, k, v, window=window)


def _fwd(q, k, v, window):
    return attend(q, k, v, window), (q, k, v)


def _bwd(window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: flash_attention_ref(q, k, v, window),
                     q, k, v)
    return vjp(g)


attend.defvjp(_fwd, _bwd)

__all__ = ["attend", "flash_attention", "flash_attention_ref"]
