"""Public wrapper: flash attention with a recompute-based backward.

Forward runs the Pallas kernel; the VJP recomputes attention with the
pure-jnp oracle (flash backward on TPU would mirror the forward's
block structure — the recompute fallback keeps training numerically
exact at ~2x forward cost, the standard remat trade).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attend(q: jax.Array, k: jax.Array, v: jax.Array,
           window: int = 1 << 30) -> jax.Array:
    """Blocked causal/windowed GQA attention (train/prefill layout)."""
    return flash_attention(q, k, v, window=window, interpret=not _on_tpu())


def _fwd(q, k, v, window):
    return attend(q, k, v, window), (q, k, v)


def _bwd(window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: flash_attention_ref(q, k, v, window),
                     q, k, v)
    return vjp(g)


attend.defvjp(_fwd, _bwd)

__all__ = ["attend", "flash_attention", "flash_attention_ref"]
