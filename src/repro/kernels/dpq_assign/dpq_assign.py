"""Pallas TPU kernel: nearest-centroid search (the DPQ/MGQE encoder).

The compute hot-spot of DPQ *training* and of serving-code export: for
every row, squared-L2 argmin against K centroids in each of D
subspaces.  In matmul form (||e-c||^2 = ||e||^2 - 2e.c + ||c||^2, the
||e||^2 term constant w.r.t. the argmin) the distance tensor is one
MXU batched-matmul:  -2 * e_sub @ centroids^T + ||c||^2.

MGQE's tier rule rides along as a per-item mask: slots k >= k_limit[b]
get +inf before the argmin — the masked single-pass lookup that
replaces the paper's dynamic group-split (DESIGN.md §3).

Block layout: grid over (B blocks, D).  Per step: e block (Bblk, 1, S),
centroid block (1, K, S) — both VMEM; distances (Bblk, K) never leave
VMEM; only the int32 codes (Bblk, 1) are written back.  This is the
fusion win: XLA's unfused path would round-trip the (B, D, K) distance
tensor through HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(e_ref, cent_ref, klim_ref, codes_ref):
    e = e_ref[...][:, 0, :]                            # (Bblk, S)
    cent = cent_ref[...][0]                            # (K, S)
    k = cent.shape[0]
    dots = jnp.dot(e, cent.T, preferred_element_type=jnp.float32)
    c_sq = jnp.sum(jnp.square(cent.astype(jnp.float32)), axis=-1)
    dist = c_sq[None, :] - 2.0 * dots                  # (Bblk, K)
    klim = klim_ref[...]                               # (Bblk,)
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    dist = jnp.where(slot >= klim[:, None], jnp.inf, dist)
    codes_ref[...] = jnp.argmin(dist, axis=-1
                                ).astype(jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def dpq_assign(e_sub: jax.Array, centroids: jax.Array,
               k_limit: Optional[jax.Array] = None,
               block_b: int = 512, interpret: bool = False) -> jax.Array:
    """e_sub (B, D, S); centroids (D, K, S); k_limit (B,) or None
    -> codes (B, D) int32."""
    b, d, s = e_sub.shape
    n_sub, k, s2 = centroids.shape
    if (d, s) != (n_sub, s2):
        raise ValueError(f"e_sub subspaces {(d, s)} do not match "
                         f"centroids {(n_sub, s2)}")
    if k_limit is None:
        k_limit = jnp.full((b,), k, jnp.int32)
    k_limit = k_limit.astype(jnp.int32)
    pad = (-b) % block_b
    if pad:
        e_sub = jnp.pad(e_sub, ((0, pad), (0, 0), (0, 0)))
        k_limit = jnp.pad(k_limit, (0, pad), constant_values=k)
    codes = pl.pallas_call(
        _assign_kernel,
        grid=((b + pad) // block_b, d),
        in_specs=[
            pl.BlockSpec((block_b, 1, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, k, s), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b + pad, d), jnp.int32),
        interpret=interpret,
    )(e_sub, centroids, k_limit)
    return codes[:b]
