"""Public wrapper for the DPQ nearest-centroid assignment kernel."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.dispatch import Tunable
from repro.kernels.dpq_assign.dpq_assign import dpq_assign
from repro.kernels.dpq_assign.ref import (dpq_assign_blocked_ref,
                                          dpq_assign_ref)

# The xla entry honours block_b too (scan-blocked so the per-block
# distance slab stays cache-resident — see ref.py); 64/128 win on CPU,
# the larger blocks on the MXU-fed paths.
dispatch.register_op(
    "dpq_assign",
    pallas=lambda e_sub, cent, k_limit=None, block_b=512: dpq_assign(
        e_sub, cent, k_limit, block_b=block_b),
    xla=lambda e_sub, cent, k_limit=None, block_b=512:
        dpq_assign_blocked_ref(e_sub, cent, k_limit, block_b=block_b),
    interpret=lambda e_sub, cent, k_limit=None, block_b=512: dpq_assign(
        e_sub, cent, k_limit, block_b=block_b, interpret=True),
    tunables={"block_b": Tunable(512, (64, 128, 256, 512, 1024))},
)


def assign(e_sub: jax.Array, centroids: jax.Array,
           k_limit: Optional[jax.Array] = None,
           block_b: Optional[int] = None,
           backend: Optional[str] = None) -> jax.Array:
    """Nearest-centroid codes (B, D) for subvectors (B, D, S)."""
    return dispatch.dispatch("dpq_assign", e_sub, centroids, k_limit,
                             block_b=block_b, backend=backend)


__all__ = ["assign", "dpq_assign", "dpq_assign_blocked_ref",
           "dpq_assign_ref"]
