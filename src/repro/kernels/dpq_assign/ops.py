"""Public wrapper for the DPQ nearest-centroid assignment kernel."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.dpq_assign.dpq_assign import dpq_assign
from repro.kernels.dpq_assign.ref import dpq_assign_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def assign(e_sub: jax.Array, centroids: jax.Array,
           k_limit: Optional[jax.Array] = None,
           block_b: int = 512) -> jax.Array:
    """Nearest-centroid codes (B, D) for subvectors (B, D, S)."""
    return dpq_assign(e_sub, centroids, k_limit, block_b=block_b,
                      interpret=not _on_tpu())


__all__ = ["assign", "dpq_assign", "dpq_assign_ref"]
