"""Pure-jnp oracle for nearest-centroid code assignment (DPQ encode).

Mirrors repro.core.dpq.assign_codes: squared-L2 argmin per subspace
with an optional per-item centroid budget ``k_limit`` (the MGQE
shared-variable-K mask).

``dpq_assign_blocked_ref`` is the XLA *serving* form: the plain
reference materializes the whole (B, D, K) f32 distance tensor —
67 MB at B=8192, D=8, K=256, far past LLC — so blocking over B with a
``lax.scan`` keeps each (block_b, D, K) slab cache-resident (~4x
measured on CPU at block_b=64-128).  Rows are independent, so the
blocked form is bit-identical to the flat one; ``block_b`` is the
op's autotuned knob on every backend.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dpq_assign_ref(e_sub: jnp.ndarray, centroids: jnp.ndarray,
                   k_limit: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """e_sub (B, D, S); centroids (D, K, S); k_limit (B,) -> codes (B, D)."""
    dots = jnp.einsum("bds,dks->bdk", e_sub, centroids)
    c_sq = jnp.sum(jnp.square(centroids), axis=-1)        # (D, K)
    dist = c_sq[None] - 2.0 * dots                        # (B, D, K)
    if k_limit is not None:
        k = dist.shape[-1]
        slot = jnp.arange(k, dtype=jnp.int32)
        mask = slot[None, None, :] >= k_limit[:, None, None]
        dist = jnp.where(mask, jnp.inf, dist)
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)


def dpq_assign_blocked_ref(e_sub: jnp.ndarray, centroids: jnp.ndarray,
                           k_limit: Optional[jnp.ndarray] = None,
                           block_b: Optional[int] = 512) -> jnp.ndarray:
    """Bit-identical to :func:`dpq_assign_ref`, scanned over row blocks
    of ``block_b`` so the per-block distance slab stays in cache; the
    ragged remainder runs flat and is concatenated."""
    b = e_sub.shape[0]
    if not block_b or block_b >= b:
        return dpq_assign_ref(e_sub, centroids, k_limit)
    nb, rem = divmod(b, block_b)

    def blocks(x):
        return x[:nb * block_b].reshape((nb, block_b) + x.shape[1:])

    if k_limit is None:
        _, main = jax.lax.scan(
            lambda c, e: (c, dpq_assign_ref(e, centroids)),
            None, blocks(e_sub))
    else:
        _, main = jax.lax.scan(
            lambda c, xs: (c, dpq_assign_ref(xs[0], centroids, xs[1])),
            None, (blocks(e_sub), blocks(k_limit)))
    out = main.reshape((nb * block_b,) + main.shape[2:])
    if rem:
        tail = dpq_assign_ref(
            e_sub[nb * block_b:], centroids,
            None if k_limit is None else k_limit[nb * block_b:])
        out = jnp.concatenate([out, tail], axis=0)
    return out
