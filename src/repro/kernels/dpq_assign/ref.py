"""Pure-jnp oracle for nearest-centroid code assignment (DPQ encode).

Mirrors repro.core.dpq.assign_codes: squared-L2 argmin per subspace
with an optional per-item centroid budget ``k_limit`` (the MGQE
shared-variable-K mask).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def dpq_assign_ref(e_sub: jnp.ndarray, centroids: jnp.ndarray,
                   k_limit: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """e_sub (B, D, S); centroids (D, K, S); k_limit (B,) -> codes (B, D)."""
    dots = jnp.einsum("bds,dks->bdk", e_sub, centroids)
    c_sq = jnp.sum(jnp.square(centroids), axis=-1)        # (D, K)
    dist = c_sq[None] - 2.0 * dots                        # (B, D, K)
    if k_limit is not None:
        k = dist.shape[-1]
        slot = jnp.arange(k, dtype=jnp.int32)
        mask = slot[None, None, :] >= k_limit[:, None, None]
        dist = jnp.where(mask, jnp.inf, dist)
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)
