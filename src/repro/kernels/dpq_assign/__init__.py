from repro.kernels.dpq_assign.ops import (assign, dpq_assign,
                                          dpq_assign_blocked_ref,
                                          dpq_assign_ref)

__all__ = ["assign", "dpq_assign", "dpq_assign_blocked_ref",
           "dpq_assign_ref"]
