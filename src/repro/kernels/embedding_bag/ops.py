"""Public wrapper for the fused EmbeddingBag kernel (backend-dispatched)."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.embedding_bag.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref

dispatch.register_op(
    "embedding_bag",
    pallas=lambda table, ids, seg, num_bags, weights=None: embedding_bag(
        table, ids, seg, num_bags, weights),
    xla=embedding_bag_ref,
    interpret=lambda table, ids, seg, num_bags, weights=None: embedding_bag(
        table, ids, seg, num_bags, weights, interpret=True),
    # grid is (nnz,) — one id per step, no free block geometry to tune
    tunables={},
)


def bag(table: jax.Array, ids: jax.Array, segment_ids: jax.Array,
        num_bags: int, weights: Optional[jax.Array] = None,
        backend: Optional[str] = None) -> jax.Array:
    """Fused CSR embedding-bag pooling (sum mode), backend-dispatched."""
    return dispatch.dispatch("embedding_bag", table, ids, segment_ids,
                             num_bags, weights, backend=backend)


__all__ = ["bag", "embedding_bag", "embedding_bag_ref"]
