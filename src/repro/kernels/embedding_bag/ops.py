"""Public wrapper for the fused EmbeddingBag kernel."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.embedding_bag.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def bag(table: jax.Array, ids: jax.Array, segment_ids: jax.Array,
        num_bags: int, weights: Optional[jax.Array] = None) -> jax.Array:
    """Fused CSR embedding-bag pooling (sum mode)."""
    return embedding_bag(table, ids, segment_ids, num_bags, weights,
                         interpret=not _on_tpu())


__all__ = ["bag", "embedding_bag", "embedding_bag_ref"]
