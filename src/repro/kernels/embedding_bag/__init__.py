from repro.kernels.embedding_bag.ops import (bag, embedding_bag,
                                             embedding_bag_ref)

__all__ = ["bag", "embedding_bag", "embedding_bag_ref"]
