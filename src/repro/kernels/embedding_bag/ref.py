"""Pure-jnp oracle for the fused EmbeddingBag (gather + segment-sum).

CSR-style ragged multi-hot pooling: ids (nnz,) index rows of the table,
segment_ids (nnz,) assign each id to a bag; segment_ids must be sorted
ascending (standard CSR layout).  Optional per-id weights.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def embedding_bag_ref(table: jnp.ndarray, ids: jnp.ndarray,
                      segment_ids: jnp.ndarray, num_bags: int,
                      weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """table (V, d); ids/segment_ids (nnz,) -> pooled (num_bags, d)."""
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
