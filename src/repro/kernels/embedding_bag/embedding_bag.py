"""Pallas TPU kernel: fused EmbeddingBag (ragged gather + segment-sum).

JAX has no native EmbeddingBag; the jnp path (take -> segment_sum)
round-trips the gathered (nnz, d) rows through HBM.  This kernel fuses
the reduction so each table row is read once and each bag row written
once — the FBGEMM-TBE pattern adapted to TPU.

TPU adaptation — gather/scatter via *scalar-prefetched index maps*
(PrefetchScalarGridSpec): TPUs can't do per-lane random access into an
HBM table from inside a kernel body, but Pallas lets the BlockSpec
``index_map`` read prefetched scalar arrays.  So:

  * grid = (nnz,): one id per step
  * the INPUT block of the table is row ``ids[i]`` — the gather happens
    in the automatic block DMA, not in the body
  * the OUTPUT block is bag row ``segment_ids[i]`` — consecutive steps
    with the same segment revisit the same VMEM block, so the body can
    accumulate in place.  Pallas keeps a revisited output block resident
    (it only flushes when the index changes), which is exactly the CSR
    contract: segment_ids sorted ascending.
  * at each segment boundary (segment_ids[i] != segment_ids[i-1]) the
    body resets the accumulator with @pl.when.

Empty bags are zero-filled by a pre-pass (out init to zeros via
first-visit reset + a final jnp scatter for untouched bags is avoided
by initializing with input_output_aliasing on a zeros buffer).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(ids_ref, seg_ref, w_ref, row_ref, out_ref):
    i = pl.program_id(0)
    seg = seg_ref[i]
    prev_seg = seg_ref[jnp.maximum(i, 1) - 1]
    is_first = (i == 0) | (seg != prev_seg)

    @pl.when(is_first)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += row_ref[...] * w_ref[i].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_bags", "interpret"))
def embedding_bag(table: jax.Array, ids: jax.Array, segment_ids: jax.Array,
                  num_bags: int, weights: Optional[jax.Array] = None,
                  interpret: bool = False) -> jax.Array:
    """table (V, d); ids (nnz,); segment_ids (nnz,) sorted ascending ->
    pooled (num_bags, d).

    Bags not present in segment_ids come back zero (the scatter-style
    jnp epilogue below merges kernel output with a zeros base).
    """
    nnz = ids.shape[0]
    v, d = table.shape
    if weights is None:
        weights = jnp.ones((nnz,), table.dtype)
    ids = ids.astype(jnp.int32)
    segment_ids = segment_ids.astype(jnp.int32)

    out = pl.pallas_call(
        _bag_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,          # ids, segment_ids, weights
            grid=(nnz,),
            in_specs=[
                # gather: table row ids[i] is THE block for step i
                pl.BlockSpec((1, d), lambda i, ids, seg, w: (ids[i], 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, ids, seg, w: (seg[i], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((num_bags, d), table.dtype),
        interpret=interpret,
    )(ids, segment_ids, weights, table)

    # zero-fill bags that never appear (kernel leaves them undefined)
    present = jnp.zeros((num_bags,), jnp.bool_).at[segment_ids].set(True)
    return jnp.where(present[:, None], out, 0)
