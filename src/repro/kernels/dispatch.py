"""Kernel backend dispatch — one switch for every hand-written kernel.

Every hot-path op in ``repro.kernels`` ships (at least) two
implementations:

  ``pallas``     the hand-written Pallas TPU kernel (``<name>.py``)
  ``xla``        the pure-jnp oracle (``ref.py``) — XLA fuses it well
                 enough to be the correct CPU/GPU fallback
  ``interpret``  the Pallas kernel run in interpret mode — executes the
                 kernel *body* on CPU, so CI exercises the exact code
                 that runs on TPU (DESIGN.md §5)

Call sites never branch on hardware.  They call
:func:`dispatch`/``op(..., backend=None)`` and the backend is resolved
in precedence order:

  1. explicit ``backend=`` argument (e.g. from a config field such as
     ``EmbeddingConfig.kernel_backend``); ``"auto"`` and ``None`` both
     mean "no preference"
  2. the ``REPRO_KERNEL_BACKEND`` environment variable — the operator
     override for everything left on auto (CI exports
     ``REPRO_KERNEL_BACKEND=interpret`` and every default-configured op
     follows; a call site that pins a concrete backend keeps it)
  3. the process-wide default set via :func:`set_default_backend` /
     :func:`use_backend`
  4. ``auto``: ``pallas`` when a TPU is attached, else ``xla``

``auto`` is also re-resolved *per choice*: asking for ``pallas`` with
no TPU present silently falls back to ``xla`` (compiling a real Mosaic
kernel without TPU hardware would just crash), while ``interpret``
always honours the request — that is the whole point of interpret mode.

Registration is done by each kernel package's ``ops.py`` at import
time; :func:`dispatch` lazily imports ``repro.kernels`` so the registry
is populated no matter which module is imported first.
"""
from __future__ import annotations

import contextlib
import os
from typing import Callable, Dict, Optional

import jax

BACKENDS = ("auto", "pallas", "xla", "interpret")

ENV_VAR = "REPRO_KERNEL_BACKEND"

_REGISTRY: Dict[str, Dict[str, Callable]] = {}

_default_backend: str = "auto"


# ----------------------------------------------------------------------
# backend selection
# ----------------------------------------------------------------------

def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def set_default_backend(backend: str) -> None:
    """Set the process-wide default backend (lowest-precedence knob)."""
    global _default_backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    _default_backend = backend


def get_default_backend() -> str:
    return _default_backend


@contextlib.contextmanager
def use_backend(backend: str):
    """Temporarily override the default backend (tests, benchmarks)."""
    prev = _default_backend
    set_default_backend(backend)
    try:
        yield
    finally:
        set_default_backend(prev)


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve a backend request to a concrete one of pallas|xla|interpret.

    Precedence: explicit arg > $REPRO_KERNEL_BACKEND > process default
    > auto.  ``auto`` (and an unfulfillable ``pallas`` request) resolve
    to ``pallas`` on TPU and ``xla`` elsewhere.
    """
    if backend == "auto":
        backend = None          # "auto" carries no preference
    choice = backend or os.environ.get(ENV_VAR) or _default_backend
    if choice not in BACKENDS:
        raise ValueError(f"unknown kernel backend {choice!r}; "
                         f"expected one of {BACKENDS}")
    if choice == "auto":
        return "pallas" if _on_tpu() else "xla"
    if choice == "pallas" and not _on_tpu():
        # a compiled Mosaic kernel needs real TPU hardware; interpret
        # mode must be asked for explicitly (it is orders of magnitude
        # slower than the XLA reference path).
        return "xla"
    return choice


# ----------------------------------------------------------------------
# op registry
# ----------------------------------------------------------------------

def register_op(name: str, *, pallas: Callable, xla: Callable,
                interpret: Optional[Callable] = None) -> None:
    """Register one op's implementations.

    ``interpret`` defaults to the pallas entry point — kernel wrappers
    in this repo accept ``interpret=...`` themselves, so most register
    an explicit closure instead.
    """
    _REGISTRY[name] = {
        "pallas": pallas,
        "xla": xla,
        "interpret": interpret if interpret is not None else pallas,
    }


def registered_ops() -> Dict[str, Dict[str, Callable]]:
    _ensure_registered()
    return dict(_REGISTRY)


def _ensure_registered() -> None:
    if not _REGISTRY:
        # ops.py modules register themselves at import time
        import repro.kernels  # noqa: F401


def get_impl(name: str, backend: Optional[str] = None) -> Callable:
    """Concrete callable for ``name`` under the resolved backend."""
    _ensure_registered()
    if name not in _REGISTRY:
        raise KeyError(f"kernel op {name!r} not registered; known: "
                       f"{sorted(_REGISTRY)}")
    return _REGISTRY[name][resolve_backend(backend)]


def dispatch(name: str, *args, backend: Optional[str] = None, **kwargs):
    """Run op ``name`` on the resolved backend."""
    return get_impl(name, backend)(*args, **kwargs)


__all__ = ["BACKENDS", "ENV_VAR", "dispatch", "get_default_backend",
           "get_impl", "register_op", "registered_ops", "resolve_backend",
           "set_default_backend", "use_backend"]
