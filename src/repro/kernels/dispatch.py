"""Kernel backend dispatch — one switch for every hand-written kernel.

Every hot-path op in ``repro.kernels`` ships (at least) two
implementations:

  ``pallas``     the hand-written Pallas TPU kernel (``<name>.py``)
  ``xla``        the pure-jnp oracle (``ref.py``) — XLA fuses it well
                 enough to be the correct CPU/GPU fallback
  ``interpret``  the Pallas kernel run in interpret mode — executes the
                 kernel *body* on CPU, so CI exercises the exact code
                 that runs on TPU (DESIGN.md §5)

Call sites never branch on hardware.  They call
:func:`dispatch`/``op(..., backend=None)`` and the backend is resolved
in precedence order:

  1. explicit ``backend=`` argument (e.g. from a config field such as
     ``EmbeddingConfig.kernel_backend``); ``"auto"`` and ``None`` both
     mean "no preference"
  2. the ``REPRO_KERNEL_BACKEND`` environment variable — the operator
     override for everything left on auto (CI exports
     ``REPRO_KERNEL_BACKEND=interpret`` and every default-configured op
     follows; a call site that pins a concrete backend keeps it)
  3. the process-wide default set via :func:`set_default_backend` /
     :func:`use_backend`
  4. ``auto``: ``pallas`` when a TPU is attached, else ``xla``

``auto`` is also re-resolved *per choice*: asking for ``pallas`` with
no TPU present silently falls back to ``xla`` (compiling a real Mosaic
kernel without TPU hardware would just crash), while ``interpret``
always honours the request — that is the whole point of interpret mode.

Registration is done by each kernel package's ``ops.py`` at import
time; :func:`dispatch` lazily imports ``repro.kernels`` so the registry
is populated no matter which module is imported first.

Block-size autotune (DESIGN.md §11)
-----------------------------------

Every kernel in this repo exposes block-geometry knobs (``block_b``,
``block_d``, ``block_n``) whose defaults were historically hand-picked
per op and never revisited per backend or shape.  ``register_op`` now
accepts a declared *tunable-params spec* — kwarg name ->
:class:`Tunable` (default + candidate values) — and two layers use it
with no per-op glue:

  * :func:`tune` sweeps the candidate grid over representative example
    args, timing each combination on the resolved backend, and caches
    the winner keyed by ``(op, backend, shape-bucket)`` where the
    bucket rounds every array dim up to the next power of two (so
    nearby shapes share a tuned config);
  * :func:`dispatch` consults the cache: any declared tunable kwarg the
    caller leaves unset (or passes as ``None``) resolves to the tuned
    value for the call's shape bucket, falling back to the declared
    default.  An explicitly passed concrete value always pins.

The in-process cache optionally persists to a JSON file named by the
``REPRO_KERNEL_TUNE_CACHE`` environment variable: :func:`tune` saves
after each sweep and the first cache lookup loads it, so a CI-produced
cache file can seed a serving process.  A missing or unreadable file
degrades to the declared defaults with a warning — tuning is a
performance layer, never a correctness dependency (tuned and default
block sizes are bit-identical by the kernels' contract; the property
suite in tests/test_autotune.py holds them to it).
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
import os
import time
import warnings
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax

BACKENDS = ("auto", "pallas", "xla", "interpret")

ENV_VAR = "REPRO_KERNEL_BACKEND"
TUNE_CACHE_ENV = "REPRO_KERNEL_TUNE_CACHE"

_REGISTRY: Dict[str, Dict[str, Callable]] = {}
_TUNABLES: Dict[str, Dict[str, "Tunable"]] = {}

# (op, backend, shape-bucket) -> {param: value}
_TUNED: Dict[Tuple[str, str, str], Dict[str, Any]] = {}
_tune_file_loaded: Optional[str] = None

_default_backend: str = "auto"


# ----------------------------------------------------------------------
# backend selection
# ----------------------------------------------------------------------

def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def set_default_backend(backend: str) -> None:
    """Set the process-wide default backend (lowest-precedence knob)."""
    global _default_backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    _default_backend = backend


def get_default_backend() -> str:
    return _default_backend


@contextlib.contextmanager
def use_backend(backend: str):
    """Temporarily override the default backend (tests, benchmarks)."""
    prev = _default_backend
    set_default_backend(backend)
    try:
        yield
    finally:
        set_default_backend(prev)


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve a backend request to a concrete one of pallas|xla|interpret.

    Precedence: explicit arg > $REPRO_KERNEL_BACKEND > process default
    > auto.  ``auto`` (and an unfulfillable ``pallas`` request) resolve
    to ``pallas`` on TPU and ``xla`` elsewhere.
    """
    if backend == "auto":
        backend = None          # "auto" carries no preference
    choice = backend or os.environ.get(ENV_VAR) or _default_backend
    if choice not in BACKENDS:
        raise ValueError(f"unknown kernel backend {choice!r}; "
                         f"expected one of {BACKENDS}")
    if choice == "auto":
        return "pallas" if _on_tpu() else "xla"
    if choice == "pallas" and not _on_tpu():
        # a compiled Mosaic kernel needs real TPU hardware; interpret
        # mode must be asked for explicitly (it is orders of magnitude
        # slower than the XLA reference path).
        return "xla"
    return choice


# ----------------------------------------------------------------------
# op registry
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Tunable:
    """One autotunable kwarg of a kernel op: its default plus the
    candidate values :func:`tune` sweeps.  Candidates must be
    value-interchangeable — the op's output is bit-identical across
    them (block geometry only changes the schedule)."""

    default: Any
    candidates: Tuple[Any, ...]


def register_op(name: str, *, pallas: Callable, xla: Callable,
                interpret: Optional[Callable] = None,
                tunables: Optional[Dict[str, Tunable]] = None) -> None:
    """Register one op's implementations.

    ``interpret`` defaults to the pallas entry point — kernel wrappers
    in this repo accept ``interpret=...`` themselves, so most register
    an explicit closure instead.  ``tunables`` declares the op's
    autotunable block-geometry kwargs (see the module docstring); an
    empty dict means "tunable-aware, nothing to sweep".
    """
    _REGISTRY[name] = {
        "pallas": pallas,
        "xla": xla,
        "interpret": interpret if interpret is not None else pallas,
    }
    _TUNABLES[name] = dict(tunables or {})


def registered_ops() -> Dict[str, Dict[str, Callable]]:
    _ensure_registered()
    return dict(_REGISTRY)


def _ensure_registered() -> None:
    if not _REGISTRY:
        # ops.py modules register themselves at import time
        import repro.kernels  # noqa: F401


def get_impl(name: str, backend: Optional[str] = None) -> Callable:
    """Concrete callable for ``name`` under the resolved backend."""
    _ensure_registered()
    if name not in _REGISTRY:
        raise KeyError(f"kernel op {name!r} not registered; known: "
                       f"{sorted(_REGISTRY)}")
    return _REGISTRY[name][resolve_backend(backend)]


def dispatch(name: str, *args, backend: Optional[str] = None, **kwargs):
    """Run op ``name`` on the resolved backend.

    Declared tunable kwargs the caller leaves unset (or passes as
    ``None``) resolve through the autotune cache for this call's shape
    bucket, falling back to the declared defaults — so tuned block
    sizes apply transparently while explicit values always pin.
    """
    impl = get_impl(name, backend)
    spec = _TUNABLES.get(name)
    if spec:
        tuned = None
        for param, t in spec.items():
            if kwargs.get(param) is None:
                if tuned is None:
                    tuned = tuned_params(name, args, backend=backend)
                kwargs[param] = tuned.get(param, t.default)
    return impl(*args, **kwargs)


# ----------------------------------------------------------------------
# block-size autotune
# ----------------------------------------------------------------------

def op_tunables(name: str) -> Dict[str, Tunable]:
    """Declared tunable spec for ``name`` (empty when none declared)."""
    _ensure_registered()
    return dict(_TUNABLES.get(name, {}))


def _bucket_dim(n: int) -> int:
    return 1 << (int(n) - 1).bit_length() if n > 0 else 0


def shape_bucket(*args) -> str:
    """Canonical shape-bucket key for a call's positional args.

    Array args contribute ``dtype[dims]`` with every dim rounded up to
    the next power of two (so e.g. B=4000 and B=4096 share one tuned
    config); scalars/None contribute their repr.  The bucket, together
    with op and backend, keys the tune cache.
    """
    parts = []
    for a in args:
        if hasattr(a, "shape") and hasattr(a, "dtype"):
            dims = "x".join(str(_bucket_dim(d)) for d in a.shape)
            parts.append(f"{jax.numpy.dtype(a.dtype).name}[{dims}]")
        else:
            parts.append(repr(a))
    return ",".join(parts)


def _tune_file() -> Optional[str]:
    return os.environ.get(TUNE_CACHE_ENV) or None


def _maybe_load_tune_file() -> None:
    """Merge the JSON cache file named by $REPRO_KERNEL_TUNE_CACHE into
    the in-process cache (once per distinct path; in-process entries
    win).  Any read/parse failure warns and falls back to defaults —
    a stale or corrupt cache must never take the process down."""
    global _tune_file_loaded
    path = _tune_file()
    if path is None or path == _tune_file_loaded:
        return
    _tune_file_loaded = path
    if not os.path.exists(path):
        return
    try:
        with open(path) as f:
            raw = json.load(f)
        entries = []
        for op, per_backend in raw.items():
            for be, per_bucket in per_backend.items():
                if be not in BACKENDS:
                    raise ValueError(f"unknown backend {be!r}")
                for bucket, params in per_bucket.items():
                    if not isinstance(params, dict):
                        raise ValueError(f"params for {op}/{be}/{bucket} "
                                         f"not a dict")
                    entries.append(((op, be, bucket), dict(params)))
    except (OSError, ValueError, AttributeError) as e:
        warnings.warn(f"ignoring invalid kernel tune cache {path!r}: {e}; "
                      f"falling back to default block sizes",
                      RuntimeWarning, stacklevel=2)
        return
    for key, params in entries:
        _TUNED.setdefault(key, params)


def save_tune_cache(path: Optional[str] = None) -> Optional[str]:
    """Write the in-process tune cache as JSON to ``path`` (default:
    $REPRO_KERNEL_TUNE_CACHE).  No-op returning None when neither
    names a file."""
    path = path or _tune_file()
    if path is None:
        return None
    out: Dict[str, Dict[str, Dict[str, Dict[str, Any]]]] = {}
    for (op, be, bucket), params in sorted(_TUNED.items()):
        out.setdefault(op, {}).setdefault(be, {})[bucket] = params
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    return path


def clear_tune_cache() -> None:
    """Drop every in-process tuned entry (tests; does not touch the
    JSON file) and forget which file was loaded."""
    global _tune_file_loaded
    _TUNED.clear()
    _tune_file_loaded = None


def tuned_params(name: str, args: Iterable, *,
                 backend: Optional[str] = None) -> Dict[str, Any]:
    """Cached tuned kwargs for op ``name`` called with ``args`` on the
    resolved backend — ``{}`` when the shape bucket was never tuned."""
    if not _TUNABLES.get(name):
        return {}
    _maybe_load_tune_file()
    key = (name, resolve_backend(backend), shape_bucket(*args))
    return dict(_TUNED.get(key, {}))


def _default_timer(thunk: Callable[[], Any], iters: int) -> float:
    out = thunk()                       # compile + warm outside the clock
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(thunk())
        best = min(best, time.perf_counter() - t0)
    return best


def tune(name: str, args_sets: Iterable, *, backend: Optional[str] = None,
         iters: int = 3, timer: Optional[Callable] = None,
         force: bool = False, save: bool = True) -> Dict[str, Dict[str, Any]]:
    """Sweep op ``name``'s declared tunable candidates over example
    calls and cache the fastest config per shape bucket.

    ``args_sets``: iterable of positional-arg tuples (concrete arrays —
    the sweep actually executes the op).  ``timer(thunk, iters)``
    overrides the wall-clock measurement (tests inject a deterministic
    one).  Already-tuned buckets are returned from cache unless
    ``force``.  The declared-default combo is always swept FIRST — even
    when it is absent from the candidate grid — and a challenger must
    strictly beat it, so ties and near-ties keep the default and tuning
    can never regress below the pinned behaviour (the
    ``tuned_vs_pinned_speedup < 1`` failure mode); a winner is
    deterministic for a fixed timer.  Returns ``{shape_bucket: winning
    params}`` and, when ``save`` and $REPRO_KERNEL_TUNE_CACHE is set,
    persists the cache file.
    """
    _ensure_registered()
    if name not in _REGISTRY:
        raise KeyError(f"kernel op {name!r} not registered; known: "
                       f"{sorted(_REGISTRY)}")
    spec = _TUNABLES.get(name, {})
    be = resolve_backend(backend)
    impl = _REGISTRY[name][be]
    timer = timer or _default_timer
    out: Dict[str, Dict[str, Any]] = {}
    params_names = list(spec)
    combos = [dict(zip(params_names, values))
              for values in itertools.product(
                  *(spec[p].candidates for p in params_names))] or [{}]
    # The default combo leads the sweep (deduped from the grid): the
    # strict `<` comparison below then keeps it on any tie-or-loss, so
    # a tuned config is never slower than the declared default.
    defaults = {p: spec[p].default for p in params_names}
    if params_names:
        combos = [defaults] + [c for c in combos if c != defaults]
    for args in args_sets:
        if not isinstance(args, tuple):
            args = (args,)
        bucket = shape_bucket(*args)
        key = (name, be, bucket)
        if not force and key in _TUNED:
            out[bucket] = dict(_TUNED[key])
            continue
        best: Optional[Tuple[float, Dict[str, Any]]] = None
        for combo in combos:
            try:
                t = timer(lambda: impl(*args, **combo), iters)
            except Exception:           # combo invalid for this shape
                continue
            if best is None or t < best[0]:
                best = (t, combo)
        if best is None:
            raise ValueError(f"no tunable candidate of {name!r} ran for "
                             f"bucket {bucket!r}")
        _TUNED[key] = dict(best[1])
        out[bucket] = dict(best[1])
    if save:
        save_tune_cache()
    return out


__all__ = ["BACKENDS", "ENV_VAR", "TUNE_CACHE_ENV", "Tunable",
           "clear_tune_cache", "dispatch", "get_default_backend",
           "get_impl", "op_tunables", "register_op", "registered_ops",
           "resolve_backend", "save_tune_cache", "set_default_backend",
           "shape_bucket", "tune", "tuned_params", "use_backend"]
