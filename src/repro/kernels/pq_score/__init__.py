from repro.kernels.pq_score.ops import (INVALID_ID, build_lut,
                                        build_lut_batch, build_lut_batch_ref,
                                        build_lut_ref, pq_score,
                                        pq_score_batched,
                                        pq_score_batched_ref, pq_score_ref,
                                        pq_topk, pq_topk_ref,
                                        score_candidates,
                                        score_candidates_batched,
                                        topk_candidates)

__all__ = ["INVALID_ID", "build_lut", "build_lut_batch",
           "build_lut_batch_ref", "build_lut_ref", "pq_score",
           "pq_score_batched", "pq_score_batched_ref", "pq_score_ref",
           "pq_topk", "pq_topk_ref", "score_candidates",
           "score_candidates_batched", "topk_candidates"]
