from repro.kernels.pq_score.ops import (build_lut, build_lut_ref, pq_score,
                                        pq_score_ref, score_candidates)

__all__ = ["build_lut", "score_candidates", "pq_score",
           "pq_score_ref", "build_lut_ref"]
