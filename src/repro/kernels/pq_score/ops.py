"""Public wrapper: ADC retrieval scoring against a PQ-coded corpus."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pq_score.pq_score import pq_score
from repro.kernels.pq_score.ref import build_lut_ref, pq_score_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def build_lut(query: jax.Array, centroids: jax.Array) -> jax.Array:
    """Per-query LUT (D, K).  Tiny — stays pure jnp (one einsum)."""
    return build_lut_ref(query, centroids)


def score_candidates(query: jax.Array, centroids: jax.Array,
                     codes: jax.Array, block_n: int = 1024) -> jax.Array:
    """Full ADC path: query (d,) + corpus codes (N, D) -> scores (N,)."""
    lut = build_lut(query, centroids).astype(jnp.float32)
    return pq_score(lut, codes, block_n=block_n, interpret=not _on_tpu())


__all__ = ["build_lut", "score_candidates", "pq_score",
           "pq_score_ref", "build_lut_ref"]
