"""Public wrappers: ADC retrieval scoring against a PQ-coded corpus.

Three dispatched ops (pallas | xla | interpret, DESIGN.md §5):

  ``pq_score``          one LUT (D, K) -> scores (N,)
  ``pq_score_batched``  B LUTs (B, D, K) -> scores (B, N); one pass
                        over the code stream for the whole query batch
  ``pq_topk``           fused batched score + block-wise top-k: the
                        (B, N) score matrix never materializes

All three accept the corpus codes at their STORED dtype (uint8 when
K <= 256), so call sites no longer make an eager int32 copy of the
O(vocab) code table per request.  Where the widening lands is
backend-dependent: the pallas/interpret kernels cast per VMEM block;
the XLA references widen inside the jitted gather (gather indices are
integer, so a transient N·D int32 index buffer still exists there —
fused where XLA can, but not block-bounded like the kernels).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.dispatch import Tunable
from repro.kernels.pq_score.pq_score import (INVALID_ID, pq_score,
                                             pq_score_batched, pq_topk)
from repro.kernels.pq_score.ref import (build_lut_batch_ref, build_lut_ref,
                                        pq_score_batched_ref, pq_score_ref,
                                        pq_topk_ref)

_BLOCK_N = Tunable(1024, (256, 512, 1024, 2048))

dispatch.register_op(
    "pq_score",
    pallas=lambda lut, codes, block_n=1024: pq_score(
        lut, codes, block_n=block_n),
    xla=lambda lut, codes, block_n=1024: pq_score_ref(lut, codes),
    interpret=lambda lut, codes, block_n=1024: pq_score(
        lut, codes, block_n=block_n, interpret=True),
    tunables={"block_n": _BLOCK_N},
)

dispatch.register_op(
    "pq_score_batched",
    pallas=lambda luts, codes, block_n=1024: pq_score_batched(
        luts, codes, block_n=block_n),
    xla=lambda luts, codes, block_n=1024: pq_score_batched_ref(luts, codes),
    interpret=lambda luts, codes, block_n=1024: pq_score_batched(
        luts, codes, block_n=block_n, interpret=True),
    tunables={"block_n": _BLOCK_N},
)

dispatch.register_op(
    "pq_topk",
    pallas=lambda luts, codes, k, block_n=1024: pq_topk(
        luts, codes, k, block_n=block_n),
    xla=lambda luts, codes, k, block_n=1024: pq_topk_ref(luts, codes, k),
    interpret=lambda luts, codes, k, block_n=1024: pq_topk(
        luts, codes, k, block_n=block_n, interpret=True),
    tunables={"block_n": _BLOCK_N},
)


def build_lut(query: jax.Array, centroids: jax.Array) -> jax.Array:
    """Per-query LUT (D, K).  Tiny — stays pure jnp (one einsum)."""
    return build_lut_ref(query, centroids)


def build_lut_batch(queries: jax.Array, centroids: jax.Array) -> jax.Array:
    """Per-query LUTs (B, D, K) — one einsum for the whole batch."""
    return build_lut_batch_ref(queries, centroids)


def score_candidates(query: jax.Array, centroids: jax.Array,
                     codes: jax.Array, block_n: Optional[int] = None,
                     backend: Optional[str] = None) -> jax.Array:
    """Full ADC path: query (d,) + corpus codes (N, D) -> scores (N,)."""
    lut = build_lut(query, centroids).astype(jnp.float32)
    return dispatch.dispatch("pq_score", lut, codes, block_n=block_n,
                             backend=backend)


def score_candidates_batched(queries: jax.Array, centroids: jax.Array,
                             codes: jax.Array, block_n: Optional[int] = None,
                             backend: Optional[str] = None) -> jax.Array:
    """Batched ADC: queries (B, d) + codes (N, D) -> scores (B, N)."""
    luts = build_lut_batch(queries, centroids).astype(jnp.float32)
    return dispatch.dispatch("pq_score_batched", luts, codes,
                             block_n=block_n, backend=backend)


def topk_candidates(queries: jax.Array, centroids: jax.Array,
                    codes: jax.Array, k: int, block_n: Optional[int] = None,
                    backend: Optional[str] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Fused batched ADC top-k: queries (B, d) + codes (N, D) ->
    (scores (B, k), ids (B, k)); ordering (score desc, id asc)."""
    luts = build_lut_batch(queries, centroids).astype(jnp.float32)
    return dispatch.dispatch("pq_topk", luts, codes, k, block_n=block_n,
                             backend=backend)


__all__ = ["INVALID_ID", "build_lut", "build_lut_batch", "pq_score",
           "pq_score_batched", "pq_score_batched_ref", "pq_score_ref",
           "pq_topk", "pq_topk_ref", "build_lut_ref", "build_lut_batch_ref",
           "score_candidates", "score_candidates_batched",
           "topk_candidates"]
