"""Public wrapper: ADC retrieval scoring against a PQ-coded corpus."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.pq_score.pq_score import pq_score
from repro.kernels.pq_score.ref import build_lut_ref, pq_score_ref

dispatch.register_op(
    "pq_score",
    pallas=lambda lut, codes, block_n=1024: pq_score(
        lut, codes, block_n=block_n),
    xla=lambda lut, codes, block_n=1024: pq_score_ref(lut, codes),
    interpret=lambda lut, codes, block_n=1024: pq_score(
        lut, codes, block_n=block_n, interpret=True),
)


def build_lut(query: jax.Array, centroids: jax.Array) -> jax.Array:
    """Per-query LUT (D, K).  Tiny — stays pure jnp (one einsum)."""
    return build_lut_ref(query, centroids)


def score_candidates(query: jax.Array, centroids: jax.Array,
                     codes: jax.Array, block_n: int = 1024,
                     backend: Optional[str] = None) -> jax.Array:
    """Full ADC path: query (d,) + corpus codes (N, D) -> scores (N,)."""
    lut = build_lut(query, centroids).astype(jnp.float32)
    return dispatch.dispatch("pq_score", lut, codes, block_n=block_n,
                             backend=backend)


__all__ = ["build_lut", "score_candidates", "pq_score",
           "pq_score_ref", "build_lut_ref"]
