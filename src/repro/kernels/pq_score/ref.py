"""Pure-jnp oracle for ADC (asymmetric distance computation) scoring.

Retrieval against a PQ-coded corpus: precompute per-subspace lookup
table ``lut[d, k] = <q_d, c_k^(d)>`` once per query, then the score of
candidate i is ``sum_d lut[d, codes[i, d]]`` — the candidate embedding
is never reconstructed.
"""
from __future__ import annotations

import jax.numpy as jnp


def build_lut_ref(query: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """query (d,) with d = D*S; centroids (D, K, S) -> lut (D, K)."""
    n_sub, _, s = centroids.shape
    q_sub = query.reshape(n_sub, s)
    return jnp.einsum("ds,dks->dk", q_sub, centroids)


def pq_score_ref(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """lut (D, K); codes (N, D) -> scores (N,)."""
    gathered = jnp.take_along_axis(
        jnp.broadcast_to(lut[None], (codes.shape[0],) + lut.shape),
        codes.astype(jnp.int32)[..., None], axis=2)       # (N, D, 1)
    return jnp.sum(gathered[..., 0], axis=1)
