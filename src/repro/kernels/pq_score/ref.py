"""Pure-jnp oracles for ADC (asymmetric distance computation) scoring.

Retrieval against a PQ-coded corpus: precompute per-subspace lookup
table ``lut[d, k] = <q_d, c_k^(d)>`` once per query, then the score of
candidate i is ``sum_d lut[d, codes[i, d]]`` — the candidate embedding
is never reconstructed.  The batched forms take one LUT per query
(B, D, K) and share a single pass over the code table; ``pq_topk_ref``
additionally reduces to (score, id) top-k pairs under the tie-breaking
contract of ``repro.kernels.pq_score.pq_score`` (score desc, id asc;
padding = ``-inf`` / ``INVALID_ID``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def build_lut_ref(query: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """query (d,) with d = D*S; centroids (D, K, S) -> lut (D, K)."""
    n_sub, _, s = centroids.shape
    q_sub = query.reshape(n_sub, s)
    return jnp.einsum("ds,dks->dk", q_sub, centroids)


def build_lut_batch_ref(queries: jnp.ndarray,
                        centroids: jnp.ndarray) -> jnp.ndarray:
    """queries (B, d); centroids (D, K, S) -> luts (B, D, K)."""
    n_sub, _, s = centroids.shape
    q_sub = queries.reshape(queries.shape[0], n_sub, s)
    return jnp.einsum("bds,dks->bdk", q_sub, centroids)


def pq_score_ref(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """lut (D, K); codes (N, D) -> scores (N,)."""
    gathered = jnp.take_along_axis(
        jnp.broadcast_to(lut[None], (codes.shape[0],) + lut.shape),
        codes.astype(jnp.int32)[..., None], axis=2)       # (N, D, 1)
    return jnp.sum(gathered[..., 0], axis=1)


def pq_score_batched_ref(luts: jnp.ndarray,
                         codes: jnp.ndarray) -> jnp.ndarray:
    """luts (B, D, K); codes (N, D) -> scores (B, N).

    Flattened-LUT gather — ``take`` of (N·D) flat indices out of the
    (B, D·K) LUT block measures ~3x faster under XLA:CPU than the
    equivalent (B, D, N) ``take_along_axis`` (transpose-hostile
    layout), and identical math.
    """
    b, n_sub, k = luts.shape
    # explicit rank match (sanitizer lane runs rank_promotion='raise')
    offs = (jnp.arange(n_sub, dtype=jnp.int32) * k)[None, :]
    flat = (codes.astype(jnp.int32) + offs).reshape(-1)
    return jnp.take(luts.reshape(b, n_sub * k), flat,
                    axis=1).reshape(b, -1, n_sub).sum(-1)


def pq_topk_ref(luts: jnp.ndarray, codes: jnp.ndarray, k: int):
    """luts (B, D, K); codes (N, D) -> (scores (B, k), ids (B, k))."""
    from repro.kernels.pq_score.pq_score import INVALID_ID
    scores = pq_score_batched_ref(luts, codes)            # (B, N)
    n = scores.shape[1]
    if k > n:                                             # pad contract
        scores = jnp.pad(scores, ((0, 0), (0, k - n)),
                         constant_values=-jnp.inf)
    ids = jnp.where(jnp.arange(scores.shape[1]) < n,
                    jnp.arange(scores.shape[1], dtype=jnp.int32),
                    INVALID_ID)
    top_s, pos = jax.lax.top_k(scores, k)
    return top_s, jnp.take(ids, pos)
