"""Pallas TPU kernel: ADC scoring of a PQ-coded candidate corpus.

The beyond-paper serving win (DESIGN.md §3): scoring one query against
N=1M candidates with full d=64 fp32 embeddings reads 256 MB from HBM;
with PQ codes it reads N*D = 8 MB of uint8 codes and a (D, K) LUT that
lives in VMEM (8 KB).  Memory-roofline speedup ≈ 32x on the dominant
stream.

Kernel layout: grid over candidate blocks.  Codes block (Nblk, D) in
VMEM; LUT (D, K) pinned whole; scores block (Nblk,) out.  The gather
``lut[d, codes[n, d]]`` is again one-hot matmul form: contraction of
``onehot(codes)`` (Nblk, D, K) with LUT (D, K) over (D, K) — a single
MXU pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(codes_ref, lut_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)          # (Nblk, D)
    lut = lut_ref[...]                                # (D, K)
    k = lut.shape[1]
    onehot = (codes[:, :, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2)
              ).astype(lut.dtype)                     # (Nblk, D, K)
    out_ref[...] = jnp.einsum("ndk,dk->n", onehot, lut,
                              preferred_element_type=jnp.float32
                              ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_score(lut: jax.Array, codes: jax.Array, block_n: int = 1024,
             interpret: bool = False) -> jax.Array:
    """lut (D, K) f32; codes (N, D) int -> scores (N,) f32."""
    n, d = codes.shape
    n_sub, k = lut.shape
    assert d == n_sub, (d, n_sub)
    pad = (-n) % block_n
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _score_kernel,
        grid=((n + pad) // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, n_sub), lambda i: (i, 0)),
            pl.BlockSpec((n_sub, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.float32),
        interpret=interpret,
    )(codes, lut)
    return out[:n]
