"""Pallas TPU kernels: ADC scoring of a PQ-coded candidate corpus.

The beyond-paper serving win (DESIGN.md §3): scoring one query against
N=1M candidates with full d=64 fp32 embeddings reads 256 MB from HBM;
with PQ codes it reads N*D = 8 MB of uint8 codes and a (D, K) LUT that
lives in VMEM (8 KB).  Memory-roofline speedup ≈ 32x on the dominant
stream.

Three kernels share the layout (grid over candidate blocks, codes
block (Nblk, D) in VMEM, LUTs pinned whole):

  ``pq_score``          one query: LUT (D, K) -> scores (N,).
  ``pq_score_batched``  B queries share one pass over the code stream:
                        LUTs (B, D, K) -> scores (B, N).  The corpus
                        bytes are read ONCE for the whole batch instead
                        of once per query — the retrieval subsystem's
                        hot path (DESIGN.md §8).
  ``pq_topk``           batched scoring fused with block-wise top-k
                        accumulation: the (B, N) score matrix never
                        reaches HBM; only (B, k) scores + ids leave the
                        kernel.  The running top-k rides in the output
                        block, revisited every grid step (the TPU grid
                        is sequential).

The gather ``lut[d, codes[n, d]]`` is one-hot matmul form in all
three: contraction of ``onehot(codes)`` (Nblk, D, K) with the LUT(s)
over (D, K) — a single MXU pass per block.

Tie-breaking contract (shared with ``repro.retrieval.topk``): top-k
entries are ordered by (score desc, id asc); masked/padded slots carry
``score = -inf, id = INVALID_ID`` so every implementation — fused
kernel, XLA reference, sharded merge — emits bit-identical output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INVALID_ID = jnp.iinfo(jnp.int32).max


def _onehot_scores(codes, luts):
    """codes (Nblk, D) int; luts (B, D, K) -> scores (B, Nblk) f32."""
    k = luts.shape[-1]
    onehot = (codes[:, :, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2)
              ).astype(luts.dtype)                     # (Nblk, D, K)
    return jnp.einsum("ndk,bdk->bn", onehot, luts,
                      preferred_element_type=jnp.float32)


def _score_kernel(codes_ref, lut_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)          # (Nblk, D)
    scores = _onehot_scores(codes, lut_ref[...][None])  # (1, Nblk)
    out_ref[...] = scores[0].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_score(lut: jax.Array, codes: jax.Array, block_n: int = 1024,
             interpret: bool = False) -> jax.Array:
    """lut (D, K) f32; codes (N, D) int -> scores (N,) f32."""
    n, d = codes.shape
    n_sub, k = lut.shape
    if d != n_sub:
        raise ValueError(f"codes have {d} subspaces, LUT {n_sub}")
    pad = (-n) % block_n
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _score_kernel,
        grid=((n + pad) // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, n_sub), lambda i: (i, 0)),
            pl.BlockSpec((n_sub, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.float32),
        interpret=interpret,
    )(codes, lut)
    return out[:n]


def _score_batched_kernel(codes_ref, luts_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)          # (Nblk, D)
    out_ref[...] = _onehot_scores(codes, luts_ref[...]
                                  ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_score_batched(luts: jax.Array, codes: jax.Array,
                     block_n: int = 1024,
                     interpret: bool = False) -> jax.Array:
    """luts (B, D, K) f32; codes (N, D) int -> scores (B, N) f32."""
    n, d = codes.shape
    b, n_sub, k = luts.shape
    if d != n_sub:
        raise ValueError(f"codes have {d} subspaces, LUT {n_sub}")
    pad = (-n) % block_n
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _score_batched_kernel,
        grid=((n + pad) // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, n_sub), lambda i: (i, 0)),
            pl.BlockSpec((b, n_sub, k), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n + pad), jnp.float32),
        interpret=interpret,
    )(codes, luts)
    return out[:, :n]


def _topk_kernel(codes_ref, luts_ref, out_s_ref, out_i_ref, *,
                 block_n: int, k: int, n: int):
    i = pl.program_id(0)
    codes = codes_ref[...].astype(jnp.int32)          # (Nblk, D)
    scores = _onehot_scores(codes, luts_ref[...])     # (B, Nblk)
    ids = i * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_n), 1)                   # (1, Nblk)
    valid = ids < n
    scores = jnp.where(valid, scores, -jnp.inf)
    ids = jnp.broadcast_to(jnp.where(valid, ids, INVALID_ID), scores.shape)

    @pl.when(i == 0)
    def _init():
        out_s_ref[...] = jnp.full_like(out_s_ref[...], -jnp.inf)
        out_i_ref[...] = jnp.full_like(out_i_ref[...], INVALID_ID)

    # merge the running (B, k) state with this block.  lax.top_k keeps
    # the EARLIEST position among ties; running entries (lower ids,
    # already (score desc, id asc)-ordered) precede the block's
    # ascending ids, so the ordering contract holds inductively.
    cat_s = jnp.concatenate([out_s_ref[...], scores], axis=1)
    cat_i = jnp.concatenate([out_i_ref[...], ids], axis=1)
    top_s, pos = jax.lax.top_k(cat_s, k)
    out_s_ref[...] = top_s.astype(out_s_ref.dtype)
    out_i_ref[...] = jnp.take_along_axis(cat_i, pos, axis=1)


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def pq_topk(luts: jax.Array, codes: jax.Array, k: int,
            block_n: int = 1024, interpret: bool = False):
    """Fused batched score + top-k: luts (B, D, K), codes (N, D) ->
    (scores (B, k) f32, ids (B, k) int32).

    The (B, N) score matrix stays in VMEM block-by-block; HBM only
    sees the (B, k) running state.
    """
    n, d = codes.shape
    b, n_sub, kk = luts.shape
    if d != n_sub:
        raise ValueError(f"codes have {d} subspaces, LUT {n_sub}")
    pad = (-n) % block_n
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    scores, ids = pl.pallas_call(
        functools.partial(_topk_kernel, block_n=block_n, k=k, n=n),
        grid=((n + pad) // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, n_sub), lambda i: (i, 0)),
            pl.BlockSpec((b, n_sub, kk), lambda i: (0, 0, 0)),
        ],
        out_specs=(pl.BlockSpec((b, k), lambda i: (0, 0)),
                   pl.BlockSpec((b, k), lambda i: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((b, k), jnp.float32),
                   jax.ShapeDtypeStruct((b, k), jnp.int32)),
        interpret=interpret,
    )(codes, luts)
    return scores, ids
