"""Pure-jnp oracle for the MGQE/DPQ serving decode.

Given per-item codes (B, D) and per-subspace centroid tables (D, K, S),
reconstruct embeddings (B, D*S) by gathering centroid ``codes[b, d]``
in each subspace d and concatenating.
"""
from __future__ import annotations

import jax.numpy as jnp


def mgqe_decode_ref(codes: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """codes (B, D) int; centroids (D, K, S) -> (B, D*S) float."""
    b, d = codes.shape
    _, _, s = centroids.shape
    gathered = jnp.take_along_axis(
        centroids[None],                                   # (1, D, K, S)
        codes.astype(jnp.int32)[..., None, None],          # (B, D, 1, 1)
        axis=2)                                            # (B, D, 1, S)
    return gathered[:, :, 0, :].reshape(b, d * s)
