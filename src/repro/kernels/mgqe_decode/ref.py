"""Pure-jnp oracle for the MGQE/DPQ serving decode.

Given per-item codes (B, D) and per-subspace centroid tables (D, K, S),
reconstruct embeddings (B, D*S) by gathering centroid ``codes[b, d]``
in each subspace d and concatenating.
"""
from __future__ import annotations

import jax.numpy as jnp


def mgqe_decode_ref(codes: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """codes (B, D) int; centroids (D, K, S) -> (B, D*S) float."""
    b, d = codes.shape
    _, _, s = centroids.shape
    # mode="clip": under mgqe private_k, ids of OTHER tiers carry codes
    # >= this tier's K — those lanes are masked downstream by the tier
    # select, but jit's default OOB fill (NaN) would trip debug_nans
    gathered = jnp.take_along_axis(
        centroids[None],                                   # (1, D, K, S)
        codes.astype(jnp.int32)[..., None, None],          # (B, D, 1, 1)
        axis=2, mode="clip")                               # (B, D, 1, S)
    return gathered[:, :, 0, :].reshape(b, d * s)


def rq_decode_stages_ref(codes: jnp.ndarray,
                         codebooks: jnp.ndarray) -> jnp.ndarray:
    """codes (B, M) int; stacked codebooks (M, K, d) -> (B, d) float:
    ``sum_m codebooks[m, codes[:, m]]``.

    Written as an unrolled per-stage row-gather + add chain — under one
    jit XLA fuses it into a single pass over the output (each output
    row is the running sum of M gathered rows in registers; no
    (B, M, d) intermediate reaches HBM).  A flat one-shot gather of
    (B·M, d) rows measures ~10x slower on CPU, and the old
    ``take_along_axis`` form that materialized (B, M·d) before an
    external sum is what BENCH_kernels.json ``rq_decode`` flagged at
    0.27x of this chain.
    """
    m = codebooks.shape[0]
    out = jnp.take(codebooks[0], codes[:, 0].astype(jnp.int32), axis=0)
    for i in range(1, m):
        out = out + jnp.take(codebooks[i], codes[:, i].astype(jnp.int32),
                             axis=0)
    return out
