"""Pallas TPU kernel: MGQE/DPQ serving decode (codes -> embeddings).

Roofline story (DESIGN.md §3): serving FE reads ``B*d*4`` bytes of
embedding rows from HBM; MGQE reads ``B*D`` bytes of uint8 codes plus a
one-time ``D*K*S*4``-byte centroid table that *fits in VMEM* (64 KB at
d=64, K=256).  Fusing the decode keeps the 4x-32x byte reduction —
doing it as take_along_axis in HBM would read the centroids once per
row and defeat the point.

TPU adaptation: per-row dynamic gathers vectorize poorly on the VPU,
so the gather is re-expressed as a **one-hot matmul** — the MXU eats
``onehot(codes) @ centroids`` at full throughput:

    onehot:  (Bblk, D, K)  built from a broadcasted iota compare
    decode:  einsum('bdk,dks->bds') -> (Bblk, D, S) -> reshape (Bblk, d)

Block layout: grid over B; codes block (Bblk, D) and output block
(Bblk, d) stream through VMEM; the centroid table is mapped whole into
VMEM every step (index_map returns the same block).

``rq_decode_stages`` is the residual-quantization variant (DESIGN.md
§11): codes (B, M) against M stacked full-width codebooks (M, K, d),
where the output is the SUM over stages rather than a concatenation
over subspaces.  Running it as M ``mgqe_decode`` launches (one per
stage, summed outside) costs M kernel dispatches plus an HBM
round-trip of the (B, M·d) stage outputs; here the stage sum happens
in one pass — the grid's innermost dimension iterates stages and the
revisited (Bblk, dblk) output block accumulates in VMEM, so only the
final (B, d) sum ever reaches HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(codes_ref, cent_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)          # (Bblk, D)
    cent = cent_ref[...]                              # (D, K, S)
    k = cent.shape[1]
    onehot = (codes[:, :, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2)
              ).astype(cent.dtype)                    # (Bblk, D, K)
    dec = jnp.einsum("bdk,dks->bds", onehot, cent,
                     preferred_element_type=jnp.float32)
    out_ref[...] = dec.reshape(dec.shape[0], -1).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def mgqe_decode(codes: jax.Array, centroids: jax.Array,
                block_b: int = 256, interpret: bool = False) -> jax.Array:
    """codes (B, D) int; centroids (D, K, S) -> (B, D*S) float32.

    block_b: rows per grid step.  VMEM working set per step =
    Bblk*D codes + D*K*S*4 centroids + Bblk*D*K*4 onehot + Bblk*d*4 out;
    256*8*256*4 = 2 MB onehot dominates — comfortably inside 16 MB VMEM.
    """
    b, d = codes.shape
    n_sub, k, s = centroids.shape
    if d != n_sub:
        raise ValueError(f"codes have {d} subspaces, centroids {n_sub}")
    pad = (-b) % block_b
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _decode_kernel,
        grid=((b + pad) // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, n_sub), lambda i: (i, 0)),
            pl.BlockSpec((n_sub, k, s), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_sub * s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((b + pad), n_sub * s),
                                       centroids.dtype),
        interpret=interpret,
    )(codes, centroids)
    return out[:b]


def _staged_kernel(codes_ref, cb_ref, out_ref):
    stage = pl.program_id(2)                          # innermost grid dim
    codes = codes_ref[...].astype(jnp.int32)          # (Bblk, 1)
    cb = cb_ref[0]                                    # (K, dblk)
    k = cb.shape[0]
    onehot = (codes
              == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
              ).astype(cb.dtype)                      # (Bblk, K)
    dec = jnp.dot(onehot, cb, preferred_element_type=jnp.float32)

    @pl.when(stage == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += dec.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_d", "interpret"))
def rq_decode_stages(codes: jax.Array, codebooks: jax.Array,
                     block_b: int = 256, block_d: Optional[int] = None,
                     interpret: bool = False) -> jax.Array:
    """codes (B, M) int; stacked codebooks (M, K, d) -> (B, d) float:
    single-pass residual-stage decode, ``sum_m codebooks[m, codes[:, m]]``.

    Grid (B/block_b, d/block_d, M) with the stage index innermost: the
    (block_b, block_d) output block is revisited across all M stages
    and accumulates the one-hot-matmul stage decodes in VMEM — Pallas
    only flushes a revisited block when its index changes, so the stage
    sum never round-trips HBM.  Codes stay at their stored dtype
    (uint8) until the per-block int32 widening in the body.

    ``block_d`` tiles the output width (None = full d; values that do
    not divide d fall back to full width).  VMEM working set per step:
    block_b codes + K*block_d codebook slice + block_b*K onehot +
    block_b*block_d out — 256*256*4 = 256 KB onehot dominates.
    """
    b, m = codes.shape
    m2, k, d = codebooks.shape
    if m != m2:
        raise ValueError(f"codes have {m} layers, codebooks {m2}")
    if block_d is None or d % block_d:
        block_d = d
    pad = (-b) % block_b
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _staged_kernel,
        grid=((b + pad) // block_b, d // block_d, m),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda i, j, s: (i, s)),
            pl.BlockSpec((1, k, block_d), lambda i, j, s: (s, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b + pad, d), codebooks.dtype),
        interpret=interpret,
    )(codes, codebooks)
    return out[:b]
