from repro.kernels.mgqe_decode.ops import decode, mgqe_decode, mgqe_decode_ref

__all__ = ["decode", "mgqe_decode", "mgqe_decode_ref"]
