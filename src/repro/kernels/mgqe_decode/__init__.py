from repro.kernels.mgqe_decode.ops import (decode, decode_stages,
                                           mgqe_decode, mgqe_decode_ref,
                                           rq_decode_stages,
                                           rq_decode_stages_ref)

__all__ = ["decode", "decode_stages", "mgqe_decode", "mgqe_decode_ref",
           "rq_decode_stages", "rq_decode_stages_ref"]
