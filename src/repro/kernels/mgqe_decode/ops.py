"""Public wrapper for the MGQE decode kernel.

``decode(codes, centroids)`` dispatches to the Pallas kernel on TPU and
to interpret mode elsewhere (CPU test/dev containers), so call sites
never branch on backend.
"""
from __future__ import annotations

import jax

from repro.kernels.mgqe_decode.mgqe_decode import mgqe_decode
from repro.kernels.mgqe_decode.ref import mgqe_decode_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode(codes: jax.Array, centroids: jax.Array,
           block_b: int = 256) -> jax.Array:
    """codes (B, D) -> embeddings (B, D*S) via the fused kernel."""
    return mgqe_decode(codes, centroids, block_b=block_b,
                       interpret=not _on_tpu())


__all__ = ["decode", "mgqe_decode", "mgqe_decode_ref"]
