"""Public wrapper for the MGQE decode kernel.

``decode(codes, centroids)`` routes through the kernel backend dispatch
layer (``repro.kernels.dispatch``): the Pallas kernel on TPU, the jnp
reference under XLA elsewhere, or Pallas interpret mode when explicitly
requested (CI runs the kernel bodies on CPU this way) — so call sites
never branch on backend.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.mgqe_decode.mgqe_decode import mgqe_decode
from repro.kernels.mgqe_decode.ref import mgqe_decode_ref

dispatch.register_op(
    "mgqe_decode",
    pallas=lambda codes, cent, block_b=256: mgqe_decode(
        codes, cent, block_b=block_b),
    xla=lambda codes, cent, block_b=256: mgqe_decode_ref(codes, cent),
    interpret=lambda codes, cent, block_b=256: mgqe_decode(
        codes, cent, block_b=block_b, interpret=True),
)


def decode(codes: jax.Array, centroids: jax.Array, block_b: int = 256,
           backend: Optional[str] = None) -> jax.Array:
    """codes (B, D) -> embeddings (B, D*S) via the dispatched kernel."""
    return dispatch.dispatch("mgqe_decode", codes, centroids,
                             block_b=block_b, backend=backend)


__all__ = ["decode", "mgqe_decode", "mgqe_decode_ref"]
