"""Public wrappers for the MGQE decode kernels.

``decode(codes, centroids)`` routes through the kernel backend dispatch
layer (``repro.kernels.dispatch``): the Pallas kernel on TPU, the jnp
reference under XLA elsewhere, or Pallas interpret mode when explicitly
requested (CI runs the kernel bodies on CPU this way) — so call sites
never branch on backend.

``decode_stages(codes, codebooks)`` is the residual-quantization form:
codes (B, M) against stacked full-width codebooks (M, K, d), with the
M-stage sum fused into one kernel pass (DESIGN.md §11).  Codes keep
their stored dtype (uint8) end-to-end; each backend widens per block.

Both ops declare their block-geometry kwargs as autotunables — leave
``block_b``/``block_d`` as None and the dispatch layer substitutes the
tuned value for the call's shape bucket (or the declared default when
the bucket was never tuned).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.dispatch import Tunable
from repro.kernels.mgqe_decode.mgqe_decode import (mgqe_decode,
                                                   rq_decode_stages)
from repro.kernels.mgqe_decode.ref import (mgqe_decode_ref,
                                           rq_decode_stages_ref)

dispatch.register_op(
    "mgqe_decode",
    pallas=lambda codes, cent, block_b=256: mgqe_decode(
        codes, cent, block_b=block_b),
    xla=lambda codes, cent, block_b=256: mgqe_decode_ref(codes, cent),
    interpret=lambda codes, cent, block_b=256: mgqe_decode(
        codes, cent, block_b=block_b, interpret=True),
    tunables={"block_b": Tunable(256, (64, 128, 256, 512))},
)

dispatch.register_op(
    "rq_decode_stages",
    pallas=lambda codes, cbs, block_b=256, block_d=None: rq_decode_stages(
        codes, cbs, block_b=block_b, block_d=block_d),
    xla=lambda codes, cbs, block_b=256, block_d=None: rq_decode_stages_ref(
        codes, cbs),
    interpret=lambda codes, cbs, block_b=256, block_d=None: rq_decode_stages(
        codes, cbs, block_b=block_b, block_d=block_d, interpret=True),
    tunables={"block_b": Tunable(256, (64, 128, 256, 512)),
              "block_d": Tunable(None, (None, 32, 64, 128))},
)


def decode(codes: jax.Array, centroids: jax.Array,
           block_b: Optional[int] = None,
           backend: Optional[str] = None) -> jax.Array:
    """codes (B, D) -> embeddings (B, D*S) via the dispatched kernel."""
    return dispatch.dispatch("mgqe_decode", codes, centroids,
                             block_b=block_b, backend=backend)


def decode_stages(codes: jax.Array, codebooks: jax.Array,
                  block_b: Optional[int] = None,
                  block_d: Optional[int] = None,
                  backend: Optional[str] = None) -> jax.Array:
    """codes (B, M) + stacked codebooks (M, K, d) -> (B, d): the
    single-pass fused residual-stage decode, backend-dispatched."""
    return dispatch.dispatch("rq_decode_stages", codes, codebooks,
                             block_b=block_b, block_d=block_d,
                             backend=backend)


__all__ = ["decode", "decode_stages", "mgqe_decode", "mgqe_decode_ref",
           "rq_decode_stages", "rq_decode_stages_ref"]
