"""Pallas TPU kernels for the paper's compute hot-spots.

Each subpackage ships <name>.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd dispatch wrapper), ref.py (pure-jnp oracle):

  mgqe_decode     codes + centroids -> embeddings (serving hot path)
  dpq_assign      nearest-centroid search (training/export hot path)
  pq_score        ADC retrieval scoring vs a PQ-coded corpus
  embedding_bag   fused ragged gather + segment-sum (TBE pattern)
  flash_attention blocked causal/windowed GQA attention

All validated against their oracles in interpret mode (tests/), which
executes the kernel bodies on CPU.
"""
from repro.kernels import (dpq_assign, embedding_bag, flash_attention,
                           mgqe_decode, pq_score)

__all__ = ["dpq_assign", "embedding_bag", "flash_attention",
           "mgqe_decode", "pq_score"]
