"""Pallas TPU kernels for the paper's compute hot-spots.

Each subpackage ships <name>.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (dispatch-registered wrapper), ref.py (pure-jnp
oracle):

  mgqe_decode     codes + centroids -> embeddings (serving hot path)
  packed_decode   fused unpack-and-decode for bit-packed mpe codes
  dpq_assign      nearest-centroid search (training/export hot path)
  pq_score        ADC retrieval scoring vs a PQ-coded corpus
  embedding_bag   fused ragged gather + segment-sum (TBE pattern)
  flash_attention blocked causal/windowed GQA attention

Backend selection (pallas | xla | interpret) is centralized in
``dispatch.py``: each ops.py registers its implementations there, and
call sites pick a backend via config field, the REPRO_KERNEL_BACKEND
env var, or automatic hardware detection (DESIGN.md §5).

All kernels are validated against their oracles in interpret mode
(tests/), which executes the kernel bodies on CPU.
"""
from repro.kernels import dispatch  # noqa: F401  (must import first)
from repro.kernels import (dpq_assign, embedding_bag, flash_attention,
                           mgqe_decode, packed_decode, pq_score)

__all__ = ["dispatch", "dpq_assign", "embedding_bag", "flash_attention",
           "mgqe_decode", "packed_decode", "pq_score"]
