"""Graph data: synthetic generators for the four assigned GNN shapes and
a real fanout neighbor sampler (GraphSAGE-style) for minibatch_lg.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


# ----------------------------------------------------------------------
# batched small molecules (shape: molecule — 30 nodes, 64 edges, B=128)
# ----------------------------------------------------------------------

def molecule_batch(n_graphs: int = 128, n_atoms: int = 30,
                   n_edges: int = 64, n_species: int = 10,
                   box: float = 6.0, seed: int = 0) -> Dict[str, np.ndarray]:
    """Random molecules with a Lennard-Jones-ish teacher energy so the
    regression task has signal.  Edges: nearest pairs, padded/capped to
    exactly n_edges per graph (static shape)."""
    rng = np.random.default_rng(seed)
    all_pos, all_spec, all_send, all_recv, all_gid, energies = \
        [], [], [], [], [], []
    for g in range(n_graphs):
        pos = rng.uniform(0, box, size=(n_atoms, 3)).astype(np.float32)
        spec = rng.integers(0, n_species, n_atoms)
        d = np.linalg.norm(pos[:, None] - pos[None, :], axis=-1)
        np.fill_diagonal(d, np.inf)
        # pick the n_edges closest directed pairs
        flat = np.argsort(d, axis=None)[:n_edges]
        send, recv = np.unravel_index(flat, d.shape)
        r = np.maximum(d[send, recv], 0.9)   # clamp: keep teacher bounded
        # LJ-style pair energy teacher (+ species affinity term)
        eps = 0.5 + 0.1 * ((spec[send] + spec[recv]) % 3)
        e = np.sum(eps * ((1.2 / r) ** 12 - 2 * (1.2 / r) ** 6)) / n_atoms
        off = g * n_atoms
        all_pos.append(pos)
        all_spec.append(spec)
        all_send.append(send + off)
        all_recv.append(recv + off)
        all_gid.append(np.full(n_atoms, g))
        energies.append(e)
    return {
        "positions": np.concatenate(all_pos).astype(np.float32),
        "species": np.concatenate(all_spec).astype(np.int32),
        "edge_index": np.stack([np.concatenate(all_send),
                                np.concatenate(all_recv)]).astype(np.int32),
        "graph_id": np.concatenate(all_gid).astype(np.int32),
        "n_graphs": n_graphs,
        "energy": np.asarray(energies, np.float32),
    }


# ----------------------------------------------------------------------
# full-batch citation/products-like graphs (synthetic coordinates)
# ----------------------------------------------------------------------

def random_graph(n_nodes: int, n_edges: int, d_feat: int,
                 n_classes: int = 16, seed: int = 0) -> Dict[str, np.ndarray]:
    """Power-law-degree random graph with planted community labels."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-ish: sample endpoints from Zipf over nodes
    def zipf_ids(n):
        u = rng.random(n)
        x = (1.0 - u) ** (-1.0 / 0.35) - 1.0
        return np.minimum(x.astype(np.int64), n_nodes - 1)
    send = zipf_ids(n_edges)
    recv = rng.integers(0, n_nodes, n_edges)
    labels = rng.integers(0, n_classes, n_nodes)
    # features correlate with labels (learnable signal)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats = centers[labels] + rng.normal(scale=2.0,
                                         size=(n_nodes, d_feat)).astype(np.float32)
    return {
        "positions": rng.normal(size=(n_nodes, 3)).astype(np.float32),
        "species": (labels % 100).astype(np.int32),
        "node_feats": feats.astype(np.float32),
        "edge_index": np.stack([send, recv]).astype(np.int32),
        "labels": labels.astype(np.int32),
    }


# ----------------------------------------------------------------------
# CSR adjacency + fanout neighbor sampler (minibatch_lg)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray      # (N+1,)
    indices: np.ndarray     # (E,)
    n_nodes: int

    @staticmethod
    def from_edge_index(edge_index: np.ndarray, n_nodes: int) -> "CSRGraph":
        send, recv = edge_index
        order = np.argsort(recv, kind="stable")
        sorted_send = send[order]
        counts = np.bincount(recv, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(indptr, sorted_send.astype(np.int64), n_nodes)


class NeighborSampler:
    """GraphSAGE fanout sampling: for seed nodes, sample ``fanout[0]``
    in-neighbors, then ``fanout[1]`` neighbors of those, etc.  Nodes
    with degree < fanout are padded with self-loops so every batch has
    a static shape (TPU requirement)."""

    def __init__(self, graph: CSRGraph, fanout: Tuple[int, ...],
                 seed: int = 0):
        self.g = graph
        self.fanout = fanout
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> Dict[str, np.ndarray]:
        layers = [seeds.astype(np.int64)]
        sends, recvs = [], []
        frontier = seeds.astype(np.int64)
        for f in self.fanout:
            deg = self.g.indptr[frontier + 1] - self.g.indptr[frontier]
            # sample with replacement; degree-0 nodes self-loop
            offs = self.rng.integers(0, np.maximum(deg, 1)[:, None],
                                     size=(len(frontier), f))
            base = self.g.indptr[frontier][:, None]
            neigh = np.where(deg[:, None] > 0,
                             self.g.indices[np.minimum(
                                 base + offs,
                                 len(self.g.indices) - 1)],
                             frontier[:, None])
            sends.append(neigh.reshape(-1))
            recvs.append(np.repeat(frontier, f))
            frontier = neigh.reshape(-1)
            layers.append(frontier)
        # compact node ids: unique nodes, seeds first
        all_nodes = np.concatenate(layers)
        uniq, inv = np.unique(all_nodes, return_inverse=True)
        # reorder so seeds occupy [0, len(seeds))
        seed_pos = inv[:len(seeds)]
        perm = np.full(len(uniq), -1, np.int64)
        perm[seed_pos] = np.arange(len(seeds))
        rest = np.setdiff1d(np.arange(len(uniq)), seed_pos, assume_unique=False)
        perm[rest] = np.arange(len(seeds), len(uniq))
        # map edges to local ids via searchsorted over the sorted uniq
        send_cat = np.concatenate(sends)
        recv_cat = np.concatenate(recvs)
        send_l = perm[np.searchsorted(uniq, send_cat)]
        recv_l = perm[np.searchsorted(uniq, recv_cat)]
        return {
            "node_ids": uniq[np.argsort(perm)],
            "edge_index": np.stack([send_l, recv_l]).astype(np.int32),
            "n_seeds": len(seeds),
        }


def sampled_subgraph_sizes(batch_nodes: int,
                           fanout: Tuple[int, ...]) -> Tuple[int, int]:
    """Static (n_nodes, n_edges) upper bounds for a fanout sample —
    what the dry-run lowers."""
    nodes, edges, frontier = batch_nodes, 0, batch_nodes
    for f in fanout:
        edges += frontier * f
        frontier = frontier * f
        nodes += frontier
    return nodes, edges
