"""Data substrate: synthetic dataset generators (the container is
offline), negative samplers, shard-aware batch iterators, and the GNN
neighbor sampler."""
