"""Negative samplers + batch iterators for the paper-repro training runs
(GMF/NeuMF pointwise with sampled negatives; SASRec sequence batches),
plus a shard-aware wrapper for multi-host input pipelines.
"""
from __future__ import annotations

import threading
import queue as queue_mod
from typing import Dict, Iterator

import numpy as np

from repro.data.synthetic import InteractionData


class PointwiseSampler:
    """(user, item, label) batches: each positive paired with
    ``n_neg`` sampled negatives (NCF protocol)."""

    def __init__(self, data: InteractionData, batch_pos: int = 256,
                 n_neg: int = 4, seed: int = 0):
        self.data = data
        self.batch_pos = batch_pos
        self.n_neg = n_neg
        self.rng = np.random.default_rng(seed)
        self.users = np.concatenate([
            np.full(len(s), u, np.int64)
            for u, s in enumerate(data.train_seqs) if len(s)])
        self.items = np.concatenate(
            [s for s in data.train_seqs if len(s)])

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        n = len(self.users)
        while True:
            idx = self.rng.integers(0, n, self.batch_pos)
            u_pos, i_pos = self.users[idx], self.items[idx]
            u_neg = np.repeat(u_pos, self.n_neg)
            i_neg = self.rng.integers(0, self.data.n_items,
                                      self.batch_pos * self.n_neg)
            users = np.concatenate([u_pos, u_neg])
            items = np.concatenate([i_pos, i_neg])
            labels = np.concatenate([
                np.ones(self.batch_pos, np.float32),
                np.zeros(self.batch_pos * self.n_neg, np.float32)])
            yield {"user_ids": users, "item_ids": items, "label": labels}


class SequenceSampler:
    """SASRec batches: (seq (B, L), pos (B, L), neg (B, L)) with 0 = pad
    and item ids shifted by +1 (0 reserved)."""

    def __init__(self, data: InteractionData, batch: int = 128,
                 maxlen: int = 50, seed: int = 0):
        self.data = data
        self.batch = batch
        self.maxlen = maxlen
        self.rng = np.random.default_rng(seed)
        self.valid_users = [u for u, s in enumerate(data.train_seqs)
                            if len(s) >= 2]

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        l = self.maxlen
        while True:
            users = self.rng.choice(self.valid_users, self.batch)
            seq = np.zeros((self.batch, l), np.int64)
            pos = np.zeros((self.batch, l), np.int64)
            neg = np.zeros((self.batch, l), np.int64)
            for row, u in enumerate(users):
                s = self.data.train_seqs[u] + 1          # shift: 0 = pad
                take = min(len(s) - 1, l)
                seq[row, l - take:] = s[-take - 1:-1]
                pos[row, l - take:] = s[-take:]
                neg[row, l - take:] = self.rng.integers(
                    1, self.data.n_items + 1, take)
            yield {"seq": seq, "pos": pos, "neg": neg}


class ShardedIterator:
    """Slices a global batch for one host: host h of H takes rows
    [h*B/H, (h+1)*B/H) — the multi-host input-pipeline contract."""

    def __init__(self, base: Iterator[Dict[str, np.ndarray]],
                 host_id: int, num_hosts: int):
        self.base = iter(base)
        self.host_id = host_id
        self.num_hosts = num_hosts

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = next(self.base)
        out = {}
        for k, v in batch.items():
            b = v.shape[0]
            if b % self.num_hosts:
                raise ValueError(
                    f"batch leaf {k!r} has {b} rows, not divisible over "
                    f"{self.num_hosts} hosts")
            per = b // self.num_hosts
            out[k] = v[self.host_id * per:(self.host_id + 1) * per]
        return out


class Prefetcher:
    """Background-thread prefetch so host-side sampling overlaps with
    device compute (the CPU analogue of an input pipeline)."""

    def __init__(self, base: Iterator, depth: int = 2):
        self.base = iter(base)
        self.q: queue_mod.Queue = queue_mod.Queue(maxsize=depth)
        self.thread = threading.Thread(target=self._fill, daemon=True)
        self.thread.start()

    def _fill(self):
        try:
            while True:
                self.q.put(next(self.base))
        except StopIteration:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item
