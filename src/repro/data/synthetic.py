"""Synthetic datasets, statistically matched to the paper's benchmarks.

The container is offline, so MovieLens-1M and the proprietary AAR set
are *regenerated*: interactions are drawn from a planted latent-factor
model with Zipf-distributed item popularity, which preserves the two
properties the paper's technique exploits — collaborative structure
(so models have signal to learn) and a power-law long tail (so MGQE's
frequency tiers matter).  Ids are frequency-sorted by construction
(id 0 = most popular), matching the framework convention.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np


def zipf_ids(rng: np.random.Generator, n: int, vocab: int,
             zipf_a: float) -> np.ndarray:
    """Truncated-power-law ids via inverse CDF, overflow-safe.

    ``zipf_a`` must be > 1.0: the inverse-CDF exponent is
    ``-1 / (zipf_a - 1)``, which diverges at 1.0 — there is no silent
    rescue to "some nearby distribution" (`not (a > 1)` also rejects
    NaN).
    """
    if not zipf_a > 1.0:
        raise ValueError(
            f"zipf_ids needs zipf_a > 1.0 (the truncated power law's "
            f"inverse CDF diverges at a <= 1.0), got {zipf_a}")
    u = rng.random(n)
    x = (1.0 - u) ** (-1.0 / (zipf_a - 1.0)) - 1.0
    x = np.minimum(x, float(vocab - 1))     # clip in float space (inf-safe)
    return x.astype(np.int64)


def open_loop_arrivals(rate_rps: float, duration_s: float = None,
                       n_requests: int = None, process: str = "poisson",
                       seed: int = 0) -> np.ndarray:
    """Arrival timestamps (seconds from stream start) for an OPEN-LOOP
    load generator: requests arrive on the generator's clock at a
    target ``rate_rps``, independent of how fast the server answers.

    A closed-loop driver (fire, wait, fire) implicitly slows its
    offered load whenever the server lags, so its measured latency
    hides exactly the queueing delay a latency SLO is about
    (coordinated omission); benchmarking "sustained throughput AT a
    p99" requires this open-loop shape
    (``launch/async_engine.drive_open_loop``).

    Exactly one of ``duration_s`` / ``n_requests`` sets the stream
    length (``duration_s`` implies ``round(rate_rps * duration_s)``
    requests — rate-driven, not count-driven).  ``process``:

    * ``"poisson"`` — i.i.d. exponential interarrivals (memoryless,
      the standard model of independent user traffic; bursts happen,
      which is what stresses a deadline-batched queue);
    * ``"deterministic"`` — fixed ``1/rate`` spacing (worst-case-free
      baseline; isolates service time from arrival burstiness).
    """
    if not rate_rps > 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    if (duration_s is None) == (n_requests is None):
        raise ValueError("pass exactly one of duration_s / n_requests")
    if n_requests is None:
        n_requests = int(round(rate_rps * duration_s))
    if n_requests < 1:
        raise ValueError(
            f"stream is empty: rate {rate_rps}/s over {duration_s}s")
    if process == "poisson":
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0 / rate_rps, n_requests)
        return np.cumsum(gaps)
    if process == "deterministic":
        return (1.0 + np.arange(n_requests)) / rate_rps
    raise ValueError(f"unknown arrival process {process!r} "
                     f"(want 'poisson' or 'deterministic')")


def zipf_open_loop_stream(vocab: int, rate_rps: float, duration_s: float,
                          req_batch: int, zipf_a: float = 1.2,
                          process: str = "poisson", seed: int = 0
                          ) -> Tuple[np.ndarray, List[np.ndarray]]:
    """Arrival-rate-driven power-law serving load: the open-loop
    arrival schedule of :func:`open_loop_arrivals` paired with
    Zipf(``zipf_a``) id batches of random size 1..``req_batch`` from
    :func:`zipf_request_stream`.  Returns ``(arrivals, requests)`` of
    equal length — the input :func:`launch.async_engine.drive_open_loop`
    replays against the async engine."""
    arrivals = open_loop_arrivals(rate_rps, duration_s=duration_s,
                                  process=process, seed=seed)
    requests = zipf_request_stream(vocab, len(arrivals), req_batch,
                                   zipf_a=zipf_a, seed=seed + 1)
    return arrivals, requests


def zipf_request_stream(vocab: int, n_requests: int, req_batch: int,
                        zipf_a: float = 1.2, seed: int = 0
                        ) -> List[np.ndarray]:
    """Power-law serving traffic: ``n_requests`` id batches of random
    size 1..``req_batch``, ids Zipf(``zipf_a``)-distributed over the
    frequency-sorted vocabulary (id 0 = hottest).  This is the request
    mix the ServingEngine's hot-row cache exists for — the head tier
    absorbs most lookups (``launch/engine.py::drive_zipf_stream``)."""
    rng = np.random.default_rng(seed)
    return [zipf_ids(rng, int(rng.integers(1, req_batch + 1)), vocab,
                     zipf_a)
            for _ in range(n_requests)]


# ----------------------------------------------------------------------
# MovieLens-1M-like implicit-feedback sequences
# ----------------------------------------------------------------------

@dataclasses.dataclass
class InteractionData:
    n_users: int
    n_items: int
    train_seqs: List[np.ndarray]     # per-user item sequence (time order)
    valid_item: np.ndarray           # (n_users,) withheld action
    test_item: np.ndarray            # (n_users,) withheld action
    item_counts: np.ndarray          # (n_items,) train popularity


def movielens_like(n_users: int = 6040, n_items: int = 3416,
                   mean_len: int = 96, latent_dim: int = 16,
                   zipf_a: float = 1.2, seed: int = 0) -> InteractionData:
    """~1M implicit-feedback interactions, 94%+ sparsity like ML-1M."""
    rng = np.random.default_rng(seed)
    # planted latent structure
    u_lat = rng.normal(size=(n_users, latent_dim)).astype(np.float32)
    i_lat = rng.normal(size=(n_items, latent_dim)).astype(np.float32)
    # popularity bias: Zipf over frequency-sorted ids
    pop = 1.0 / np.arange(1, n_items + 1) ** (zipf_a - 1.0)
    log_pop = np.log(pop / pop.sum())

    lens = np.clip(rng.geometric(1.0 / mean_len, size=n_users) + 4, 5,
                   min(600, n_items - 2))
    train_seqs, valid, test = [], np.zeros(n_users, np.int64), \
        np.zeros(n_users, np.int64)
    counts = np.zeros(n_items, np.int64)
    # score items per user: affinity + popularity; sample without replace
    for u in range(n_users):
        scores = i_lat @ u_lat[u] * 0.6 + log_pop * 2.0 \
            + rng.gumbel(size=n_items)
        take = int(lens[u])
        top = np.argpartition(-scores, take)[:take]
        seq = top[rng.permutation(take)]       # random temporal order
        train, v, t = seq[:-2], seq[-2], seq[-1]
        train_seqs.append(train.astype(np.int64))
        valid[u], test[u] = v, t
        np.add.at(counts, train, 1)
    # remap ids so that id order == popularity order (framework rule)
    order = np.argsort(-counts, kind="stable")
    remap = np.empty(n_items, np.int64)
    remap[order] = np.arange(n_items)
    train_seqs = [remap[s] for s in train_seqs]
    valid, test = remap[valid], remap[test]
    counts = counts[order]
    return InteractionData(n_users, n_items, train_seqs, valid, test, counts)


# ----------------------------------------------------------------------
# AAR-like item-to-item relevance pairs
# ----------------------------------------------------------------------

def aar_like(n_apps: int = 20000, n_pairs: int = 400000,
             latent_dim: int = 16, zipf_a: float = 1.3,
             seed: int = 1) -> Dict[str, np.ndarray]:
    """(app_a, app_b, score in [-100, 100]) relevance triples; 90/10
    train/eval split (paper §3.1)."""
    rng = np.random.default_rng(seed)
    lat = rng.normal(size=(n_apps, latent_dim)).astype(np.float32)
    p = 1.0 / np.arange(1, n_apps + 1) ** zipf_a
    p /= p.sum()
    a = rng.choice(n_apps, size=n_pairs, p=p)
    b = rng.choice(n_apps, size=n_pairs, p=p)
    sim = np.sum(lat[a] * lat[b], axis=1) / latent_dim ** 0.5
    score = np.clip(sim * 40 + rng.normal(scale=10, size=n_pairs), -100, 100)
    n_train = int(0.9 * n_pairs)
    return {
        "train_a": a[:n_train], "train_b": b[:n_train],
        "train_y": score[:n_train].astype(np.float32),
        "eval_a": a[n_train:], "eval_b": b[n_train:],
        "eval_y": score[n_train:].astype(np.float32),
        "n_apps": n_apps,
    }


# ----------------------------------------------------------------------
# Criteo-like CTR batches (AutoInt / DeepFM)
# ----------------------------------------------------------------------

def criteo_field_vocabs(n_sparse: int = 39) -> Tuple[int, ...]:
    """Power-law mix of field vocabularies, Criteo-style: a couple of
    huge id spaces, a middle band, and many small enum fields."""
    sizes = ([10_000_000] * 2 + [1_000_000] * 4 + [100_000] * 6
             + [10_000] * 9 + [1_000] * 9 + [100] * 9)
    if len(sizes) != 39:
        raise ValueError(f"criteo-style tier list has {len(sizes)} != 39 "
                         f"entries")
    return tuple(sizes[:n_sparse])


class CTRStream:
    """Infinite deterministic batch stream with a planted logistic
    teacher so CTR models have real signal to fit."""

    def __init__(self, vocab_sizes: Tuple[int, ...], batch: int,
                 zipf_a: float = 1.1, teacher_dim: int = 8, seed: int = 0):
        self.vocab_sizes = vocab_sizes
        self.batch = batch
        self.rng = np.random.default_rng(seed)
        self.zipf_a = zipf_a
        t_rng = np.random.default_rng(seed + 1)
        # hashed teacher embeddings (cheap for 10M vocabs)
        self.teacher = [t_rng.normal(size=(min(v, 4096), teacher_dim))
                        .astype(np.float32) for v in vocab_sizes]
        self.w = t_rng.normal(size=(len(vocab_sizes), teacher_dim)) \
            .astype(np.float32)

    def _sample_ids(self, vocab: int, n: int) -> np.ndarray:
        return zipf_ids(self.rng, n, vocab, self.zipf_a)

    def next_batch(self) -> Dict[str, np.ndarray]:
        ids = np.stack([self._sample_ids(v, self.batch)
                        for v in self.vocab_sizes], axis=1)   # (B, F)
        logit = np.zeros(self.batch, np.float32)
        for f in range(ids.shape[1]):
            e = self.teacher[f][ids[:, f] % self.teacher[f].shape[0]]
            logit += e @ self.w[f]
        p = 1.0 / (1.0 + np.exp(-(logit * 0.5 - 1.0)))
        label = (self.rng.random(self.batch) < p).astype(np.float32)
        return {"sparse_ids": ids, "label": label}

    def __iter__(self):
        while True:
            yield self.next_batch()


# ----------------------------------------------------------------------
# Two-tower retrieval interactions
# ----------------------------------------------------------------------

class RetrievalStream:
    def __init__(self, n_users: int, n_items: int, batch: int,
                 zipf_a: float = 1.2, seed: int = 0):
        self.n_users, self.n_items, self.batch = n_users, n_items, batch
        self.rng = np.random.default_rng(seed)
        self.zipf_a = zipf_a
        # empirical item sampling probability for logQ correction
        idx = np.arange(1, n_items + 1, dtype=np.float64)
        p = idx ** -zipf_a
        self.item_p = (p / p.sum()).astype(np.float64)

    def next_batch(self) -> Dict[str, np.ndarray]:
        u = self.rng.integers(0, self.n_users, self.batch)
        i = zipf_ids(self.rng, self.batch, self.n_items, self.zipf_a)
        logq = np.log(self.item_p[i]).astype(np.float32)
        return {"user_ids": u, "item_ids": i, "item_logq": logq}


# ----------------------------------------------------------------------
# BST behavior sequences
# ----------------------------------------------------------------------

class BehaviorSeqStream:
    def __init__(self, n_items: int, seq_len: int, batch: int,
                 zipf_a: float = 1.2, latent_dim: int = 8, seed: int = 0):
        self.n_items, self.seq_len, self.batch = n_items, seq_len, batch
        self.rng = np.random.default_rng(seed)
        self.zipf_a = zipf_a
        t_rng = np.random.default_rng(seed + 1)
        self.lat = t_rng.normal(size=(min(n_items, 8192), latent_dim)) \
            .astype(np.float32)

    def next_batch(self) -> Dict[str, np.ndarray]:
        b, l = self.batch, self.seq_len
        ids = zipf_ids(self.rng, b * (l + 1), self.n_items,
                       self.zipf_a).reshape(b, l + 1)
        hist, target = ids[:, :l], ids[:, l]
        h_lat = self.lat[hist % self.lat.shape[0]].mean(axis=1)
        t_lat = self.lat[target % self.lat.shape[0]]
        logit = np.sum(h_lat * t_lat, axis=1) * 2.0
        p = 1.0 / (1.0 + np.exp(-logit))
        label = (self.rng.random(b) < p).astype(np.float32)
        return {"hist_ids": hist, "target_id": target, "label": label}


# ----------------------------------------------------------------------
# PQ-structured retrieval corpus (recall benchmarks, DESIGN.md §8)
# ----------------------------------------------------------------------

def pq_clustered_corpus(n: int = 100_000, d: int = 64,
                        num_subspaces: int = 8, n_words: int = 16,
                        n_clusters: int = 64, p_mut: float = 0.25,
                        n_queries: int = 16, query_noise: float = 0.05,
                        seed: int = 0, cluster_zipf_a: float = 0.0
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Synthetic corpus for measuring retrieval recall vs the exact
    dense scan: (items (n, d) f32, queries (n_queries, d) f32).

    Items live exactly on a product code — per subspace each item takes
    one of ``n_words`` codeword sub-vectors — so a PQ codec with
    K >= ~4x n_words recovers the corpus losslessly and measured recall
    isolates the RETRIEVAL approximation (IVF probing), not quantizer
    noise.  Cluster structure for IVF comes from ``n_clusters``
    prototype tuples that items copy with per-subspace mutation prob
    ``p_mut``; code tuples are deduplicated (duplicates resampled
    uniformly) so top-k boundaries are not degenerate tie groups.
    Queries point along cluster prototypes plus noise — the
    concentrated-top-k regime IVF exists for.

    ``cluster_zipf_a`` > 1 draws cluster membership from the truncated
    power law instead of uniform — head clusters hold most of the
    corpus, the skew regime the bounded IVF list layout exists for
    (DESIGN.md §12).  0 (default) keeps cluster sizes uniform.
    """
    if d % num_subspaces:
        raise ValueError(
            f"dim {d} does not divide into {num_subspaces} subspaces")
    s = d // num_subspaces
    rng = np.random.default_rng(seed)
    books = rng.normal(size=(num_subspaces, n_words, s)).astype(np.float32)
    proto = rng.integers(0, n_words, (n_clusters, num_subspaces))
    if cluster_zipf_a:
        g = zipf_ids(rng, n, n_clusters, cluster_zipf_a)
    else:
        g = rng.integers(0, n_clusters, n)
    mut = rng.random((n, num_subspaces)) < p_mut
    code = np.where(mut, rng.integers(0, n_words, (n, num_subspaces)),
                    proto[g])
    # resample duplicates until every tuple is unique (a single pass
    # can re-collide; one residual duplicate at n=100k puts two
    # bit-identical scores on a top-k boundary and reads as recall loss)
    while True:
        _, first = np.unique(code, axis=0, return_index=True)
        if first.size == n:
            break
        dup = np.ones(n, bool)
        dup[first] = False
        code[dup] = rng.integers(0, n_words,
                                 (int(dup.sum()), num_subspaces))
    items = books[np.arange(num_subspaces)[None], code].reshape(n, d)
    qc = rng.integers(0, n_clusters, n_queries)
    qvec = books[np.arange(num_subspaces)[None], proto[qc]].reshape(
        n_queries, d)
    q = qvec / np.linalg.norm(qvec, axis=1, keepdims=True)
    q = q + query_noise * rng.normal(size=(n_queries, d))
    return items.astype(np.float32), q.astype(np.float32)
