"""Serving driver: export quantized artifacts, then serve batched
requests on the paper's Figure-1 path (codes + centroids, full table
discarded).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
        --prompt-len 32 --decode-steps 16 --batch 4
    PYTHONPATH=src python -m repro.launch.serve --arch two-tower-retrieval \
        --smoke --candidates 10000 --retrieval ivf_pq --nprobe 8 --topk 100

``--engine`` drives a request stream through the micro-batching
:class:`repro.launch.engine.ServingEngine` instead (device-resident
artifact, queued lookups padded to the decode kernel's block_b) and
reports lookups/second:

    PYTHONPATH=src python -m repro.launch.serve --arch deepfm --smoke \
        --engine --requests 200 --req-batch 64

``--async`` wraps the engine in the latency-SLO front-end
(:class:`repro.launch.async_engine.AsyncServingEngine`): an open-loop
Zipf stream offered at ``--arrival-rate`` req/s, deadline-batched
flushes (``--max-wait-us``), and a p50/p99/p999 latency report judged
against ``--slo-ms``:

    PYTHONPATH=src python -m repro.launch.serve --arch deepfm --smoke \
        --engine --async --arrival-rate 500 --max-wait-us 1000 --slo-ms 5

``--mesh data=2,model=2`` serves the engine's artifact *sharded*
(DESIGN.md §6): codes row-sharded over the ``model`` axis, codebooks
replicated, one shard_map decode fanned across the mesh per flush.
Off-TPU the requested device count is forced via
``--xla_force_host_platform_device_count`` (set before jax
initializes), so the same command works on a CPU dev box:

    PYTHONPATH=src python -m repro.launch.serve --arch deepfm --smoke \
        --engine --mesh data=2,model=2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core.types import KERNEL_BACKENDS


def parse_mesh(spec: str):
    """'data=2,model=2' -> (("data", "model"), (2, 2))."""
    axes, shape = [], []
    for part in spec.split(","):
        name, _, n = part.partition("=")
        if not n:
            raise ValueError(f"bad mesh axis {part!r}; want name=N")
        axes.append(name.strip())
        shape.append(int(n))
    return tuple(axes), tuple(shape)


def serve_lm(cfg, batch: int, prompt_len: int, decode_steps: int):
    from repro.core import Embedding
    from repro.models import lm
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    emb = Embedding(cfg.embedding)
    artifact = emb.export(params["embed"])
    full_bits = cfg.embedding.vocab_size * cfg.embedding.dim * 32
    print(f"embedding artifact: {emb.serving_size_bits()/8/1e6:.2f} MB "
          f"({100*emb.serving_size_bits()/full_bits:.1f}% of full)")

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)
    max_seq = prompt_len + decode_steps

    t0 = time.time()
    cache, logits = jax.jit(
        lambda p, t: lm.prefill(p, t, cfg, max_seq=max_seq,
                                embed_artifact=artifact)
    )(params, prompts)
    print(f"prefill: {time.time()-t0:.2f}s; logits {logits.shape}")

    decode = jax.jit(
        lambda p, c, t: lm.decode_step(p, c, t, cfg,
                                       embed_artifact=artifact))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(decode_steps):
        cache, logits = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decoded {decode_steps} steps x B={batch} in {dt:.2f}s "
          f"({batch*decode_steps/dt:.1f} tok/s); sample: "
          f"{np.asarray(jnp.stack(out, 1))[0][:8]}")


def serve_retrieval(cfg, n_candidates: int, index_kind: str = "flat_pq",
                    nprobe: int = 8, topk: int = 100,
                    n_requests: int = 50, req_batch: int = 16,
                    backend=None, host_staged: bool = False):
    """Top-k candidate retrieval through the index registry + the
    micro-batching RetrievalEngine (DESIGN.md §8).

    ``host_staged`` keeps the O(corpus) list tables in host memory and
    stages only probed lists per flush (DESIGN.md §12)."""
    from repro.launch.engine import RetrievalEngine
    from repro.models.recsys.two_tower import TwoTower
    from repro.retrieval import IndexConfig, suggest_nlist
    model = TwoTower(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = min(n_candidates, cfg.n_items)
    item_ids = jnp.arange(n, dtype=jnp.int32)
    # nlist ≈ √N balances probed work against list length; the old
    # min(64, n // 64) cap left a 10M corpus probing 156k-row lists
    nlist = suggest_nlist(n, nprobe)
    icfg = IndexConfig(kind=index_kind, num_subspaces=8, num_centroids=64,
                       nlist=nlist, nprobe=min(nprobe, nlist),
                       kernel_backend=backend)

    # offline: build the index over the PQ-coded candidate tower outputs
    t0 = time.time()
    index, artifact = model.build_index(jax.random.PRNGKey(1), params,
                                        item_ids, icfg)
    code_mb = sum(np.asarray(artifact[name]).nbytes
                  for name in index.rows_leaves) / 1e6
    print(f"{index_kind} index built in {time.time()-t0:.1f}s: "
          f"{code_mb:.1f} MB corpus rows vs "
          f"{n*cfg.tower_mlp[-1]*4/1e6:.1f} MB dense"
          + (f" (nlist={icfg.nlist}, nprobe={icfg.nprobe})"
             if index_kind == "ivf_pq" else ""))

    # online: stream user batches through the engine; top-k ids + scores
    engine = RetrievalEngine(index, artifact, k=topk, block_q=16,
                             host_staged=host_staged)
    rng = np.random.default_rng(0)
    users = [rng.integers(0, cfg.n_users,
                          int(rng.integers(1, req_batch + 1)))
             for _ in range(n_requests)]
    user_vec = jax.jit(lambda p, u: model.user_vec(p, u)[0])
    reqs = [np.asarray(user_vec(params, jnp.asarray(u, jnp.int32)))
            for u in users]
    engine.serve_stream(reqs)                  # warm pass: jit traces
    engine.stats_ = type(engine.stats_)()
    st = engine.serve_stream(reqs)
    print(f"engine: {st.requests} requests / {st.lookups} queries in "
          f"{st.flushes} flushes, {st.seconds:.3f}s -> "
          f"{st.lookups_per_s:,.0f} queries/s x top-{topk}")
    if host_staged:
        print(f"host-staged: {engine.staged_mbytes:.2f} MB staged over "
              f"{st.flushes * 2} flushes (warm+measured) vs "
              f"{code_mb:.1f} MB device-resident")

    # recall vs the exact dense scan, one probe batch
    scores, ids = model.retrieval_topk(params, index, artifact,
                                       jnp.arange(8, dtype=jnp.int32),
                                       topk)
    cand_vecs = model.encode_items(params, item_ids)
    u8, _ = model.user_vec(params, jnp.arange(8, dtype=jnp.int32))
    ex = np.argsort(-np.asarray(u8 @ cand_vecs.T), axis=1)[:, :topk]
    rec = np.mean([len(set(np.asarray(ids)[b].tolist())
                       & set(ex[b].tolist())) / topk for b in range(8)])
    print(f"recall@{topk} vs exact dense scan: {rec:.3f}")


def serve_ctr(cfg, batch: int):
    from repro.launch.cells import _recsys_model
    model = _recsys_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if cfg.model == "bst":
        artifacts = model.item_emb.export(params["item_emb"])
        rng = np.random.default_rng(0)
        b = {"hist_ids": jnp.asarray(
                 rng.integers(0, cfg.n_items, (batch, cfg.seq_len)),
                 jnp.int32),
             "target_id": jnp.asarray(
                 rng.integers(0, cfg.n_items, batch), jnp.int32)}
    else:
        artifacts = model.fields.export(params["fields"])
        rng = np.random.default_rng(0)
        ids = np.stack([rng.integers(0, v, batch)
                        for v in cfg.field_vocab_sizes], 1)
        b = {"sparse_ids": jnp.asarray(ids, jnp.int32)}
    t0 = time.time()
    scores = jax.jit(lambda p, a, bb: model.serve(p, a, bb))(
        params, artifacts, b)
    jax.block_until_ready(scores)
    print(f"served B={batch} in {time.time()-t0:.2f}s; "
          f"scores mean {float(jnp.mean(scores)):.4f}")


def serve_async_engine(engine, vocab_size: int, req_batch: int,
                       max_wait_us: float, arrival_rate: float,
                       slo_ms: float, duration_s: float,
                       zipf_a: float, hot_refresh: int = 0):
    """Open-loop latency demo of the async front-end (DESIGN.md §10):
    wrap the engine, replay a Zipf arrival schedule at ``arrival_rate``
    requests/s, report the latency histogram and the SLO verdict."""
    from repro.data.synthetic import zipf_open_loop_stream
    from repro.launch.async_engine import (AsyncServingEngine,
                                           drive_open_loop)
    arrivals, reqs = zipf_open_loop_stream(
        vocab_size, rate_rps=arrival_rate, duration_s=duration_s,
        req_batch=req_batch, zipf_a=zipf_a)
    with AsyncServingEngine(engine, max_wait_us=max_wait_us,
                            refresh_every=hot_refresh) as aeng:
        # warm pass: pay every padded-shape jit trace before measuring
        # (an open-loop p99 with a compile in it measures the compiler)
        drive_open_loop(aeng, reqs, arrivals)
        aeng.drain()
        aeng.reset_stats()
        st = drive_open_loop(aeng, reqs, arrivals)
    offered = len(reqs) / arrivals[-1]
    print(f"async engine: {st.requests} requests ({st.lookups} lookups) "
          f"open-loop at {offered:,.0f} req/s over "
          f"{st.wall_seconds:.2f}s wall -> "
          f"{st.sustained_lookups_per_s:,.0f} lookups/s sustained")
    print(f"  flush triggers: {st.flushes_full} block-full / "
          f"{st.flushes_deadline} deadline({max_wait_us:.0f}us) / "
          f"{st.flushes_drain} drain; device time "
          f"{st.seconds:.3f}s of {st.wall_seconds:.2f}s wall")
    print(f"  latency p50 {st.p50_ms:.2f} ms | p99 {st.p99_ms:.2f} ms | "
          f"p999 {st.p999_ms:.2f} ms")
    ok = st.p99_ms <= slo_ms
    print(f"  SLO p99 <= {slo_ms:.1f} ms: {'MET' if ok else 'MISSED'}")
    return st


def serve_engine(family, cfg, n_requests: int, req_batch: int,
                 backend=None, max_queue: int = 4096, mesh_spec=None,
                 hot_rows: int = 0, hot_refresh: int = 0,
                 zipf_a: float = 0.0, use_async: bool = False,
                 max_wait_us: float = 1000.0, arrival_rate: float = 500.0,
                 slo_ms: float = 5.0, duration_s: float = 2.0):
    """Request-stream demo of the micro-batching engine: N requests of
    random size <= req_batch against the arch's main embedding table.

    ``hot_rows`` enables the hot-row decode-ahead cache (DESIGN.md §9),
    ``hot_refresh`` re-points it at observed traffic every N flushes,
    and ``zipf_a`` > 1 switches the stream from uniform to power-law
    ids — the traffic mix the cache exists for."""
    from repro.core import Embedding
    from repro.launch.engine import (ServingEngine, drive_random_stream,
                                     drive_zipf_stream,
                                     embedding_config_of_arch)
    ecfg = embedding_config_of_arch(family, cfg)
    emb = Embedding(ecfg)
    params = emb.init(jax.random.PRNGKey(0))
    artifact = emb.export(params)
    full_bits = ecfg.vocab_size * ecfg.dim * 32
    print(f"engine table: kind={ecfg.kind} vocab={ecfg.vocab_size} "
          f"d={ecfg.dim}; artifact "
          f"{emb.serving_size_bits()/8/1e6:.2f} MB "
          f"({100*emb.serving_size_bits()/full_bits:.1f}% of full)")

    mesh = None
    if mesh_spec is not None:
        axes, shape = parse_mesh(mesh_spec)
        need = int(np.prod(shape))
        if jax.device_count() < need:
            raise SystemExit(
                f"--mesh {mesh_spec} needs {need} devices, found "
                f"{jax.device_count()} (XLA_FLAGS was set too late? "
                f"export XLA_FLAGS=--xla_force_host_platform_device_"
                f"count={need})")
        mesh = jax.make_mesh(shape, axes)
        model_n = dict(mesh.shape).get("model", 1)
        # size report only for quantized artifacts; other kinds fall
        # through so ServingEngine raises its designed ValueError.
        # Placement info comes off the scheme's artifact spec — the
        # leaves tagged rows=True are what gets row-sharded.
        if emb.scheme.supports_sharded_codes:
            spec = emb.scheme.artifact_leaves()
            mb = lambda ls: sum(l.storage_bits for l in ls) / 8 / 1e6
            codes_mb = mb([l for l in spec if l.rows])
            cb_mb = mb([l for l in spec if not l.rows])
            print(f"mesh {dict(mesh.shape)}: codes {codes_mb:.2f} MB "
                  f"row-sharded x{model_n} -> {codes_mb/model_n:.2f} "
                  f"MB/shard, + {cb_mb:.3f} MB codebooks replicated "
                  f"per device")

    engine = ServingEngine(emb, artifact, backend=backend,
                           max_queue=max_queue, mesh=mesh,
                           hot_rows=hot_rows or None,
                           hot_refresh_every=hot_refresh)
    if engine.hot_rows:
        # true block width comes off the scheme's spec (param_dtype
        # aware — bf16 tables cache bf16 rows)
        width = jnp.dtype(engine.emb.scheme.hot_dtype).itemsize
        hot_mb = engine.hot_rows * ecfg.dim * width / 1e6
        print(f"hot-row cache: {engine.hot_rows} rows pre-decoded "
              f"({hot_mb:.2f} MB dense, replicated)"
              + (f", refresh every {hot_refresh} flushes"
                 if hot_refresh else ""))
    if use_async:
        return serve_async_engine(engine, ecfg.vocab_size, req_batch,
                                  max_wait_us=max_wait_us,
                                  arrival_rate=arrival_rate,
                                  slo_ms=slo_ms, duration_s=duration_s,
                                  zipf_a=zipf_a or 1.2,
                                  hot_refresh=(hot_refresh
                                               if hot_rows else 0))
    if zipf_a:
        st = drive_zipf_stream(engine, ecfg.vocab_size, n_requests,
                               req_batch, zipf_a=zipf_a)
    else:
        st = drive_random_stream(engine, ecfg.vocab_size, n_requests,
                                 req_batch)
    print(f"engine: {st.requests} requests / {st.lookups} lookups in "
          f"{st.flushes} flushes, {st.seconds:.3f}s -> "
          f"{st.lookups_per_s:,.0f} lookups/s "
          f"(block_b={engine.block_b} x {engine.data_shards} data "
          f"shard(s), pad overhead "
          f"{100*(st.padded_lookups/st.lookups-1) if st.lookups else 0.0:.1f}%)")
    if engine.hot_rows:
        print(f"hot cache: hit rate {st.hit_rate:.1%} "
              f"({st.hot_hits}/{st.lookups} lookups cache-served; "
              f"{st.decoded_lookups} rows through the fused decode vs "
              f"{st.padded_lookups} without the cache; "
              f"{st.hot_refreshes} refresh(es))")
    return st


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--candidates", type=int, default=10000)
    ap.add_argument("--retrieval", default="flat_pq",
                    help="retrieval index kind for two-tower serving "
                         "(registered kinds: flat_pq, ivf_pq, ...)")
    ap.add_argument("--nprobe", type=int, default=8,
                    help="ivf_pq: coarse lists probed per query")
    ap.add_argument("--topk", type=int, default=100,
                    help="candidates returned per retrieval query")
    ap.add_argument("--host-staged", action="store_true",
                    help="retrieval: keep the list tables in host "
                         "memory; stage only probed lists per flush "
                         "(ivf_pq, DESIGN.md §12)")
    ap.add_argument("--engine", action="store_true",
                    help="drive the micro-batching ServingEngine")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--req-batch", type=int, default=64)
    ap.add_argument("--hot-rows", type=int, default=0,
                    help="pre-decode this many head rows into the "
                         "engine's hot-row cache (0 = off; DESIGN.md §9)")
    ap.add_argument("--hot-refresh", type=int, default=0,
                    help="re-point the hot cache at observed traffic "
                         "every N flushes (0 = static head-id set)")
    ap.add_argument("--zipf-a", type=float, default=0.0,
                    help="drive the engine with Zipf(a) power-law ids "
                         "instead of uniform (needs a > 1.0)")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="serve through the AsyncServingEngine front-end "
                         "(DESIGN.md §10): open-loop arrival-rate-driven "
                         "stream, deadline-batched flushes, p50/p99/p999 "
                         "latency report against --slo-ms")
    ap.add_argument("--max-wait-us", type=float, default=1000.0,
                    help="async: flush deadline — a partial batch fires "
                         "once its oldest request has waited this long")
    ap.add_argument("--arrival-rate", type=float, default=500.0,
                    help="async: open-loop offered load, requests/second "
                         "(Poisson interarrivals)")
    ap.add_argument("--slo-ms", type=float, default=5.0,
                    help="async: p99 latency SLO the report is judged "
                         "against")
    ap.add_argument("--duration", type=float, default=2.0,
                    help="async: measured stream length in seconds")
    ap.add_argument("--kernel-backend", default=None,
                    choices=KERNEL_BACKENDS)
    ap.add_argument("--mesh", default=None, metavar="data=2,model=2",
                    help="serve the engine's artifact sharded over this "
                         "mesh (codes over 'model', batch over the rest)")
    args = ap.parse_args()

    if args.mesh and not args.engine:
        ap.error("--mesh requires --engine")
    if args.mesh:
        # must happen before the first jax call of the process
        from repro.launch.mesh import force_host_device_count
        _, shape = parse_mesh(args.mesh)
        force_host_device_count(int(np.prod(shape)))

    family, cfg = get_arch(args.arch, smoke=args.smoke)
    if (args.hot_rows or args.hot_refresh or args.zipf_a) \
            and not args.engine:
        ap.error("--hot-rows/--hot-refresh/--zipf-a require --engine")
    if args.hot_refresh and not args.hot_rows:
        ap.error("--hot-refresh needs a cache to refresh; pass "
                 "--hot-rows N")
    if args.zipf_a and args.zipf_a <= 1.0:
        ap.error(f"--zipf-a must be > 1.0 (the truncated power law "
                 f"diverges at a <= 1), got {args.zipf_a}")
    if args.use_async and not args.engine:
        ap.error("--async requires --engine")
    if args.use_async and args.arrival_rate <= 0:
        ap.error(f"--arrival-rate must be > 0 (open-loop load is "
                 f"rate-driven), got {args.arrival_rate}")
    if args.host_staged and args.engine:
        ap.error("--host-staged applies to the retrieval serving path, "
                 "not --engine")
    if args.engine:
        serve_engine(family, cfg, args.requests, args.req_batch,
                     backend=args.kernel_backend, mesh_spec=args.mesh,
                     hot_rows=args.hot_rows, hot_refresh=args.hot_refresh,
                     zipf_a=args.zipf_a, use_async=args.use_async,
                     max_wait_us=args.max_wait_us,
                     arrival_rate=args.arrival_rate, slo_ms=args.slo_ms,
                     duration_s=args.duration)
    elif family == "lm":
        serve_lm(cfg, args.batch, args.prompt_len, args.decode_steps)
    elif cfg.model == "two_tower":
        serve_retrieval(cfg, args.candidates, index_kind=args.retrieval,
                        nprobe=args.nprobe, topk=args.topk,
                        backend=args.kernel_backend,
                        host_staged=args.host_staged)
    elif family == "recsys":
        serve_ctr(cfg, args.batch)
    else:
        raise SystemExit("mace has no serving path (train-only arch)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
