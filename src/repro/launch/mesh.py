"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module touches no jax device state — required because the dry-run must
set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import os

import jax


def force_host_device_count(n: int) -> None:
    """Give CPU boxes ``n`` XLA host devices for mesh tests/demos.

    Appends ``--xla_force_host_platform_device_count`` to XLA_FLAGS;
    an existing setting is left alone.  Must run before the process's
    first jax call (backend init reads XLA_FLAGS once); inert when
    real accelerators are attached.  Shared by ``launch/serve.py
    --mesh`` and ``benchmarks/kernel_bench.py``.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}").strip()


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4, *,
                    multi_pod: bool = False):
    """Small mesh for CI-grade sharding tests (8 host devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
