"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch autoint --steps 200
    PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

On CPU containers use --smoke (reduced config); the full configs are
for real TPU slices (the dry-run proves they shard).  The loop includes
checkpoint/auto-resume, straggler detection, and optional failure
injection (--fail-at) to exercise the fault-tolerance path end to end.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.train import optimizer as opt_lib
from repro.train.loop import LoopConfig, fit
from repro.train.optimizer import TrainState
from repro.train.resilience import FailureInjector


def _lm_setup(cfg, batch: int, seq: int):
    from repro.models import lm
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    ocfg = opt_lib.OptimizerConfig(kind="adamw", lr=3e-4,
                                   schedule="linear_warmup_cosine",
                                   warmup_steps=20, total_steps=1000)
    state = TrainState.create(ocfg, params)
    step = opt_lib.make_step_fn(ocfg, functools.partial(lm.loss_fn, cfg=cfg))

    def data():
        rng = np.random.default_rng(0)
        while True:
            toks = rng.integers(0, cfg.vocab_size, (batch, seq + 1))
            yield {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                   "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    return state, step, data()


def _recsys_setup(cfg, batch: int):
    from repro.data.synthetic import CTRStream
    from repro.launch.cells import _recsys_model
    model = _recsys_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = opt_lib.OptimizerConfig(kind="adagrad", lr=1e-2)
    state = TrainState.create(ocfg, params)
    step = opt_lib.make_step_fn(ocfg, model.loss)

    if cfg.model == "two_tower":
        def data():
            rng = np.random.default_rng(0)
            logq = np.log(1.0 / cfg.n_items)
            while True:
                yield {"user_ids": jnp.asarray(
                           rng.integers(0, cfg.n_users, batch), jnp.int32),
                       "item_ids": jnp.asarray(
                           rng.integers(0, cfg.n_items, batch), jnp.int32),
                       "item_logq": jnp.full((batch,), logq, jnp.float32)}
        return state, step, data()
    if cfg.model == "bst":
        def data():
            rng = np.random.default_rng(0)
            while True:
                yield {"hist_ids": jnp.asarray(
                           rng.integers(0, cfg.n_items,
                                        (batch, cfg.seq_len)), jnp.int32),
                       "target_id": jnp.asarray(
                           rng.integers(0, cfg.n_items, batch), jnp.int32),
                       "label": jnp.asarray(
                           rng.random(batch) < 0.3, jnp.float32)}
        return state, step, data()
    stream = CTRStream(cfg.field_vocab_sizes, batch)
    def data():
        for b in stream:
            yield {"sparse_ids": jnp.asarray(b["sparse_ids"], jnp.int32),
                   "label": jnp.asarray(b["label"], jnp.float32)}
    return state, step, data()


def _gnn_setup(cfg, batch: int):
    from repro.data.graph import molecule_batch
    from repro.models.gnn.mace import MACE
    model = MACE(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = opt_lib.OptimizerConfig(kind="adam", lr=1e-3)
    state = TrainState.create(ocfg, params)

    def loss_fn(p, graph):
        g = dict(graph)
        n_graphs = int(g.pop("n_graphs"))
        return model.energy_loss(p, dict(g, n_graphs=n_graphs))

    def step(state, graph):
        g = {k: v for k, v in graph.items()}
        (loss, metrics), grads = jax.value_and_grad(
            model.energy_loss, has_aux=True)(state.params, g)
        new_p, new_o = opt_lib.apply_updates(ocfg, state.params, grads,
                                             state.opt_state)
        return TrainState(new_p, new_o), metrics

    def data():
        seed = 0
        while True:
            g = molecule_batch(n_graphs=min(batch, 32), n_atoms=12,
                               n_edges=24, n_species=cfg.num_species,
                               seed=seed)
            seed += 1
            yield {k: (jnp.asarray(v) if not np.isscalar(v) else v)
                   for k, v in g.items()}
    return state, step, data()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a crash at this step (tests restart)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    family, cfg = get_arch(args.arch, smoke=args.smoke)
    if family == "lm":
        state, step, data = _lm_setup(cfg, args.batch, args.seq)
    elif family == "recsys":
        state, step, data = _recsys_setup(cfg, args.batch)
    else:
        state, step, data = _gnn_setup(cfg, args.batch)

    injector = (FailureInjector(fail_at_steps=[args.fail_at])
                if args.fail_at else None)
    lcfg = LoopConfig(total_steps=args.steps, log_every=args.log_every,
                      ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                      metrics_hook=lambda s, m: print(
                          f"step {s}: " + " ".join(
                              f"{k}={v:.4f}" for k, v in m.items()
                              if k not in ("step",))))
    t0 = time.time()
    state, hist = fit(state, step, data, lcfg, injector=injector)
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s; "
          f"final loss {hist[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
