"""Launchers: production mesh, multi-pod dry-run, train/serve entry
points.  dryrun.py sets XLA_FLAGS for 512 host devices — nothing else
in the package may touch jax device state at import time."""
