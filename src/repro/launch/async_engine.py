"""Asynchronous latency-SLO serving front-end (DESIGN.md §10).

The synchronous engines (`launch/engine.py`) answer "how many lookups
per second can one fused call sustain"; production serving must answer
"what latency does a REQUEST see while traffic arrives on its own
clock".  This module adds the missing layer, shaped like the
worker/actor split of long-lived serving systems (a dedicated worker
owns the device-resident state and a submission thread never touches
device work):

  * :class:`AsyncServingEngine` wraps any micro-batch engine
    (``ServingEngine`` or ``RetrievalEngine``).  ``submit()`` appends
    to a host-side queue and returns a ``Future`` immediately; a
    dedicated flush thread runs the fused device call and resolves the
    futures.  Submitters NEVER block on device work.
  * **Deadline-based adaptive batching** — a flush fires when the
    queue reaches a block's worth of rows ("full") OR when the oldest
    queued request has waited ``max_wait_us`` ("deadline"), whichever
    comes first.  The trigger logic is a pure state machine
    (:class:`FlushPolicy`) so tests drive it with a fake clock.
  * **Per-request latency** — submit→result, recorded into a
    fixed log-bucket :class:`~repro.launch.latency.LatencyHistogram`
    (O(1)/request, mergeable) on :class:`AsyncEngineStats`, which
    extends ``EngineStats`` with p50/p99/p999 readouts.
  * **Background hot-row refresh** — EMA re-ranking and the O(C) block
    re-decode run on a refresher thread; the rebuilt cache state is
    swapped in atomically between flushes
    (``ServingEngine.prepare_hot_rows`` / ``install_hot_rows``), so a
    refresh never stalls the flush path.
  * :func:`drive_open_loop` replays an arrival schedule open-loop
    (submission times come from the generator's clock, not from
    completions), which is what makes a measured p99 honest — a
    closed-loop driver would slow its offered load whenever the engine
    lags and hide exactly the queueing delay an SLO is about
    (coordinated omission).

The synchronous API is untouched: the wrapper only calls the inner
engine's public ``submit``/``flush`` from its single flush thread.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Optional, Sequence

import jax
import numpy as np

from repro.launch.engine import EngineStats, ServingEngine
from repro.launch.latency import LatencyHistogram

__all__ = ["AsyncEngineStats", "AsyncServingEngine", "FlushPolicy",
           "drive_open_loop"]


class FlushPolicy:
    """Deadline-based adaptive-batching trigger, as a pure state
    machine over ``(pending rows, oldest submit time, now)``.

    The flush thread owns one instance; tests drive it directly with a
    fake clock.  Transitions:

      * ``on_submit(n_rows, now)`` — rows join the queue; the deadline
        clock starts when the queue goes non-empty.
      * ``decision(now, forced=False)`` — ``"full"`` when pending rows
        reach ``block_rows`` (a whole kernel block is ready: waiting
        longer adds latency but no batching efficiency), else
        ``"deadline"`` once the OLDEST request has waited
        ``max_wait_s`` (its latency budget is being spent on idling),
        else ``"drain"`` when a flush is being forced (drain/close),
        else ``None`` (keep waiting).  Full wins over deadline: both
        true means the queue filled during the wait, and the flush is
        the same either way — the label records why it fired.
      * ``timeout(now)`` — how long the flush thread may sleep before
        the deadline can possibly fire (None while the queue is empty).
      * ``on_flush(now)`` — the queue was taken; reset.
    """

    def __init__(self, block_rows: int, max_wait_s: float):
        if block_rows < 1:
            raise ValueError(f"block_rows must be >= 1, got {block_rows}")
        if not max_wait_s >= 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self.block_rows = int(block_rows)
        self.max_wait_s = float(max_wait_s)
        self.rows = 0
        self.oldest: Optional[float] = None

    def on_submit(self, n_rows: int, now: float) -> None:
        if self.rows == 0:
            self.oldest = now
        self.rows += int(n_rows)

    def decision(self, now: float, forced: bool = False) -> Optional[str]:
        if self.rows <= 0:
            return None
        if self.rows >= self.block_rows:
            return "full"
        if now - self.oldest >= self.max_wait_s:
            return "deadline"
        if forced:
            return "drain"
        return None

    def timeout(self, now: float) -> Optional[float]:
        if self.rows <= 0:
            return None
        return max(0.0, self.oldest + self.max_wait_s - now)

    def on_flush(self, now: float) -> None:
        self.rows = 0
        self.oldest = None


@dataclasses.dataclass
class AsyncEngineStats(EngineStats):
    """``EngineStats`` plus the async front-end's request-level view.

    The wrapper installs ONE instance as the inner engine's ``stats_``,
    so the inherited counters (lookups, flushes, device ``seconds``,
    hot-cache hits) accumulate exactly as in synchronous serving, and
    the async fields ride along:

      * ``latency`` — submit→result histogram (one sample per request);
        ``p50_ms``/``p99_ms``/``p999_ms`` read it (NaN when empty);
      * ``flushes_full`` / ``flushes_deadline`` / ``flushes_drain`` —
        which trigger fired each flush (their sum == ``flushes``);
      * ``wall_seconds`` — open-loop stream wall time (set by
        :func:`drive_open_loop`; device ``seconds`` only counts time
        inside fused calls), feeding ``sustained_lookups_per_s``.

    Every derived readout is a property, so ``as_dict()`` exports it
    through the base class's property registry with no re-listing.
    """
    submitted: int = 0
    flushes_full: int = 0
    flushes_deadline: int = 0
    flushes_drain: int = 0
    wall_seconds: float = 0.0
    latency: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)

    @property
    def p50_ms(self) -> float:
        return self.latency.p50_ms

    @property
    def p99_ms(self) -> float:
        return self.latency.p99_ms

    @property
    def p999_ms(self) -> float:
        return self.latency.p999_ms

    @property
    def sustained_lookups_per_s(self) -> float:
        """Completed lookups over stream WALL time (queueing included)
        — the open-loop throughput a latency SLO is stated against."""
        return (self.lookups / self.wall_seconds
                if self.wall_seconds > 0 else 0.0)


class AsyncServingEngine:
    """Asynchronous front-end over a micro-batch engine.

    Parameters
    ----------
    engine:
        A ``ServingEngine`` or ``RetrievalEngine`` (anything with the
        ``_MicroBatchEngine`` submit/flush contract).  The wrapper
        becomes its only caller; its ``stats_`` is replaced with a
        shared :class:`AsyncEngineStats`.
    max_wait_us:
        Deadline for the oldest queued request before a partial flush
        fires.  The knob trades tail latency against batching: 0 makes
        every submit flush-eligible immediately (smallest batches,
        lowest queueing delay), large values converge on block-full
        batching (best device efficiency, worst p99 at low rates).
    max_block_rows:
        Row threshold for the "full" trigger; defaults to the inner
        engine's ``pad_multiple`` (one kernel block per data shard) —
        beyond that a flush pads to the next block anyway, so waiting
        buys nothing.
    refresh_every:
        When > 0 (ServingEngine with a hot-row cache): every N flushes
        the refresher thread re-ranks the EMA counters, re-decodes the
        hot block OFF the flush path, and swaps it in between flushes.
        The inner engine's own in-flush auto-refresh is disabled and
        EMA tracking enabled.
    clock:
        Monotonic time source (injectable for deterministic tests).
    """

    def __init__(self, engine, max_wait_us: float = 1000.0,
                 max_block_rows: Optional[int] = None,
                 refresh_every: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        self.engine = engine
        self.clock = clock
        self.policy = FlushPolicy(
            block_rows=(engine.pad_multiple if max_block_rows is None
                        else max_block_rows),
            max_wait_s=float(max_wait_us) * 1e-6)
        self.stats_ = AsyncEngineStats()
        engine.stats_ = self.stats_      # shared: inner flush accumulates
        self.refresh_every = int(refresh_every)
        if self.refresh_every:
            if not (isinstance(engine, ServingEngine) and engine.hot_rows):
                raise ValueError(
                    "refresh_every needs a ServingEngine with a hot-row "
                    "cache (hot_rows > 0)")
            # the refresher thread owns the cadence now; in-flush
            # refresh would put the O(C) re-decode back ON the flush
            # path, the exact thing this engine exists to avoid
            engine.hot_refresh_every = 0
            engine.hot_track_freq = True

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)   # flush thread waits
        self._idle = threading.Condition(self._lock)   # drain/refresh wait
        self._pending: List[tuple] = []    # (request, Future, t_submit)
        self._inflight = False
        self._force = False
        self._stop = False
        self._flusher = threading.Thread(
            target=self._flush_loop, name="async-engine-flush", daemon=True)
        self._refresh_evt = threading.Event()
        self._refresher = None
        if self.refresh_every:
            self._refresher = threading.Thread(
                target=self._refresh_loop, name="async-engine-refresh",
                daemon=True)
            self._refresher.start()
        self._flusher.start()

    # ------------------------------------------------------------ submit
    def submit(self, request) -> Future:
        """Enqueue one request; returns a Future resolving to the
        request's result rows — host (numpy) arrays, value-identical to
        what the synchronous engine's flush returns for the same
        request.  Never blocks on device work: the submit path is a
        numpy coerce + a host-side queue append (one device upload
        happens per FLUSH, for the whole concatenated batch, on the
        flush thread)."""
        arr = self.engine._coerce_host(request)
        fut: Future = Future()
        now = self.clock()
        with self._work:
            if self._stop:
                raise RuntimeError("AsyncServingEngine is closed")
            self._pending.append((arr, fut, now))
            self.policy.on_submit(arr.shape[0], now)
            self.stats_.submitted += 1
            self._work.notify()
        return fut

    def lookup(self, request, timeout: Optional[float] = None):
        """Synchronous convenience: ``submit(...).result()``."""
        return self.submit(request).result(timeout=timeout)

    @property
    def pending(self) -> int:
        with self._lock:
            return self.policy.rows

    # ------------------------------------------------------- flush thread
    def _flush_loop(self) -> None:
        while True:
            with self._work:
                reason = None
                while reason is None:
                    now = self.clock()
                    reason = self.policy.decision(
                        now, forced=self._force or self._stop)
                    if reason is None:
                        if self._stop:
                            return           # closed and drained
                        self._work.wait(self.policy.timeout(now))
                # take whole requests until a block's worth of rows is
                # reached — NOT the entire backlog.  An uncapped take
                # after any stall produces an arbitrary padded shape,
                # and every new padded shape is an XLA recompile on the
                # flush path (hundreds of ms), which grows the backlog
                # further; bounded takes keep the padded shapes to a
                # couple of warmable sizes and drain a backlog as a
                # sequence of steady-state flushes instead.
                k, rows = 0, 0
                while k < len(self._pending) and rows < self.policy.block_rows:
                    rows += self._pending[k][0].shape[0]
                    k += 1
                batch, self._pending = self._pending[:k], self._pending[k:]
                if self._pending:
                    self.policy.rows -= rows
                    self.policy.oldest = self._pending[0][2]
                else:
                    self.policy.on_flush(self.clock())
                field = {"full": "flushes_full",
                         "deadline": "flushes_deadline",
                         "drain": "flushes_drain"}[reason]
                setattr(self.stats_, field,
                        getattr(self.stats_, field) + 1)
                self._inflight = True
            # device work OUTSIDE the lock: submitters keep enqueueing.
            # The whole batch is assembled host-side and goes through
            # the inner engine as ONE padded call (``run_flat``): one
            # host->device upload, one fused call, one device->host
            # transfer — then the result is scattered back to futures
            # as zero-copy numpy views.  The per-request alternative
            # (inner submit per request) costs an XLA dispatch per
            # request on the coerce AND on the result split, which
            # alone is milliseconds of wall time per flush.
            err, results = None, []
            try:
                sizes = [arr.shape[0] for arr, _, _ in batch]
                flat = (batch[0][0] if len(batch) == 1 else
                        np.concatenate([arr for arr, _, _ in batch]))
                n_valid = int(flat.shape[0])
                out = self.engine.run_flat(flat, n_valid,
                                           n_requests=len(batch))
                leaves, treedef = jax.tree_util.tree_flatten(out)
                np_leaves = [np.asarray(leaf)[:n_valid] for leaf in leaves]
                offs = np.cumsum([0] + sizes)
                results = [
                    treedef.unflatten(
                        [leaf[offs[i]:offs[i + 1]] for leaf in np_leaves])
                    for i in range(len(sizes))]
            except BaseException as e:         # noqa: BLE001 — forwarded
                err = e
            done = self.clock()
            with self._idle:
                if err is None:
                    for _, _, t0 in batch:
                        self.stats_.latency.record(done - t0)
                self._inflight = False
                self._idle.notify_all()
            # resolve futures outside the lock (callbacks run here)
            if err is None:
                for (_, fut, _), res in zip(batch, results):
                    fut.set_result(res)
            else:
                for _, fut, _ in batch:
                    fut.set_exception(err)
            if (err is None and self.refresh_every
                    and self.stats_.flushes % self.refresh_every == 0):
                self._refresh_evt.set()

    # --------------------------------------------------- refresher thread
    def _refresh_loop(self) -> None:
        while True:
            self._refresh_evt.wait()
            self._refresh_evt.clear()
            if self._stop:
                return
            self._do_refresh()

    def _do_refresh(self) -> None:
        """One background refresh: EMA re-rank, re-decode the block off
        the flush path, swap it in between flushes.  The EMA counters
        are read without a lock — the flush thread updates them
        concurrently, and the ranking is a traffic heuristic, not an
        invariant; the INSTALL is what must be atomic, and it happens
        under the lock while no flush is in flight."""
        eng = self.engine
        ids = eng.select_hot_ids()
        if ids is None:
            return                       # no traffic observed yet
        with self._lock:
            self.stats_.hot_refreshes += 1
        if np.array_equal(ids, eng._hot_ids):
            return                       # steady state: skip the decode
        state = eng.prepare_hot_rows(ids)     # device work, NOT the lock
        with self._idle:
            while self._inflight and not self._stop:
                self._idle.wait()
            eng.install_hot_rows(state)

    def refresh_now(self, wait: bool = False) -> None:
        """Trigger a background refresh immediately (testing/ops hook).
        With ``wait=True`` the refresh runs on the calling thread
        instead — deterministic, still off the flush path."""
        if not self.refresh_every and not (
                isinstance(self.engine, ServingEngine)
                and self.engine.hot_rows):
            raise ValueError("no hot-row cache to refresh")
        if wait:
            self._do_refresh()
        else:
            self._refresh_evt.set()

    # -------------------------------------------------------------- drain
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Force-flush and block until every submitted request has
        resolved.  Returns False on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._idle:
            self._force = True
            self._work.notify_all()
            try:
                while self._pending or self._inflight:
                    left = (None if deadline is None
                            else deadline - time.monotonic())
                    if left is not None and left <= 0:
                        return False
                    self._idle.wait(left)
            finally:
                self._force = False
        return True

    # -------------------------------------------------------------- stats
    def stats(self) -> AsyncEngineStats:
        return self.stats_

    def reset_stats(self) -> None:
        """Fresh counters/histogram (e.g. after a warmup pass)."""
        with self._lock:
            self.stats_ = AsyncEngineStats()
            self.engine.stats_ = self.stats_

    # ------------------------------------------------------------ closing
    def close(self, timeout: Optional[float] = None) -> None:
        """Drain, then stop both threads.  Idempotent."""
        self.drain(timeout=timeout)
        with self._work:
            self._stop = True
            self._work.notify_all()
        self._refresh_evt.set()          # wake the refresher to exit
        self._flusher.join(timeout)
        if self._refresher is not None:
            self._refresher.join(timeout)

    def __enter__(self) -> "AsyncServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def drive_open_loop(engine: AsyncServingEngine,
                    requests: Sequence[np.ndarray],
                    arrivals: Sequence[float],
                    sleep: Callable[[float], None] = time.sleep
                    ) -> AsyncEngineStats:
    """Replay an arrival schedule through the async engine, open-loop.

    ``arrivals[i]`` (seconds from stream start,
    ``data/synthetic.open_loop_arrivals``) is when ``requests[i]`` is
    submitted — on the GENERATOR's clock, never gated on completions.
    If the engine falls behind, requests queue up and their measured
    latency grows; a closed-loop driver would instead slow its offered
    load and underreport exactly the queueing delay an SLO is about
    (coordinated omission).  After the last submission the engine is
    drained; ``wall_seconds`` on the returned stats covers
    first-submit → drain-complete, so ``sustained_lookups_per_s`` is
    honest open-loop throughput."""
    if len(requests) != len(arrivals):
        raise ValueError(f"{len(requests)} requests vs {len(arrivals)} "
                         f"arrival times")
    clock = engine.clock
    t0 = clock()
    for req, due in zip(requests, arrivals):
        delay = due - (clock() - t0)
        if delay > 0:
            sleep(delay)
        engine.submit(req)
    engine.drain()
    st = engine.stats()
    st.wall_seconds += clock() - t0
    return st
