"""Batched embedding serving engine (paper Fig. 1 serving path).

Production serving traffic is many small lookup requests, not one big
batch.  The engine owns the exported artifact (codes + centroids) as
*device-resident* buffers — placed once with ``jax.device_put`` and
reused across every request, never re-uploaded — and micro-batches
queued requests into a single fused-decode call:

  * ``submit(ids)`` enqueues a request and returns a handle;
  * ``flush()`` concatenates the queue, pads the flat id batch up to
    the decode kernel's ``block_b`` granularity (so every launch hits
    the kernel's full-block fast path and JIT retraces are bounded by
    queue-size/block_b, not by request shape), runs ONE serve call,
    and splits results back per request;
  * ``lookup(ids)`` is submit + flush for the synchronous case.

Stats accumulate across flushes; ``stats()`` reports lookups/sec — the
number `benchmarks/kernel_bench.py` and `launch/serve.py --engine`
print for fused-vs-unfused comparisons.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import Embedding


@dataclasses.dataclass
class EngineStats:
    requests: int = 0
    lookups: int = 0           # ids actually requested (pre-padding)
    padded_lookups: int = 0    # ids decoded incl. block_b padding
    flushes: int = 0
    seconds: float = 0.0

    @property
    def lookups_per_s(self) -> float:
        return self.lookups / self.seconds if self.seconds > 0 else 0.0

    def as_dict(self) -> Dict:
        return {**dataclasses.asdict(self),
                "lookups_per_s": self.lookups_per_s}


class ServingEngine:
    """Micro-batching lookup engine over one exported embedding table.

    Single-device by default.  Pass ``mesh`` to serve a *sharded*
    quantized artifact (DESIGN.md §6): code tables are placed
    row-sharded over ``model_axis`` and codebooks replicated — each
    shard device-resident once — and every flush fans ONE batched
    decode across the whole mesh through the shard_map quantized
    gather, padded to ``block_b x data_shards`` so each data shard's
    local batch still hits the decode kernel's full-block fast path.
    """

    def __init__(self, emb: Embedding, artifact: dict,
                 block_b: Optional[int] = None,
                 max_queue: int = 65536,
                 backend: Optional[str] = None,
                 mesh=None, model_axis: str = "model"):
        overrides = {}
        if backend is not None:
            overrides["kernel_backend"] = backend
        if block_b is not None:
            # the kernel's block size must match the queue padding —
            # otherwise a custom block_b would pad flushes to sizes the
            # decode kernel re-pads anyway, multiplying retraces
            overrides["decode_block_b"] = block_b
        self.mesh = mesh
        self.model_axis = model_axis
        data_shards = 1
        if mesh is not None:
            cfg = emb.cfg
            # registry-driven capability check: any scheme whose codes
            # the sharded gather can row-shard qualifies (DESIGN.md §7)
            if not emb.scheme.supports_sharded_codes:
                raise ValueError(
                    f"sharded serving needs a quantized table, got "
                    f"kind={cfg.kind!r}")
            if model_axis not in mesh.axis_names:
                raise ValueError(
                    f"mesh {dict(mesh.shape)} has no {model_axis!r} axis "
                    f"to shard codes over")
            model_n = dict(mesh.shape)[model_axis]
            if model_n > 1 and cfg.vocab_size % model_n:
                raise ValueError(
                    f"vocab={cfg.vocab_size} does not divide over "
                    f"{model_axis}={model_n}")
            data_shards = int(np.prod(
                [n for a, n in mesh.shape.items() if a != model_axis])) or 1
            overrides["sharded_codes"] = True
        if overrides:
            # rebuild the config so the decode path dispatches as asked
            emb = Embedding(dataclasses.replace(emb.cfg, **overrides))
        self.emb = emb
        self.block_b = emb.cfg.decode_block_b
        # flushes pad to this granularity: block_b per data shard
        self.pad_multiple = self.block_b * data_shards
        self.data_shards = data_shards
        self.max_queue = max_queue
        # device-resident once; requests only ship (B,) int32 ids
        if mesh is not None:
            from repro.sharding.rules import shard_quantized_artifact
            self.artifact = shard_quantized_artifact(
                artifact, emb.cfg, mesh, model_axis=model_axis)
        else:
            self.artifact = jax.device_put(artifact)
        self._serve = jax.jit(lambda art, ids: emb.serve(art, ids))
        self._queue: List[jax.Array] = []
        self._queued = 0
        self.stats_ = EngineStats()

    # ------------------------------------------------------------ queue
    def submit(self, ids) -> int:
        """Enqueue one request of flat ids; returns its handle (index
        into the list the next flush() returns)."""
        ids = jnp.asarray(ids, jnp.int32).reshape(-1)
        self._queue.append(ids)
        self._queued += ids.shape[0]
        return len(self._queue) - 1

    @property
    def pending(self) -> int:
        return self._queued

    def should_flush(self) -> bool:
        return self._queued >= self.max_queue

    # ------------------------------------------------------------ serve
    def flush(self) -> List[jax.Array]:
        """Decode every queued request in one padded micro-batch."""
        if not self._queue:
            return []
        reqs, self._queue = self._queue, []
        n_req, n_ids = len(reqs), self._queued
        self._queued = 0
        flat = jnp.concatenate(reqs) if n_req > 1 else reqs[0]
        pad = (-flat.shape[0]) % self.pad_multiple
        if pad:
            flat = jnp.pad(flat, (0, pad))  # id 0 is always valid
        t0 = time.perf_counter()
        if self.mesh is not None:
            # ambient mesh at trace time -> shard_map quantized gather
            with self.mesh:
                out = self._serve(self.artifact, flat)
        else:
            out = self._serve(self.artifact, flat)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        self.stats_.requests += n_req
        self.stats_.lookups += n_ids
        self.stats_.padded_lookups += int(flat.shape[0])
        self.stats_.flushes += 1
        self.stats_.seconds += dt
        splits = np.cumsum([r.shape[0] for r in reqs])[:-1].tolist()
        return [s for s in jnp.split(out[:n_ids], splits)] if splits \
            else [out[:n_ids]]

    def lookup(self, ids) -> jax.Array:
        """Synchronous single-request path (submit + flush).  Flushes
        whatever else is queued too and returns THIS request's rows."""
        handle = self.submit(ids)
        return self.flush()[handle]

    def serve_stream(self, requests: Sequence[np.ndarray]) -> EngineStats:
        """Drive a request stream through the micro-batcher; flush
        whenever the queue reaches max_queue, once more at the end."""
        for r in requests:
            self.submit(r)
            if self.should_flush():
                self.flush()
        self.flush()
        return self.stats_

    def stats(self) -> EngineStats:
        return self.stats_


def drive_random_stream(engine: ServingEngine, vocab_size: int,
                        n_requests: int, req_batch: int,
                        seed: int = 0) -> EngineStats:
    """Shared bench/demo harness: stream n_requests random-size
    requests (1..req_batch ids each) and return the throughput stats.

    The identical stream is driven twice: flush points are a pure
    function of the request sizes, so the first pass compiles every
    padded shape the measured pass will hit — the returned stats
    contain zero XLA compile time."""
    rng = np.random.default_rng(seed)
    reqs = [rng.integers(0, vocab_size, int(rng.integers(1, req_batch + 1)))
            for _ in range(n_requests)]
    engine.serve_stream(reqs)          # warm pass: pays all jit traces
    engine.stats_ = EngineStats()
    return engine.serve_stream(reqs)


def embedding_config_of_arch(family: str, cfg):
    """Pick the arch's main large-vocab EmbeddingConfig (engine demo)."""
    from repro.models.recsys.fields import field_embedding_config
    if family == "lm":
        return cfg.embedding
    if cfg.model == "bst":
        return field_embedding_config(cfg, cfg.n_items)
    if cfg.model == "two_tower":
        return field_embedding_config(cfg, cfg.n_items)
    return field_embedding_config(cfg, max(cfg.field_vocab_sizes))


__all__ = ["EngineStats", "ServingEngine", "embedding_config_of_arch"]
