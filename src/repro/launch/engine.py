"""Batched serving engines (paper Fig. 1 serving path + DESIGN.md §8).

Production serving traffic is many small requests, not one big batch.
The engines here own device-resident artifacts — placed once with
``jax.device_put`` and reused across every request, never re-uploaded —
and micro-batch queued requests into a single fused call:

  * ``submit(x)`` enqueues a request (coerced HOST-side — no device
    work on the submit path) and returns a handle;
  * ``flush()`` concatenates the queue in numpy, pads the flat batch
    up to the kernel's block granularity (so every launch hits the
    full-block fast path and JIT retraces are bounded by
    queue-size/block, not by request shape), runs ONE jitted call via
    the shared ``run_flat`` device leg — one upload, one fused call —
    and splits results back per request;
  * the synchronous helpers (``lookup`` / ``search``) are
    submit + flush.

Two engines share that plumbing (``_MicroBatchEngine``):

  ``ServingEngine``    id lookups -> embedding rows over one exported
                       quantized table (fused decode kernel);
  ``RetrievalEngine``  query vectors -> (top-k scores, candidate ids)
                       over a built retrieval index (fused batched ADC
                       top-k, flat or IVF — retrieval/).

Stats accumulate across flushes; ``stats()`` reports requests/second —
the numbers `benchmarks/kernel_bench.py` and `launch/serve.py` print
for fused-vs-unfused comparisons.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import Embedding


@dataclasses.dataclass
class EngineStats:
    requests: int = 0
    lookups: int = 0           # items actually requested (pre-padding)
    padded_lookups: int = 0    # items processed incl. block padding
    flushes: int = 0
    seconds: float = 0.0
    # hot-row cache accounting (ServingEngine, DESIGN.md §9): hits are
    # counted over REAL lookups only (flush padding rows never count),
    # decoded_lookups are the rows that actually reached the fused
    # decode kernel including the cold side's own block padding — a
    # fully cache-served flush adds zero here.
    hot_hits: int = 0
    decoded_lookups: int = 0
    hot_refreshes: int = 0

    @property
    def lookups_per_s(self) -> float:
        # zero guard: empty or instantaneous streams (all-cached
        # flushes, zero requests) report 0.0 instead of dividing by 0
        return self.lookups / self.seconds if self.seconds > 0 else 0.0

    @property
    def hit_rate(self) -> float:
        """Fraction of real lookups served from the hot-row cache."""
        return self.hot_hits / self.lookups if self.lookups else 0.0

    @classmethod
    def derived_metrics(cls) -> List[str]:
        """Every derived (computed) metric this stats class exports:
        the properties defined anywhere on the class — ONE registry, so
        subclasses adding derived fields (e.g. the async engine's
        latency percentiles) are exported by ``as_dict`` without
        re-listing them by hand."""
        return sorted({name for klass in cls.__mro__
                       for name, val in vars(klass).items()
                       if isinstance(val, property)})

    def as_dict(self) -> Dict:
        # counters first (a field with its own as_dict — e.g. the async
        # stats' latency histogram — exports through it), then every
        # registered derived metric, including subclass additions
        out = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            out[f.name] = v.as_dict() if hasattr(v, "as_dict") else v
        for name in self.derived_metrics():
            out[name] = getattr(self, name)
        return out


class _MicroBatchEngine:
    """Queue/pad/flush/split plumbing shared by the serving engines.

    Subclasses define ``_coerce_host`` (request -> numpy array with a
    leading batch dim) and ``_run`` (padded flat batch -> pytree of
    arrays with the same leading dim); everything else — queueing,
    padding to ``pad_multiple``, stats, splitting results back per
    request — is identical between id-lookup and retrieval traffic.
    """

    def __init__(self, pad_multiple: int, max_queue: int,
                 mesh=None):
        self.pad_multiple = pad_multiple
        self.max_queue = max_queue
        self.mesh = mesh
        self._queue: List[np.ndarray] = []
        self._queued = 0
        self._n_valid = 0          # real rows of the flush in flight
        self.stats_ = EngineStats()

    # --------------------------------------------------------- hooks
    def _coerce_host(self, request) -> np.ndarray:
        """Request -> host (numpy) array with a leading batch dim, NO
        device upload.  Both front-ends (the queueing ``submit`` here
        and `launch/async_engine.py`) queue requests host-side and
        ship one concatenated array per flush; per-request device
        arrays would cost a dispatch each on the submit path."""
        raise NotImplementedError

    def _run(self, flat: jax.Array):
        """One fused call over the padded flat batch; returns an array
        or pytree of arrays with flat.shape[0] leading rows."""
        raise NotImplementedError

    # --------------------------------------------------------- queue
    def submit(self, request) -> int:
        """Enqueue one request; returns its handle (index into the
        list the next flush() returns).  Requests are coerced and
        queued HOST-side (``_coerce_host``) so the submit path never
        dispatches device work — the whole batch ships as one upload
        inside the flush."""
        arr = self._coerce_host(request)
        self._queue.append(arr)
        self._queued += arr.shape[0]
        return len(self._queue) - 1

    @property
    def pending(self) -> int:
        return self._queued

    def should_flush(self) -> bool:
        return self._queued >= self.max_queue

    # --------------------------------------------------------- serve
    def flush(self) -> List:
        """Process every queued request in one padded micro-batch.

        Assembly and padding are pure host work routed through the
        shared :meth:`run_flat` device leg — the device-side
        ``jnp.pad`` this method used to do re-dispatched (and on a
        fresh length, recompiled) per distinct unpadded batch size
        (lint rule ``pad-in-flush``, DESIGN.md §15)."""
        if not self._queue:
            return []
        reqs, self._queue = self._queue, []
        n_req, n_rows = len(reqs), self._queued
        self._queued = 0
        flat = np.concatenate(reqs) if n_req > 1 else reqs[0]
        out = self.run_flat(flat, n_rows, n_requests=n_req)
        sizes = [r.shape[0] for r in reqs]
        splits = np.cumsum(sizes)[:-1].tolist()
        leaves, treedef = jax.tree.flatten(out)
        pieces = [jnp.split(leaf[:n_rows], splits) if splits
                  else [leaf[:n_rows]] for leaf in leaves]
        return [treedef.unflatten([p[i] for p in pieces])
                for i in range(n_req)]

    def run_flat(self, flat: np.ndarray, n_valid: Optional[int] = None,
                 n_requests: int = 1):
        """One fused call over a HOST-assembled flat batch — the async
        front-end's flush path (`launch/async_engine.py`); the queueing
        ``submit``/``flush`` pair above is unchanged.

        Padding happens in numpy BEFORE the single upload: the
        device-side padding in ``flush`` re-dispatches (and on a fresh
        length, recompiles) for every distinct unpadded batch size,
        which on a latency-SLO path turns each odd-sized micro-batch
        into tens of milliseconds of XLA work.  Host padding is a
        memcpy, and the padded lengths collapse to a couple of stable,
        warmable shapes.  Returns the RAW result pytree (padded rows
        included) — callers slice ``[:n_valid]`` host-side, where it is
        free.  Stats accumulate as ``n_requests`` requests (the queueing
        ``flush`` and the async front-end pass their batch sizes; the
        default 1 fits direct callers) of ``n_valid`` total lookups.
        """
        n_valid = int(flat.shape[0] if n_valid is None else n_valid)
        pad = (-n_valid) % self.pad_multiple
        if pad:
            widths = [(0, pad)] + [(0, 0)] * (flat.ndim - 1)
            flat = np.pad(flat, widths)    # zero rows are always valid
        dev = jnp.asarray(flat)
        self._n_valid = n_valid        # lets _run tell rows from padding
        t0 = time.perf_counter()
        if self.mesh is not None:
            with self.mesh:
                out = self._run(dev)
        else:
            out = self._run(dev)
        jax.block_until_ready(out)
        self.stats_.seconds += time.perf_counter() - t0
        self.stats_.requests += n_requests
        self.stats_.lookups += n_valid
        self.stats_.padded_lookups += int(dev.shape[0])
        self.stats_.flushes += 1
        return out

    def serve_stream(self, requests: Sequence[np.ndarray]) -> EngineStats:
        """Drive a request stream through the micro-batcher; flush
        whenever the queue reaches max_queue, once more at the end."""
        for r in requests:
            self.submit(r)
            if self.should_flush():
                self.flush()
        self.flush()
        return self.stats_

    def stats(self) -> EngineStats:
        return self.stats_


class ServingEngine(_MicroBatchEngine):
    """Micro-batching lookup engine over one exported embedding table.

    Single-device by default.  Pass ``mesh`` to serve a *sharded*
    quantized artifact (DESIGN.md §6): code tables are placed
    row-sharded over ``model_axis`` and codebooks replicated — each
    shard device-resident once — and every flush fans ONE batched
    decode across the whole mesh through the shard_map quantized
    gather, padded to ``block_b x data_shards`` so each data shard's
    local batch still hits the decode kernel's full-block fast path.

    **Hot-row cache** (DESIGN.md §9): recsys traffic is power-law — the
    head tier absorbs most lookups — so when ``hot_rows`` > 0 (or the
    config/artifact carry a pre-decoded ``hot`` block from export) the
    engine keeps a dense ``(C, d)`` block of the hottest rows and
    splits every flush: cached ids are a plain gather from the block,
    only the cold remainder (padded to ``block_b``) reaches the fused
    decode, and a gather-merge reassembles the flush.  Cached rows are
    bit-identical to the cold path — the block is either the artifact's
    export-time pre-decode or re-decoded through THIS engine's own
    serve function.  ``refresh_hot_rows()`` re-points the cache at the
    observed-hottest ids (EMA frequency counters accumulated per
    flush), so the cached set tracks live traffic rather than static
    tiering; ``hot_refresh_every`` automates that every N flushes.
    """

    def __init__(self, emb: Embedding, artifact: dict,
                 block_b: Optional[int] = None,
                 max_queue: int = 65536,
                 backend: Optional[str] = None,
                 mesh=None, model_axis: str = "model",
                 hot_rows: Optional[int] = None,
                 hot_ema_decay: float = 0.99,
                 hot_refresh_every: int = 0,
                 hot_track_freq: Optional[bool] = None):
        overrides = {}
        if backend is not None:
            overrides["kernel_backend"] = backend
        if block_b is not None:
            # the kernel's block size must match the queue padding —
            # otherwise a custom block_b would pad flushes to sizes the
            # decode kernel re-pads anyway, multiplying retraces
            overrides["decode_block_b"] = block_b
        self.model_axis = model_axis
        data_shards = 1
        if mesh is not None:
            cfg = emb.cfg
            # registry-driven capability check: any scheme whose codes
            # the sharded gather can row-shard qualifies (DESIGN.md §7)
            if not emb.scheme.supports_sharded_codes:
                raise ValueError(
                    f"sharded serving needs a quantized table, got "
                    f"kind={cfg.kind!r}")
            if model_axis not in mesh.axis_names:
                raise ValueError(
                    f"mesh {dict(mesh.shape)} has no {model_axis!r} axis "
                    f"to shard codes over")
            model_n = dict(mesh.shape)[model_axis]
            if model_n > 1 and cfg.vocab_size % model_n:
                raise ValueError(
                    f"vocab={cfg.vocab_size} does not divide over "
                    f"{model_axis}={model_n}")
            data_shards = int(np.prod(
                [n for a, n in mesh.shape.items() if a != model_axis])) or 1
            overrides["sharded_codes"] = True
        if overrides:
            # rebuild the config so the decode path dispatches as asked
            emb = Embedding(dataclasses.replace(emb.cfg, **overrides))
        self.emb = emb
        self.block_b = emb.cfg.decode_block_b
        self.data_shards = data_shards
        # flushes pad to this granularity: block_b per data shard
        super().__init__(pad_multiple=self.block_b * data_shards,
                         max_queue=max_queue, mesh=mesh)
        # device-resident once; requests only ship (B,) int32 ids
        if mesh is not None:
            from repro.sharding.rules import shard_quantized_artifact
            self.artifact = shard_quantized_artifact(
                artifact, emb.cfg, mesh, model_axis=model_axis)
        else:
            self.artifact = jax.device_put(artifact)
        self._serve = jax.jit(lambda art, ids: emb.serve(art, ids))

        # ------------------------------------------------ hot-row cache
        self.hot_rows = (emb.cfg.hot_rows if hot_rows is None
                         else int(hot_rows))
        if not 0 <= self.hot_rows <= emb.cfg.vocab_size:
            raise ValueError(
                f"hot_rows={self.hot_rows} must lie in [0, vocab_size="
                f"{emb.cfg.vocab_size}]")
        self.hot_ema_decay = float(hot_ema_decay)
        self.hot_refresh_every = int(hot_refresh_every)
        # the EMA counters cost O(vocab) host work per flush; track
        # them only when the adaptive cache is actually in play
        self.hot_track_freq = (hot_refresh_every > 0
                               if hot_track_freq is None
                               else bool(hot_track_freq))
        self._hot_block = None     # (C, d) device block, None = disabled
        self._hot_slot = None      # host (vocab,) int32 id->slot, -1 cold
        self._hot_ids = None       # (C,) host int64, the cached id set
        self._freq = None          # (vocab,) float32 EMA traffic counters
        if self.hot_rows:
            # Seed with the head ids (frequency-sorted convention).
            # The artifact's export-time pre-decode is reused verbatim
            # only when this engine decodes through the exact same path
            # (no backend/block rebuild, no mesh); otherwise the block
            # is re-decoded through self._serve so cached rows stay
            # bit-identical to this engine's cold decode.
            block = None
            if ("hot" in artifact and not overrides and mesh is None
                    and artifact["hot"].shape[0] == self.hot_rows):
                block = self.artifact["hot"]
            self._set_hot_rows(np.arange(self.hot_rows), block=block)

        def gather_select(hot_block, cold_out, slots, cold_rank):
            # two O(B)-row gathers + a select, NO scatter (XLA scatters
            # crawl on CPU) and NO concatenate (an O(C) buffer copy per
            # flush — the cache block can be tens of MB): position i
            # takes its cache row when slot >= 0, else its decoded row
            # via the host-computed rank into the cold batch.
            hot = jnp.take(hot_block,
                           jnp.clip(slots, 0, hot_block.shape[0] - 1),
                           axis=0)
            cold = jnp.take(cold_out, cold_rank, axis=0)
            return jnp.where((slots >= 0)[:, None], hot, cold)

        def cold_merge(art, hot_block, slots, cold_ids, cold_rank):
            # single device: decode + merge in ONE dispatch
            return gather_select(hot_block, emb.serve(art, cold_ids),
                                 slots, cold_rank)

        self._cold_merge = jax.jit(cold_merge)
        # mesh path: the shard_map decode must run as its OWN jit — a
        # shard_map output consumed by further ops inside one jit
        # miscounts under GSPMD (P() x P('data') concat doubles the
        # sharded operand) — then the same gather-select merges its
        # materialized output, tolerating mixed shardings
        self._mesh_merge = jax.jit(gather_select)
        self._hot_only = jax.jit(
            lambda blk, slots: jnp.take(
                blk, jnp.clip(slots, 0, blk.shape[0] - 1), axis=0))

    # ----------------------------------------------------- hot-row cache
    def _decode_ids(self, ids_np: np.ndarray) -> jax.Array:
        """Decode arbitrary ids through the engine's own jitted serve
        path (padded to the flush granularity) — by construction
        bit-identical to what the cold path of a flush would produce."""
        n = len(ids_np)
        pad = (-n) % self.pad_multiple
        padded = np.concatenate([ids_np, np.zeros(pad, np.int64)]) \
            if pad else ids_np
        ids = jnp.asarray(padded, jnp.int32)
        if self.mesh is not None:
            with self.mesh:
                out = self._serve(self.artifact, ids)
        else:
            out = self._serve(self.artifact, ids)
        return out[:n]

    def prepare_hot_rows(self, ids_np: np.ndarray, block=None) -> tuple:
        """Build (but do not install) the cache state for an id set:
        decode the block through the engine's own serve path, place it
        device-resident (replicated under a mesh), and compute the
        id->slot map.  Pure with respect to the engine's live cache
        fields, so a background thread can run it concurrently with
        flushes and hand the result to :meth:`install_hot_rows` for an
        atomic swap (the async engine's refresh path, DESIGN.md §10)."""
        ids_np = np.asarray(ids_np, np.int64)
        if block is None:
            block = self._decode_ids(ids_np)
        if self.mesh is not None:
            # the serve output is data-sharded; the cache block is read
            # by every flush on every device — replicate it
            from jax.sharding import NamedSharding, PartitionSpec as P
            block = jax.device_put(np.asarray(block),
                                   NamedSharding(self.mesh, P()))
        else:
            block = jax.device_put(jnp.asarray(block))
        slot = np.full(self.emb.cfg.vocab_size, -1, np.int32)
        slot[ids_np] = np.arange(len(ids_np), dtype=np.int32)
        return block, slot, ids_np

    def install_hot_rows(self, state: tuple) -> None:
        """Swap a prepared cache state in.  Three reference assignments
        — effectively atomic under the GIL, and the flush path reads
        each field once — so a refresh never blocks or tears a flush."""
        self._hot_block, self._hot_slot, self._hot_ids = state

    def _set_hot_rows(self, ids_np: np.ndarray, block=None) -> None:
        self.install_hot_rows(self.prepare_hot_rows(ids_np, block=block))

    def select_hot_ids(self):
        """The top ``hot_rows`` ids by the EMA frequency counters (ties
        broken by id, deterministically), or None before any traffic is
        observed."""
        if self._freq is None:
            return None
        order = np.lexsort((np.arange(len(self._freq)), -self._freq))
        return np.sort(order[:self.hot_rows])

    def refresh_hot_rows(self, hot_ids=None) -> np.ndarray:
        """Re-point the cache at the observed-hottest ids and re-decode
        the block through the engine's own serve path.

        ``hot_ids`` defaults to the top ``hot_rows`` ids by the EMA
        frequency counters (:meth:`select_hot_ids`); an explicit id set
        overrides.  Before any traffic is observed the current set is
        kept.  Returns the active hot id set."""
        if not self.hot_rows:
            raise ValueError("hot-row cache disabled (hot_rows=0)")
        if hot_ids is None:
            hot_ids = self.select_hot_ids()
            if hot_ids is None:
                return self._hot_ids       # no traffic observed yet
        hot_ids = np.asarray(hot_ids, np.int64)
        self.stats_.hot_refreshes += 1
        if np.array_equal(hot_ids, self._hot_ids):
            # steady state: the selected set is unchanged — skip the
            # O(C) re-decode, the block upload, and the slot rebuild
            return self._hot_ids
        self._set_hot_rows(hot_ids)
        return self._hot_ids

    # --------------------------------------------------------- serve
    def _coerce_host(self, ids) -> np.ndarray:
        return np.asarray(ids, np.int32).reshape(-1)

    def _run(self, flat: jax.Array) -> jax.Array:
        if self._hot_block is None:
            self.stats_.decoded_lookups += int(flat.shape[0])
            return self._serve(self.artifact, flat)
        # host-side split; clip mirrors jnp.take's OOB-clamp semantics
        flat_np = np.clip(np.asarray(flat), 0, self.emb.cfg.vocab_size - 1)
        if self.hot_track_freq:
            # EMA traffic counters feed refresh_hot_rows (real rows only)
            if self._freq is None:
                self._freq = np.zeros(self.emb.cfg.vocab_size, np.float32)
            self._freq *= self.hot_ema_decay
            self._freq += np.bincount(flat_np[:self._n_valid],
                                      minlength=len(self._freq)
                                      ).astype(np.float32)
        slots = self._hot_slot[flat_np]            # (B,), -1 = cold
        self.stats_.hot_hits += int((slots[:self._n_valid] >= 0).sum())
        # flush-padding rows are dropped after the flush — point them
        # at cache row 0 so they never force fused-decode work
        slots[self._n_valid:] = 0
        cold_mask = slots < 0
        n_cold = int(cold_mask.sum())
        if n_cold == 0:
            # fully cache-served: zero fused-decode (kernel) work
            return self._hot_only(self._hot_block, jnp.asarray(slots))
        cold_rank = np.maximum(np.cumsum(cold_mask) - 1, 0)
        cold_ids = flat_np[cold_mask]
        pad = (-n_cold) % self.pad_multiple
        if pad:
            cold_ids = np.concatenate(
                [cold_ids, np.zeros(pad, cold_ids.dtype)])
        self.stats_.decoded_lookups += cold_ids.size
        slots_dev = jnp.asarray(slots)
        rank_dev = jnp.asarray(cold_rank.astype(np.int32))
        cold_dev = jnp.asarray(cold_ids, jnp.int32)
        if self.mesh is not None:
            cold_out = self._serve(self.artifact, cold_dev)
            return self._mesh_merge(self._hot_block, cold_out,
                                    slots_dev, rank_dev)
        return self._cold_merge(self.artifact, self._hot_block,
                                slots_dev, cold_dev, rank_dev)

    def run_flat(self, flat: np.ndarray, n_valid: Optional[int] = None,
                 n_requests: int = 1):
        out = super().run_flat(flat, n_valid, n_requests=n_requests)
        # one refresh cadence for BOTH front-ends — the queueing flush()
        # routes through here; the async front-end sets
        # hot_refresh_every=0 and refreshes on its own thread
        if (self._hot_block is not None and self.hot_refresh_every
                and self.stats_.flushes % self.hot_refresh_every == 0):
            self.refresh_hot_rows()
        return out

    def lookup(self, ids) -> jax.Array:
        """Synchronous single-request path (submit + flush).  Flushes
        whatever else is queued too and returns THIS request's rows."""
        handle = self.submit(ids)
        return self.flush()[handle]


class RetrievalEngine(_MicroBatchEngine):
    """Micro-batching top-k retrieval over one built index.

    Requests are query-vector batches (B_i, d); every flush pads the
    concatenated queries to ``block_q x data_shards`` and runs ONE
    fused batched search (``Index.search``) returning per request
    ``(scores (B_i, k), candidate ids (B_i, k))`` — candidate ids +
    scores instead of embedding rows, same plumbing.

    Pass ``mesh`` to search a *distributed* corpus (DESIGN.md §8):
    the O(corpus) artifact rows are placed row-sharded over
    ``model_axis`` (``sharding/rules.shard_retrieval_artifact``) and
    every flush fans one shard_map per-shard-top-k + merge across the
    whole mesh — wire bytes O(B·k), corpus-independent.

    Pass ``host_staged=True`` (or build the index with
    ``IndexConfig(host_staged=True)``) to keep the O(corpus) list
    tables in HOST memory (DESIGN.md §12): every flush stages only the
    probed lists to device (``Index.search_host_staged``) — upload
    ∝ B·nprobe·cap per flush, corpus-independent.  Single-device only
    (a sharded corpus already bounds per-device bytes by 1/shards).
    """

    def __init__(self, index, artifact: dict, k: int,
                 block_q: int = 64, max_queue: int = 4096,
                 backend: Optional[str] = None,
                 mesh=None, model_axis: str = "model",
                 host_staged: Optional[bool] = None):
        from repro.retrieval import get_index, sharded_topk
        if backend is not None:
            index = get_index(dataclasses.replace(
                index.cfg, kernel_backend=backend))
        self.index, self.k = index, k
        self.block_q = block_q
        self.model_axis = model_axis
        if host_staged is None:
            host_staged = index.cfg.host_staged
        if host_staged:
            if mesh is not None:
                raise ValueError(
                    "host_staged serving is single-device; a sharded "
                    "corpus already bounds per-device bytes")
            if not index.supports_host_staged:
                raise ValueError(
                    f"index kind {index.cfg.kind!r} has no host-staged "
                    f"serve path")
        self.host_staged = bool(host_staged)
        data_shards = 1
        if mesh is not None:
            if not index.supports_sharded:
                raise ValueError(
                    f"index kind {index.cfg.kind!r} cannot be "
                    f"distributed")
            if model_axis not in mesh.axis_names:
                raise ValueError(
                    f"mesh {dict(mesh.shape)} has no {model_axis!r} axis "
                    f"to shard corpus rows over")
            model_n = dict(mesh.shape)[model_axis]
            bad = {name: artifact[name].shape[0]
                   for name in index.rows_leaves
                   if artifact[name].shape[0] % model_n}
            if model_n > 1 and bad:
                raise ValueError(
                    f"corpus rows {bad} do not divide over "
                    f"{model_axis}={model_n}")
            data_shards = int(np.prod(
                [n for a, n in mesh.shape.items() if a != model_axis])) or 1
        self.data_shards = data_shards
        super().__init__(pad_multiple=block_q * data_shards,
                         max_queue=max_queue, mesh=mesh)
        if mesh is not None:
            from repro.sharding.rules import shard_retrieval_artifact
            self.artifact = shard_retrieval_artifact(
                artifact, index, mesh, model_axis=model_axis)
            self._search = jax.jit(lambda art, q: sharded_topk(
                index, art, q, k, model_axis=model_axis, mesh=mesh))
        elif self.host_staged:
            # host leaves stay numpy; only the tiny replicated leaves
            # (coarse table, codebooks, chain) go to device up front
            host = set(index.host_leaves())
            self.artifact = {
                name: np.asarray(leaf) if name in host
                else jax.device_put(jnp.asarray(leaf))
                for name, leaf in artifact.items()}
            # search_host_staged jits its device stages internally (the
            # staged-list count varies per flush)
            self._search = lambda art, q: index.search_host_staged(
                art, q, k)
        else:
            self.artifact = jax.device_put(
                {name: jnp.asarray(leaf)
                 for name, leaf in artifact.items()})
            self._search = jax.jit(lambda art, q: index.search(art, q, k))

    @property
    def staged_mbytes(self) -> float:
        """Total MB staged to device so far (host-staged mode)."""
        return float(getattr(self.index, "staged_bytes", 0)) / 1e6

    def _coerce_host(self, queries) -> np.ndarray:
        q = np.asarray(queries, np.float32)
        return q[None] if q.ndim == 1 else q

    def _run(self, flat: jax.Array):
        return self._search(self.artifact, flat)

    def search(self, queries):
        """Synchronous single-request path (submit + flush): queries
        (B, d) or (d,) -> (scores, ids).  Flushes whatever else is
        queued too and returns THIS request's results."""
        handle = self.submit(queries)
        return self.flush()[handle]


def drive_random_stream(engine: ServingEngine, vocab_size: int,
                        n_requests: int, req_batch: int,
                        seed: int = 0) -> EngineStats:
    """Shared bench/demo harness: stream n_requests random-size
    requests (1..req_batch ids each) and return the throughput stats.

    The identical stream is driven twice: flush points are a pure
    function of the request sizes, so the first pass compiles every
    padded shape the measured pass will hit — the returned stats
    contain zero XLA compile time."""
    rng = np.random.default_rng(seed)
    reqs = [rng.integers(0, vocab_size, int(rng.integers(1, req_batch + 1)))
            for _ in range(n_requests)]
    engine.serve_stream(reqs)          # warm pass: pays all jit traces
    engine.stats_ = EngineStats()
    return engine.serve_stream(reqs)


def drive_zipf_stream(engine: ServingEngine, vocab_size: int,
                      n_requests: int, req_batch: int,
                      zipf_a: float = 1.2, seed: int = 0) -> EngineStats:
    """Power-law twin of :func:`drive_random_stream`: Zipf(``zipf_a``)
    ids over the frequency-sorted vocabulary — the head-heavy traffic
    the hot-row cache exists for (DESIGN.md §9).

    The identical stream is driven twice: with a static hot set the
    hot/cold split is a pure function of the request ids, so the warm
    pass compiles every (flush, cold-batch) shape the measured pass
    hits — zero XLA compile time in the returned stats.  (Auto-refresh
    between passes can shift the cached set and re-trace a handful of
    shapes; the EMA counters and stats are reset so the measured pass
    starts clean either way.)"""
    from repro.data.synthetic import zipf_request_stream
    reqs = zipf_request_stream(vocab_size, n_requests, req_batch,
                               zipf_a=zipf_a, seed=seed)
    engine.serve_stream(reqs)          # warm pass: pays all jit traces
    engine.stats_ = EngineStats()
    if engine._freq is not None:
        engine._freq[:] = 0.0
    return engine.serve_stream(reqs)


def drive_random_query_stream(engine: RetrievalEngine, dim: int,
                              n_requests: int, req_batch: int,
                              seed: int = 0) -> EngineStats:
    """Retrieval twin of :func:`drive_random_stream`: random-size
    query-vector requests, warm pass first, zero compile time in the
    returned stats."""
    rng = np.random.default_rng(seed)
    reqs = [rng.normal(size=(int(rng.integers(1, req_batch + 1)), dim)
                       ).astype(np.float32)
            for _ in range(n_requests)]
    engine.serve_stream(reqs)          # warm pass: pays all jit traces
    engine.stats_ = EngineStats()
    return engine.serve_stream(reqs)


def embedding_config_of_arch(family: str, cfg):
    """Pick the arch's main large-vocab EmbeddingConfig (engine demo)."""
    from repro.models.recsys.fields import field_embedding_config
    if family == "lm":
        return cfg.embedding
    if cfg.model == "bst":
        return field_embedding_config(cfg, cfg.n_items)
    if cfg.model == "two_tower":
        return field_embedding_config(cfg, cfg.n_items)
    return field_embedding_config(cfg, max(cfg.field_vocab_sizes))


__all__ = ["EngineStats", "RetrievalEngine", "ServingEngine",
           "drive_random_query_stream", "drive_random_stream",
           "drive_zipf_stream", "embedding_config_of_arch"]
