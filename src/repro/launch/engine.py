"""Batched serving engines (paper Fig. 1 serving path + DESIGN.md §8).

Production serving traffic is many small requests, not one big batch.
The engines here own device-resident artifacts — placed once with
``jax.device_put`` and reused across every request, never re-uploaded —
and micro-batch queued requests into a single fused call:

  * ``submit(x)`` enqueues a request and returns a handle;
  * ``flush()`` concatenates the queue, pads the flat batch up to the
    kernel's block granularity (so every launch hits the full-block
    fast path and JIT retraces are bounded by queue-size/block, not by
    request shape), runs ONE jitted call, and splits results back per
    request;
  * the synchronous helpers (``lookup`` / ``search``) are
    submit + flush.

Two engines share that plumbing (``_MicroBatchEngine``):

  ``ServingEngine``    id lookups -> embedding rows over one exported
                       quantized table (fused decode kernel);
  ``RetrievalEngine``  query vectors -> (top-k scores, candidate ids)
                       over a built retrieval index (fused batched ADC
                       top-k, flat or IVF — retrieval/).

Stats accumulate across flushes; ``stats()`` reports requests/second —
the numbers `benchmarks/kernel_bench.py` and `launch/serve.py` print
for fused-vs-unfused comparisons.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import Embedding


@dataclasses.dataclass
class EngineStats:
    requests: int = 0
    lookups: int = 0           # items actually requested (pre-padding)
    padded_lookups: int = 0    # items processed incl. block padding
    flushes: int = 0
    seconds: float = 0.0

    @property
    def lookups_per_s(self) -> float:
        # zero guard: empty or instantaneous streams (all-cached
        # flushes, zero requests) report 0.0 instead of dividing by 0
        return self.lookups / self.seconds if self.seconds > 0 else 0.0

    def as_dict(self) -> Dict:
        return {**dataclasses.asdict(self),
                "lookups_per_s": self.lookups_per_s}


class _MicroBatchEngine:
    """Queue/pad/flush/split plumbing shared by the serving engines.

    Subclasses define ``_coerce`` (request -> array with a leading
    batch dim) and ``_run`` (padded flat batch -> pytree of arrays
    with the same leading dim); everything else — queueing, padding to
    ``pad_multiple``, stats, splitting results back per request — is
    identical between id-lookup and retrieval traffic.
    """

    def __init__(self, pad_multiple: int, max_queue: int,
                 mesh=None):
        self.pad_multiple = pad_multiple
        self.max_queue = max_queue
        self.mesh = mesh
        self._queue: List[jax.Array] = []
        self._queued = 0
        self.stats_ = EngineStats()

    # --------------------------------------------------------- hooks
    def _coerce(self, request) -> jax.Array:
        raise NotImplementedError

    def _run(self, flat: jax.Array):
        """One fused call over the padded flat batch; returns an array
        or pytree of arrays with flat.shape[0] leading rows."""
        raise NotImplementedError

    # --------------------------------------------------------- queue
    def submit(self, request) -> int:
        """Enqueue one request; returns its handle (index into the
        list the next flush() returns)."""
        arr = self._coerce(request)
        self._queue.append(arr)
        self._queued += arr.shape[0]
        return len(self._queue) - 1

    @property
    def pending(self) -> int:
        return self._queued

    def should_flush(self) -> bool:
        return self._queued >= self.max_queue

    # --------------------------------------------------------- serve
    def flush(self) -> List:
        """Process every queued request in one padded micro-batch."""
        if not self._queue:
            return []
        reqs, self._queue = self._queue, []
        n_req, n_rows = len(reqs), self._queued
        self._queued = 0
        flat = jnp.concatenate(reqs) if n_req > 1 else reqs[0]
        pad = (-flat.shape[0]) % self.pad_multiple
        if pad:
            widths = [(0, pad)] + [(0, 0)] * (flat.ndim - 1)
            flat = jnp.pad(flat, widths)   # zero rows are always valid
        t0 = time.perf_counter()
        if self.mesh is not None:
            # ambient mesh at trace time -> shard_map fused path
            with self.mesh:
                out = self._run(flat)
        else:
            out = self._run(flat)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        self.stats_.requests += n_req
        self.stats_.lookups += n_rows
        self.stats_.padded_lookups += int(flat.shape[0])
        self.stats_.flushes += 1
        self.stats_.seconds += dt
        sizes = [r.shape[0] for r in reqs]
        splits = np.cumsum(sizes)[:-1].tolist()
        leaves, treedef = jax.tree.flatten(out)
        pieces = [jnp.split(leaf[:n_rows], splits) if splits
                  else [leaf[:n_rows]] for leaf in leaves]
        return [treedef.unflatten([p[i] for p in pieces])
                for i in range(n_req)]

    def serve_stream(self, requests: Sequence[np.ndarray]) -> EngineStats:
        """Drive a request stream through the micro-batcher; flush
        whenever the queue reaches max_queue, once more at the end."""
        for r in requests:
            self.submit(r)
            if self.should_flush():
                self.flush()
        self.flush()
        return self.stats_

    def stats(self) -> EngineStats:
        return self.stats_


class ServingEngine(_MicroBatchEngine):
    """Micro-batching lookup engine over one exported embedding table.

    Single-device by default.  Pass ``mesh`` to serve a *sharded*
    quantized artifact (DESIGN.md §6): code tables are placed
    row-sharded over ``model_axis`` and codebooks replicated — each
    shard device-resident once — and every flush fans ONE batched
    decode across the whole mesh through the shard_map quantized
    gather, padded to ``block_b x data_shards`` so each data shard's
    local batch still hits the decode kernel's full-block fast path.
    """

    def __init__(self, emb: Embedding, artifact: dict,
                 block_b: Optional[int] = None,
                 max_queue: int = 65536,
                 backend: Optional[str] = None,
                 mesh=None, model_axis: str = "model"):
        overrides = {}
        if backend is not None:
            overrides["kernel_backend"] = backend
        if block_b is not None:
            # the kernel's block size must match the queue padding —
            # otherwise a custom block_b would pad flushes to sizes the
            # decode kernel re-pads anyway, multiplying retraces
            overrides["decode_block_b"] = block_b
        self.model_axis = model_axis
        data_shards = 1
        if mesh is not None:
            cfg = emb.cfg
            # registry-driven capability check: any scheme whose codes
            # the sharded gather can row-shard qualifies (DESIGN.md §7)
            if not emb.scheme.supports_sharded_codes:
                raise ValueError(
                    f"sharded serving needs a quantized table, got "
                    f"kind={cfg.kind!r}")
            if model_axis not in mesh.axis_names:
                raise ValueError(
                    f"mesh {dict(mesh.shape)} has no {model_axis!r} axis "
                    f"to shard codes over")
            model_n = dict(mesh.shape)[model_axis]
            if model_n > 1 and cfg.vocab_size % model_n:
                raise ValueError(
                    f"vocab={cfg.vocab_size} does not divide over "
                    f"{model_axis}={model_n}")
            data_shards = int(np.prod(
                [n for a, n in mesh.shape.items() if a != model_axis])) or 1
            overrides["sharded_codes"] = True
        if overrides:
            # rebuild the config so the decode path dispatches as asked
            emb = Embedding(dataclasses.replace(emb.cfg, **overrides))
        self.emb = emb
        self.block_b = emb.cfg.decode_block_b
        self.data_shards = data_shards
        # flushes pad to this granularity: block_b per data shard
        super().__init__(pad_multiple=self.block_b * data_shards,
                         max_queue=max_queue, mesh=mesh)
        # device-resident once; requests only ship (B,) int32 ids
        if mesh is not None:
            from repro.sharding.rules import shard_quantized_artifact
            self.artifact = shard_quantized_artifact(
                artifact, emb.cfg, mesh, model_axis=model_axis)
        else:
            self.artifact = jax.device_put(artifact)
        self._serve = jax.jit(lambda art, ids: emb.serve(art, ids))

    def _coerce(self, ids) -> jax.Array:
        return jnp.asarray(ids, jnp.int32).reshape(-1)

    def _run(self, flat: jax.Array) -> jax.Array:
        return self._serve(self.artifact, flat)

    def lookup(self, ids) -> jax.Array:
        """Synchronous single-request path (submit + flush).  Flushes
        whatever else is queued too and returns THIS request's rows."""
        handle = self.submit(ids)
        return self.flush()[handle]


class RetrievalEngine(_MicroBatchEngine):
    """Micro-batching top-k retrieval over one built index.

    Requests are query-vector batches (B_i, d); every flush pads the
    concatenated queries to ``block_q x data_shards`` and runs ONE
    fused batched search (``Index.search``) returning per request
    ``(scores (B_i, k), candidate ids (B_i, k))`` — candidate ids +
    scores instead of embedding rows, same plumbing.

    Pass ``mesh`` to search a *distributed* corpus (DESIGN.md §8):
    the O(corpus) artifact rows are placed row-sharded over
    ``model_axis`` (``sharding/rules.shard_retrieval_artifact``) and
    every flush fans one shard_map per-shard-top-k + merge across the
    whole mesh — wire bytes O(B·k), corpus-independent.
    """

    def __init__(self, index, artifact: dict, k: int,
                 block_q: int = 64, max_queue: int = 4096,
                 backend: Optional[str] = None,
                 mesh=None, model_axis: str = "model"):
        from repro.retrieval import get_index, sharded_topk
        if backend is not None:
            index = get_index(dataclasses.replace(
                index.cfg, kernel_backend=backend))
        self.index, self.k = index, k
        self.block_q = block_q
        self.model_axis = model_axis
        data_shards = 1
        if mesh is not None:
            if not index.supports_sharded:
                raise ValueError(
                    f"index kind {index.cfg.kind!r} cannot be "
                    f"distributed")
            if model_axis not in mesh.axis_names:
                raise ValueError(
                    f"mesh {dict(mesh.shape)} has no {model_axis!r} axis "
                    f"to shard corpus rows over")
            model_n = dict(mesh.shape)[model_axis]
            bad = {name: artifact[name].shape[0]
                   for name in index.rows_leaves
                   if artifact[name].shape[0] % model_n}
            if model_n > 1 and bad:
                raise ValueError(
                    f"corpus rows {bad} do not divide over "
                    f"{model_axis}={model_n}")
            data_shards = int(np.prod(
                [n for a, n in mesh.shape.items() if a != model_axis])) or 1
        self.data_shards = data_shards
        super().__init__(pad_multiple=block_q * data_shards,
                         max_queue=max_queue, mesh=mesh)
        if mesh is not None:
            from repro.sharding.rules import shard_retrieval_artifact
            self.artifact = shard_retrieval_artifact(
                artifact, index, mesh, model_axis=model_axis)
            self._search = jax.jit(lambda art, q: sharded_topk(
                index, art, q, k, model_axis=model_axis, mesh=mesh))
        else:
            self.artifact = jax.device_put(artifact)
            self._search = jax.jit(lambda art, q: index.search(art, q, k))

    def _coerce(self, queries) -> jax.Array:
        q = jnp.asarray(queries, jnp.float32)
        return q[None] if q.ndim == 1 else q

    def _run(self, flat: jax.Array):
        return self._search(self.artifact, flat)

    def search(self, queries):
        """Synchronous single-request path (submit + flush): queries
        (B, d) or (d,) -> (scores, ids).  Flushes whatever else is
        queued too and returns THIS request's results."""
        handle = self.submit(queries)
        return self.flush()[handle]


def drive_random_stream(engine: ServingEngine, vocab_size: int,
                        n_requests: int, req_batch: int,
                        seed: int = 0) -> EngineStats:
    """Shared bench/demo harness: stream n_requests random-size
    requests (1..req_batch ids each) and return the throughput stats.

    The identical stream is driven twice: flush points are a pure
    function of the request sizes, so the first pass compiles every
    padded shape the measured pass will hit — the returned stats
    contain zero XLA compile time."""
    rng = np.random.default_rng(seed)
    reqs = [rng.integers(0, vocab_size, int(rng.integers(1, req_batch + 1)))
            for _ in range(n_requests)]
    engine.serve_stream(reqs)          # warm pass: pays all jit traces
    engine.stats_ = EngineStats()
    return engine.serve_stream(reqs)


def drive_random_query_stream(engine: RetrievalEngine, dim: int,
                              n_requests: int, req_batch: int,
                              seed: int = 0) -> EngineStats:
    """Retrieval twin of :func:`drive_random_stream`: random-size
    query-vector requests, warm pass first, zero compile time in the
    returned stats."""
    rng = np.random.default_rng(seed)
    reqs = [rng.normal(size=(int(rng.integers(1, req_batch + 1)), dim)
                       ).astype(np.float32)
            for _ in range(n_requests)]
    engine.serve_stream(reqs)          # warm pass: pays all jit traces
    engine.stats_ = EngineStats()
    return engine.serve_stream(reqs)


def embedding_config_of_arch(family: str, cfg):
    """Pick the arch's main large-vocab EmbeddingConfig (engine demo)."""
    from repro.models.recsys.fields import field_embedding_config
    if family == "lm":
        return cfg.embedding
    if cfg.model == "bst":
        return field_embedding_config(cfg, cfg.n_items)
    if cfg.model == "two_tower":
        return field_embedding_config(cfg, cfg.n_items)
    return field_embedding_config(cfg, max(cfg.field_vocab_sizes))


__all__ = ["EngineStats", "RetrievalEngine", "ServingEngine",
           "drive_random_query_stream", "drive_random_stream",
           "embedding_config_of_arch"]
