"""Dry-run cell construction: one Cell per (arch x input-shape).

A Cell bundles everything ``dryrun.py`` needs to lower + compile a step
on the production mesh without allocating anything:

    fn             the step function (closed over configs)
    args           tuple of ShapeDtypeStruct pytrees
    in_shardings   matching pytree of NamedShardings
    out_shardings  pytree / None (auto)
    model_flops    useful-FLOPs estimate for §Roofline
    donate         argnums to donate
    note           free-text (what the cell lowers)

Conventions:
  * TRAIN cells lower a full optimizer step (grads + Adam update).
  * PREFILL cells lower prompt -> (KV cache, logits) on the *serving*
    path: the quantized embedding artifact replaces the full table
    (paper Fig. 1 — the table is dead at serving time).
  * DECODE cells lower one-token serve_step against a full cache.
  * Uneven leading dims are padded up to multiples of the device count
    (XLA GSPMD wants divisible shardings; the pad rows are masked).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import GNNConfig, LMConfig, RecsysConfig, ShapeSpec
from repro.configs.registry import get_arch
from repro.core import Embedding
from repro.models import lm
from repro.models.gnn.mace import MACE
from repro.models.recsys.autoint import AutoInt
from repro.models.recsys.bst import BST
from repro.models.recsys.deepfm import DeepFM
from repro.models.recsys.two_tower import TwoTower
from repro.sharding import rules
from repro.train import optimizer as opt_lib
from repro.train.optimizer import TrainState


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable
    args: Tuple
    in_shardings: Any
    out_shardings: Any
    model_flops: float
    donate: Tuple[int, ...] = ()
    note: str = ""


def _named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if isinstance(s, P) else P()),
        tree, is_leaf=lambda x: isinstance(x, P) or x is None)


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _dp(mesh, multi_pod: bool):
    axes = ("pod", "data") if multi_pod else ("data",)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    return axes, n


def _batch_or_seq_spec(b: int, dp_axes, dp_n: int, extra_dims: int = 1):
    """Shard the batch over DP when it divides; else leave replicated
    and (for 2d+ inputs) shard dim 1 — the B=1 long-context SP case."""
    if b % dp_n == 0 and b >= dp_n:
        return P(dp_axes, *(None,) * extra_dims)
    if extra_dims >= 1:
        return P(None, dp_axes, *(None,) * (extra_dims - 1))
    return P(None)


# ======================================================================
# LM cells
# ======================================================================

def _lm_state_struct(cfg: LMConfig, ocfg: opt_lib.OptimizerConfig):
    def build(key):
        params = lm.model_init(key, cfg)
        return TrainState.create(ocfg, params)
    return jax.eval_shape(build, jax.random.PRNGKey(0))


def _lm_state_sharding(cfg, mesh, state_struct):
    p_spec, o_spec = rules.lm_state_specs(
        cfg, mesh, state_struct.params, state_struct.opt_state)
    return TrainState(_named(mesh, p_spec), _named(mesh, o_spec))


def _lm_params_sharding(cfg, mesh, params_struct):
    spec = rules.spec_tree(params_struct, rules.lm_param_rules(cfg, mesh))
    return _named(mesh, spec)


def _strip_embed_table(params_struct):
    """Serving path: the full embedding table is discarded (Fig. 1) —
    only centroids ride along for the artifact-free baselines."""
    out = dict(params_struct)
    out["embed"] = {k: v for k, v in params_struct["embed"].items()
                    if k != "emb"}
    return out


def _lm_artifact_struct(cfg: LMConfig):
    return Embedding(cfg.embedding).serving_artifact_struct()


def _lm_artifact_sharding(mesh, artifact_struct):
    spec = {}
    for k, v in artifact_struct.items():
        if k == "codes":
            spec[k] = P("model", None)
        elif k == "emb":                      # full-embedding baseline
            spec[k] = P("model", None)
        elif k in ("q",):                     # sq artifact
            spec[k] = P("model", None)
        else:
            spec[k] = P()
    return _named(mesh, spec)


def lm_train_cell(arch: str, cfg: LMConfig, shape: ShapeSpec, mesh,
                  multi_pod: bool, microbatches: int = 1) -> Cell:
    dp_axes, dp_n = _dp(mesh, multi_pod)
    b, s = shape.global_batch, shape.seq_len
    ocfg = opt_lib.OptimizerConfig(kind="adamw", lr=3e-4, grad_clip=1.0)
    state_struct = _lm_state_struct(cfg, ocfg)
    state_shard = _lm_state_sharding(cfg, mesh, state_struct)
    batch_struct = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    batch_spec = {k: _batch_or_seq_spec(b, dp_axes, dp_n, 1)
                  for k in batch_struct}
    loss_fn = functools.partial(lm.loss_fn, cfg=cfg)
    if microbatches == 1:
        step = opt_lib.make_step_fn(ocfg, loss_fn)
    else:
        if b % microbatches:
            raise ValueError(f"batch {b} not divisible into "
                             f"{microbatches} microbatches")
        mb = b // microbatches
        # fp32 accumulators carry the ZeRO-1 sharding of the Adam
        # moments (extra data-axis split) — a full param-shaped fp32
        # buffer per device would cost more HBM than the activations
        # the microbatching saves
        _, o_spec = rules.lm_state_specs(
            cfg, mesh, state_struct.params, state_struct.opt_state)
        acc_shard = _named(mesh, o_spec["m"])

        def step(state, batch):
            """Gradient accumulation: scan over microbatches, one
            optimizer update — cuts live activations by ~1/m."""
            split = jax.tree.map(
                lambda v: v.reshape((microbatches, mb) + v.shape[1:]),
                batch)

            def one(carry, mbatch):
                gsum, lsum = carry
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mbatch)
                gsum = jax.tree.map(jnp.add, gsum, grads)
                gsum = jax.lax.with_sharding_constraint(gsum, acc_shard)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            zeros = jax.lax.with_sharding_constraint(zeros, acc_shard)
            (gsum, lsum), _ = jax.lax.scan(one, (zeros, jnp.float32(0.0)),
                                           split)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            new_p, new_o = opt_lib.apply_updates(ocfg, state.params, grads,
                                                 state.opt_state)
            loss = lsum / microbatches
            return opt_lib.TrainState(new_p, new_o), {"loss": loss}

    flops = 6.0 * cfg.active_param_count() * b * s
    return Cell(arch, shape.name, step, (state_struct, batch_struct),
                (state_shard, _named(mesh, batch_spec)),
                (state_shard, None), flops, donate=(0,),
                note=f"train_step B={b} S={s}")


def lm_prefill_cell(arch: str, cfg: LMConfig, shape: ShapeSpec, mesh,
                    multi_pod: bool) -> Cell:
    dp_axes, dp_n = _dp(mesh, multi_pod)
    b, s = shape.global_batch, shape.seq_len

    params_struct = jax.eval_shape(
        lambda k: lm.model_init(k, cfg), jax.random.PRNGKey(0))
    serve_params = _strip_embed_table(params_struct)
    params_shard = _lm_params_sharding(cfg, mesh, serve_params)
    artifact_struct = _lm_artifact_struct(cfg)
    artifact_shard = _lm_artifact_sharding(mesh, artifact_struct)
    tokens_struct = jax.ShapeDtypeStruct((b, s), jnp.int32)
    tokens_spec = _batch_or_seq_spec(b, dp_axes, dp_n, 1)

    def fn(params, artifact, tokens):
        return lm.prefill(params, tokens, cfg, max_seq=s,
                          embed_artifact=artifact)

    flops = 2.0 * cfg.active_param_count() * b * s
    return Cell(arch, shape.name, fn,
                (serve_params, artifact_struct, tokens_struct),
                (params_shard, artifact_shard,
                 NamedSharding(mesh, tokens_spec)),
                None, flops, note=f"prefill B={b} S={s} (serving path)")


def lm_decode_cell(arch: str, cfg: LMConfig, shape: ShapeSpec, mesh,
                   multi_pod: bool) -> Cell:
    dp_axes, dp_n = _dp(mesh, multi_pod)
    b, s = shape.global_batch, shape.seq_len

    params_struct = jax.eval_shape(
        lambda k: lm.model_init(k, cfg), jax.random.PRNGKey(0))
    serve_params = _strip_embed_table(params_struct)
    params_shard = _lm_params_sharding(cfg, mesh, serve_params)
    artifact_struct = _lm_artifact_struct(cfg)
    artifact_shard = _lm_artifact_sharding(mesh, artifact_struct)
    cache_struct = jax.eval_shape(
        lambda: lm.make_cache(cfg, b, s))
    cache_shard = _named(mesh, rules.lm_cache_spec(
        cfg, b, mesh, multi_pod, cache_struct))
    token_struct = jax.ShapeDtypeStruct((b,), jnp.int32)
    token_spec = P(dp_axes) if b % dp_n == 0 and b >= dp_n else P()

    def fn(params, artifact, cache, token):
        return lm.decode_step(params, cache, token, cfg,
                              embed_artifact=artifact)

    flops = 2.0 * cfg.active_param_count() * b
    return Cell(arch, shape.name, fn,
                (serve_params, artifact_struct, cache_struct, token_struct),
                (params_shard, artifact_shard, cache_shard,
                 NamedSharding(mesh, token_spec)),
                (cache_shard, None), flops, donate=(2,),
                note=f"serve_step B={b} KV={s} (one new token)")


# ======================================================================
# GNN (MACE) cells
# ======================================================================

def mace_model_flops(cfg: GNNConfig, n_nodes: int, n_edges: int,
                     train: bool = True) -> float:
    """Analytic forward MACs x2 (x3 more for train) for the MACE step."""
    model = MACE(cfg)
    c = cfg.d_hidden
    s_tot = model.n_sh
    fl = 0.0
    # per layer
    per_l = 0.0
    # radial MLP: E x (rbf*64 + 64*C*P)
    per_l += n_edges * (cfg.n_rbf * 64 + 64 * c * model.n_paths)
    # edge TP + pairwise CG: paths ~ E/N x C x S1*S2*S3
    path_cost = sum((2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1)
                    for (l1, l2, l3, _) in model.paths)
    per_l += n_edges * c * path_cost          # edge TP
    per_l += 2 * n_nodes * c * path_cost      # B2, B3
    # channel mixes: 4 x N x C*C*S
    per_l += 4 * n_nodes * c * c * s_tot
    # readout
    per_l += n_nodes * (c * 64 + 64 * cfg.d_readout)
    fl = cfg.num_layers * per_l * 2.0         # MAC -> 2 FLOPs
    return fl * (3.0 if train else 1.0)


def _gnn_graph_struct(n_nodes: int, n_edges: int, d_feat: int,
                      task: str, n_classes: int = 16,
                      n_graphs: int = 0) -> Dict:
    S = jax.ShapeDtypeStruct
    g = {
        "positions": S((n_nodes, 3), jnp.float32),
        "species": S((n_nodes,), jnp.int32),
        "edge_index": S((2, n_edges), jnp.int32),
    }
    if d_feat:
        g["node_feats"] = S((n_nodes, d_feat), jnp.float32)
    if task == "node_class":
        g["labels"] = S((n_nodes,), jnp.int32)
        g["label_mask"] = S((n_nodes,), jnp.float32)
    else:
        g["graph_id"] = S((n_nodes,), jnp.int32)
        g["energy"] = S((n_graphs,), jnp.float32)
    return g


def mace_cell(arch: str, cfg: GNNConfig, shape: ShapeSpec, mesh,
              multi_pod: bool) -> Cell:
    dp_axes, dp_n = _dp(mesh, multi_pod)
    n_dev = mesh.size
    model = MACE(cfg)

    if shape.kind == "graph_mini":
        from repro.data.graph import sampled_subgraph_sizes
        n_nodes, n_edges = sampled_subgraph_sizes(shape.batch_nodes,
                                                  shape.fanout)
        d_feat, task, n_graphs = 128, "node_class", 0
    elif shape.kind == "graph_batched":
        n_nodes = shape.n_nodes * shape.batch_graphs
        n_edges = shape.n_edges * shape.batch_graphs
        d_feat, task, n_graphs = 0, "energy", shape.batch_graphs
    else:
        n_nodes, n_edges = shape.n_nodes, shape.n_edges
        d_feat, task, n_graphs = shape.d_feat, "node_class", 0

    n_nodes = _pad_to(n_nodes, n_dev)
    n_edges = _pad_to(n_edges, n_dev)

    graph_struct = _gnn_graph_struct(n_nodes, n_edges, d_feat, task,
                                     n_graphs=n_graphs)

    # shard nodes/edges over every mesh axis (no TP dim in MACE at C=128;
    # channels go over "model" via the param rules when divisible)
    all_axes = dp_axes + ("model",)
    gspec = {
        "positions": P(all_axes, None),
        "species": P(all_axes),
        "edge_index": P(None, all_axes),
    }
    if d_feat:
        gspec["node_feats"] = P(all_axes, None)
    if task == "node_class":
        gspec["labels"] = P(all_axes)
        gspec["label_mask"] = P(all_axes)
    else:
        gspec["graph_id"] = P(all_axes)
        gspec["energy"] = P(all_axes) if n_graphs % n_dev == 0 else P()

    ocfg = opt_lib.OptimizerConfig(kind="adam", lr=1e-3)
    n_feat_arg = d_feat if d_feat else None
    state_struct = jax.eval_shape(
        lambda k: TrainState.create(ocfg, model.init(k, n_feat=n_feat_arg)),
        jax.random.PRNGKey(0))
    p_spec = rules.spec_tree(state_struct.params,
                             rules.gnn_param_rules(cfg, mesh))
    o_spec = jax.tree.map(lambda _: P(), state_struct.opt_state)
    state_shard = TrainState(_named(mesh, p_spec), _named(mesh, o_spec))

    loss_fn = (model.node_class_loss if task == "node_class"
               else model.energy_loss)

    def step(state, graph):
        if task == "energy":
            graph = dict(graph, n_graphs=n_graphs)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, graph)
        new_p, new_o = opt_lib.apply_updates(ocfg, state.params, grads,
                                             state.opt_state)
        return TrainState(new_p, new_o), metrics

    flops = mace_model_flops(cfg, n_nodes, n_edges, train=True)
    return Cell(arch, shape.name, step, (state_struct, graph_struct),
                (state_shard, _named(mesh, gspec)), (state_shard, None),
                flops, donate=(0,),
                note=f"{task} train_step N={n_nodes} E={n_edges}")


# ======================================================================
# RecSys cells
# ======================================================================

_RECSYS_MODELS = {"autoint": AutoInt, "deepfm": DeepFM, "bst": BST,
                  "two_tower": TwoTower}


def _recsys_model(cfg: RecsysConfig):
    return _RECSYS_MODELS[cfg.model](cfg)


def _recsys_dense_params(cfg: RecsysConfig) -> int:
    """Rough dense (non-embedding) parameter count for MODEL_FLOPS."""
    if cfg.model == "autoint":
        d_out = cfg.n_attn_heads * cfg.d_attn
        per = 4 * cfg.embed_dim * d_out + 3 * d_out * d_out * \
            max(cfg.n_attn_layers - 1, 0)
        return per + cfg.n_sparse * d_out
    if cfg.model == "deepfm":
        dims = (cfg.n_sparse * cfg.embed_dim,) + tuple(cfg.mlp_dims) + (1,)
        return sum(a * b for a, b in zip(dims, dims[1:]))
    if cfg.model == "bst":
        d = cfg.embed_dim
        blk = cfg.n_blocks * (4 * d * d + 8 * d * d)
        s = cfg.seq_len + 1
        dims = (s * d,) + tuple(cfg.tower_mlp) + (1,)
        return blk + sum(a * b for a, b in zip(dims, dims[1:]))
    if cfg.model == "two_tower":
        dims = (cfg.embed_dim,) + tuple(cfg.tower_mlp)
        return 2 * sum(a * b for a, b in zip(dims, dims[1:]))
    raise ValueError(cfg.model)


def _recsys_batch_struct(cfg: RecsysConfig, b: int) -> Dict:
    S = jax.ShapeDtypeStruct
    if cfg.model == "two_tower":
        return {"user_ids": S((b,), jnp.int32),
                "item_ids": S((b,), jnp.int32),
                "item_logq": S((b,), jnp.float32)}
    if cfg.model == "bst":
        return {"hist_ids": S((b, cfg.seq_len), jnp.int32),
                "target_id": S((b,), jnp.int32),
                "label": S((b,), jnp.float32)}
    return {"sparse_ids": S((b, cfg.n_sparse), jnp.int32),
            "label": S((b,), jnp.float32)}


def recsys_train_cell(arch: str, cfg: RecsysConfig, shape: ShapeSpec, mesh,
                      multi_pod: bool) -> Cell:
    dp_axes, dp_n = _dp(mesh, multi_pod)
    b = shape.batch
    model = _recsys_model(cfg)
    ocfg = opt_lib.OptimizerConfig(kind="adagrad", lr=1e-2)

    state_struct = jax.eval_shape(
        lambda k: TrainState.create(ocfg, model.init(k)),
        jax.random.PRNGKey(0))
    p_spec = rules.spec_tree(state_struct.params,
                             rules.recsys_param_rules(cfg, mesh))
    # adagrad acc mirrors the params, so it shards exactly like them
    o_spec = {"step": P(), "acc": p_spec}
    state_shard = TrainState(_named(mesh, p_spec), _named(mesh, o_spec))

    batch_struct = _recsys_batch_struct(cfg, b)
    bspec = jax.tree.map(
        lambda st: P(dp_axes, *(None,) * (len(st.shape) - 1)),
        batch_struct)

    def step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state.params, batch)
        new_p, new_o = opt_lib.apply_updates(ocfg, state.params, grads,
                                             state.opt_state)
        return TrainState(new_p, new_o), metrics

    flops = 6.0 * _recsys_dense_params(cfg) * b
    return Cell(arch, shape.name, step, (state_struct, batch_struct),
                (state_shard, _named(mesh, bspec)), (state_shard, None),
                flops, donate=(0,), note=f"train_step B={b}")


def recsys_serve_cell(arch: str, cfg: RecsysConfig, shape: ShapeSpec, mesh,
                      multi_pod: bool) -> Cell:
    dp_axes, dp_n = _dp(mesh, multi_pod)
    b = shape.batch
    model = _recsys_model(cfg)
    params_struct = jax.eval_shape(lambda k: model.init(k),
                                   jax.random.PRNGKey(0))
    p_spec = rules.spec_tree(params_struct,
                             rules.recsys_param_rules(cfg, mesh))

    if cfg.model == "two_tower":
        # dot-product scoring of (user, item) pairs on the serving path
        def fn(params, batch):
            u, _ = model.user_vec(params, batch["user_ids"])
            v, _ = model.item_vec(params, batch["item_ids"])
            return jnp.sum(u * v, axis=-1)
        batch_struct = {"user_ids": jax.ShapeDtypeStruct((b,), jnp.int32),
                        "item_ids": jax.ShapeDtypeStruct((b,), jnp.int32)}
        args = (params_struct, batch_struct)
        bspec = jax.tree.map(lambda st: P(dp_axes), batch_struct)
        shards = (_named(mesh, p_spec), _named(mesh, bspec))
    else:
        # CTR serving path: quantized artifacts replace the big tables
        fields = model.fields if hasattr(model, "fields") else None
        if cfg.model == "bst":
            artifact_struct = model.item_emb.serving_artifact_struct()
        else:
            artifact_struct = fields.artifact_struct()
        batch_struct = _recsys_batch_struct(cfg, b)
        batch_struct.pop("label")
        if cfg.model == "bst":
            serve_params = dict(params_struct)
            serve_params["item_emb"] = {
                k: v for k, v in params_struct["item_emb"].items()
                if k != "emb"}
        else:
            serve_params = dict(params_struct)
            serve_params["fields"] = {
                fk: {k: v for k, v in fv.items() if k != "emb"}
                for fk, fv in params_struct["fields"].items()}

        def art_spec(tree):
            def one(path, leaf):
                name = rules._path_name(path)
                if name.endswith("codes") or name.endswith("/q") \
                        or name.endswith("emb") or name.endswith("/u"):
                    if leaf.shape[0] >= 16 * mesh.shape["model"] \
                            and leaf.shape[0] % mesh.shape["model"] == 0:
                        return P("model", *(None,) * (len(leaf.shape) - 1))
                return P()
            return jax.tree_util.tree_map_with_path(one, tree)

        def fn(params, artifacts, batch):
            return model.serve(params, artifacts, batch)
        args = (serve_params, artifact_struct, batch_struct)
        sp_spec = rules.spec_tree(serve_params,
                                  rules.recsys_param_rules(cfg, mesh))
        bspec = jax.tree.map(
            lambda st: P(dp_axes, *(None,) * (len(st.shape) - 1)),
            batch_struct)
        shards = (_named(mesh, sp_spec), _named(mesh, art_spec(artifact_struct)),
                  _named(mesh, bspec))

    flops = 2.0 * _recsys_dense_params(cfg) * b
    return Cell(arch, shape.name, fn, args, shards, None, flops,
                note=f"serve B={b} (quantized artifacts)")


def recsys_retrieval_cell(arch: str, cfg: RecsysConfig, shape: ShapeSpec,
                          mesh, multi_pod: bool) -> Cell:
    dp_axes, dp_n = _dp(mesh, multi_pod)
    n_cand = _pad_to(shape.n_candidates, mesh.size)
    model = _recsys_model(cfg)
    params_struct = jax.eval_shape(lambda k: model.init(k),
                                   jax.random.PRNGKey(0))
    p_spec = rules.spec_tree(params_struct,
                             rules.recsys_param_rules(cfg, mesh))
    all_axes = dp_axes + ("model",)

    if cfg.model == "two_tower":
        # beyond-paper ADC: corpus tower outputs PQ-coded; score via LUT.
        d_out = cfg.tower_mlp[-1]
        n_sub = 16 if d_out % 16 == 0 else 8
        corpus_struct = {
            "codes": jax.ShapeDtypeStruct((n_cand, n_sub), jnp.uint8),
            "centroids": jax.ShapeDtypeStruct(
                (n_sub, 256, d_out // n_sub), jnp.float32)}
        corpus_spec = {"codes": P(all_axes, None), "centroids": P()}
        user_struct = jax.ShapeDtypeStruct((1,), jnp.int32)

        def fn(params, corpus, user_id):
            from repro.core import adc
            u, _ = model.user_vec(params, user_id)
            return adc.adc_scores(corpus, u[0])

        args = (params_struct, corpus_struct, user_struct)
        shards = (_named(mesh, p_spec), _named(mesh, corpus_spec),
                  NamedSharding(mesh, P()))
        flops = (2.0 * _recsys_dense_params(cfg) / 2
                 + 0)  # one user tower; LUT-sum is memory-bound
        flops += 2.0 * n_cand * n_sub          # the LUT adds
        note = f"ADC retrieval 1x{n_cand} (PQ-coded corpus)"
    else:
        # CTR bulk candidate scoring: one context x N candidate items.
        batch_struct = _recsys_batch_struct(cfg, n_cand)
        batch_struct.pop("label")
        bspec = jax.tree.map(
            lambda st: P(all_axes, *(None,) * (len(st.shape) - 1)),
            batch_struct)

        def fn(params, batch):
            out, _ = model.apply(params, batch)
            return out
        args = (params_struct, batch_struct)
        shards = (_named(mesh, p_spec), _named(mesh, bspec))
        flops = 2.0 * _recsys_dense_params(cfg) * n_cand
        note = f"candidate scoring 1x{n_cand}"
    return Cell(arch, shape.name, fn, args, shards, None, flops, note=note)


# ======================================================================
# dispatch
# ======================================================================

# named §Perf optimizations applied on top of the baseline configs
_LM_CFG_OPTS = {
    "moe_shard_map": dict(moe_shard_map=True),
    "remat_group": dict(remat_granularity="group"),
    "split_cache": dict(split_local_global_cache=True),
    "xent_chunk_256": dict(xent_chunk=256),
    "attn_block_2048": dict(attention_block=2048),
    "fsdp": dict(fsdp_params=True),
    "kv_repeat": dict(attn_kv_repeat=True),
}


def build_cell(arch: str, shape: ShapeSpec, mesh, multi_pod: bool,
               opts: Tuple[str, ...] = ()) -> Cell:
    family, cfg = get_arch(arch)
    note_extra = f" +opts[{','.join(opts)}]" if opts else ""
    microbatches = 1
    for o in opts:
        if o.startswith("microbatch"):
            microbatches = int(o[len("microbatch"):])
        elif o == "embed_full" and family == "lm":
            # ablation: plain full-table embedding instead of MGQE —
            # isolates the paper technique's train-step overhead
            from repro.core.types import EmbeddingConfig
            cfg = dataclasses.replace(
                cfg, embedding=EmbeddingConfig(vocab_size=cfg.vocab_size,
                                               dim=cfg.d_model))
        elif o == "embed_sharded_rows" and family == "lm":
            # token-embedding row gathers via the shard_map path
            cfg = dataclasses.replace(
                cfg, embedding=dataclasses.replace(cfg.embedding,
                                                   sharded_rows=True))
        elif family == "lm" and o in _LM_CFG_OPTS:
            cfg = dataclasses.replace(cfg, **_LM_CFG_OPTS[o])
        elif o == "sharded_embedding" and family == "recsys":
            cfg = dataclasses.replace(cfg, sharded_embedding=True)
        else:
            raise ValueError(f"unknown opt {o!r} for family {family}")
    if family == "lm":
        if shape.kind == "train":
            cell = lm_train_cell(arch, cfg, shape, mesh, multi_pod,
                                 microbatches=microbatches)
        elif shape.kind == "prefill":
            cell = lm_prefill_cell(arch, cfg, shape, mesh, multi_pod)
        else:
            cell = lm_decode_cell(arch, cfg, shape, mesh, multi_pod)
    elif family == "gnn":
        cell = mace_cell(arch, cfg, shape, mesh, multi_pod)
    elif family == "recsys":
        if shape.kind == "rec_train":
            cell = recsys_train_cell(arch, cfg, shape, mesh, multi_pod)
        elif shape.kind == "rec_serve":
            cell = recsys_serve_cell(arch, cfg, shape, mesh, multi_pod)
        else:
            cell = recsys_retrieval_cell(arch, cfg, shape, mesh, multi_pod)
    else:
        raise ValueError(family)
    cell.note += note_extra
    return cell
