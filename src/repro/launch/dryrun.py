import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]

The XLA_FLAGS line above MUST run before any jax import (jax locks the
device count on first init) — which is why this module must never be
imported by anything that already initialized jax.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.registry import ARCHS, SHAPE_SKIPS, shapes_for
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.roofline import analyze, terms_from_hlo


def run_cell(arch: str, shape, mesh, multi_pod: bool,
             verbose: bool = True, opts=()) -> dict:
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, multi_pod, opts=tuple(opts))
    jit_fn = jax.jit(cell.fn,
                     in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate)
    with mesh:
        lowered = jit_fn.lower(*cell.args)
        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()          # raw XLA (scan bodies x1)
    hc = analyze(compiled.as_text())         # loop-weighted
    coll_kinds = hc.collective_by_kind
    counts = hc.collective_counts
    terms = terms_from_hlo(hc, mesh.size, cell.model_flops)
    if hc.warnings:
        print(f"  [hlo warnings] {hc.warnings[:3]}")

    row = {
        "arch": arch, "shape": shape.name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "note": cell.note,
        "opts": ",".join(opts),
        "compile_s": round(t1 - t0, 1),
        # memory (per device)
        "args_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
        "out_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
        "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
        "peak_gb": (getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)) / 1e9,
        # roofline terms (per-device partitioned module, loop-weighted)
        "flops": terms.hlo_flops,
        "bytes": terms.hlo_bytes,
        "xla_raw_flops": float(cost.get("flops", 0.0)),
        "coll_bytes": terms.collective_bytes,
        "coll_kinds": coll_kinds,
        "coll_counts": counts,
        "compute_ms": terms.compute_s * 1e3,
        "memory_ms": terms.memory_s * 1e3,
        "collective_ms": terms.collective_s * 1e3,
        "dominant": terms.dominant,
        "model_flops": cell.model_flops,
        "useful_frac": terms.useful_fraction,
        "roofline_frac": terms.roofline_fraction,
    }
    if verbose:
        uf = row["useful_frac"]
        rf = row["roofline_frac"]
        print(f"[{arch} x {shape.name}] {cell.note}")
        print(f"  compile {row['compile_s']}s | per-dev args "
              f"{row['args_gb']:.2f} GB, temps {row['temp_gb']:.2f} GB, "
              f"peak {row['peak_gb']:.2f} GB")
        print(f"  terms ms: compute {row['compute_ms']:.3f} | memory "
              f"{row['memory_ms']:.3f} | collective "
              f"{row['collective_ms']:.3f}  -> {row['dominant']}-bound")
        print(f"  collectives: {counts}")
        print(f"  useful_frac {uf if uf is None else round(uf, 3)} | "
              f"roofline_frac {rf if rf is None else round(rf, 3)}")
        sys.stdout.flush()
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None, help="append rows to this file")
    ap.add_argument("--opt", default="",
                    help="comma-separated §Perf optimizations, e.g. "
                         "moe_shard_map,remat_group,microbatch2")
    args = ap.parse_args()
    opts = tuple(o for o in args.opt.split(",") if o)

    meshes = []
    if args.both_meshes:
        meshes = [(make_production_mesh(multi_pod=False), False),
                  (make_production_mesh(multi_pod=True), True)]
    else:
        meshes = [(make_production_mesh(multi_pod=args.multi_pod),
                   args.multi_pod)]

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in shapes_for(arch):
                skip = SHAPE_SKIPS.get((arch, shape.name))
                if skip:
                    print(f"[{arch} x {shape.name}] SKIPPED: {skip}")
                    continue
                cells.append((arch, shape))
    else:
        if not args.arch:
            raise SystemExit("--arch or --all required")
        for shape in shapes_for(args.arch):
            if args.shape and shape.name != args.shape:
                continue
            skip = SHAPE_SKIPS.get((args.arch, shape.name))
            if skip:
                print(f"[{args.arch} x {shape.name}] SKIPPED: {skip}")
                continue
            cells.append((args.arch, shape))

    rows, failures = [], []
    for mesh, multi_pod in meshes:
        print(f"=== mesh {mesh.devices.shape} "
              f"({'multi-pod' if multi_pod else 'single-pod'}) ===")
        for arch, shape in cells:
            try:
                rows.append(run_cell(arch, shape, mesh, multi_pod,
                                     opts=opts))
            except Exception:
                failures.append((arch, shape.name, multi_pod))
                print(f"[{arch} x {shape.name}] FAILED")
                traceback.print_exc()
                sys.stdout.flush()

    if args.json:
        existing = []
        if os.path.exists(args.json):
            with open(args.json) as f:
                existing = json.load(f)
        with open(args.json, "w") as f:
            json.dump(existing + rows, f, indent=1, default=str)
        print(f"wrote {len(rows)} rows -> {args.json}")

    print(f"\n{len(rows)} cells OK, {len(failures)} failed")
    for f_ in failures:
        print("  FAILED:", f_)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
