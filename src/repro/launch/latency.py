"""Fixed log-bucket latency histogram for the serving engines.

Latency SLOs are statements about tail percentiles (p99/p999), and a
serving loop that answers millions of lookups cannot keep a float per
request to compute them — the tracker must be O(1) per observation and
O(buckets) in memory, mergeable across engines/threads, and readable at
any moment without touching the recording path's cost model.

``LatencyHistogram`` is the standard fix (HdrHistogram/Prometheus
shape): geometric buckets ``[lo·g^i, lo·g^(i+1))`` so RELATIVE
resolution is constant across six decades of latency — with the
defaults (``lo`` = 1 µs, ``g`` = 2^(1/4), 128 buckets) every readout is
exact to within ~19% of the true sample (one bucket width), covering
1 µs .. ~1 hour.  Recording is an integer increment; percentile readout
walks the cumulative counts; ``merge`` is elementwise addition, so
histograms from independent streams (or a warm/measure split) compose
losslessly at bucket granularity.

Readout convention: ``percentile`` returns the UPPER edge of the bucket
holding the rank-``⌈q·n⌉`` sample — a conservative (never optimistic)
latency bound, which is the side an SLO check must err on.  Empty
histograms read as NaN, never raise: a stream with zero completed
requests has no percentile, and the stats export path must survive it
(`launch/engine.py::EngineStats.as_dict`).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence

import numpy as np

__all__ = ["LatencyHistogram"]


class LatencyHistogram:
    """Mergeable log-bucket histogram over positive durations (seconds).

    Bucket ``i`` covers ``[lo·g^i, lo·g^(i+1))``; observations below
    ``lo`` land in bucket 0 and observations beyond the last edge land
    in the final bucket (both clamps keep recording total — an SLO
    readout must count every request, however extreme).
    """

    def __init__(self, lo: float = 1e-6, growth: float = 2.0 ** 0.25,
                 n_buckets: int = 128):
        if not (lo > 0 and growth > 1 and n_buckets >= 1):
            raise ValueError(
                f"need lo > 0, growth > 1, n_buckets >= 1; got "
                f"lo={lo}, growth={growth}, n_buckets={n_buckets}")
        self.lo = float(lo)
        self.growth = float(growth)
        self._log_g = math.log(self.growth)
        self.counts = np.zeros(n_buckets, np.int64)

    # ------------------------------------------------------------ record
    @property
    def n_buckets(self) -> int:
        return len(self.counts)

    def bucket_of(self, seconds: float) -> int:
        """Index of the bucket a duration falls into (clamped)."""
        if not seconds > self.lo:        # also catches NaN / negatives
            return 0
        i = int(math.log(seconds / self.lo) / self._log_g)
        return min(i, self.n_buckets - 1)

    def record(self, seconds: float) -> None:
        self.counts[self.bucket_of(seconds)] += 1

    def record_many(self, seconds: Sequence[float]) -> None:
        s = np.asarray(seconds, np.float64)
        if s.size == 0:
            return
        with np.errstate(divide="ignore", invalid="ignore"):
            i = np.floor(np.log(s / self.lo) / self._log_g)
        i = np.where(np.isfinite(i), i, 0)       # <= lo, NaN -> bucket 0
        i = np.clip(i, 0, self.n_buckets - 1).astype(np.int64)
        np.add.at(self.counts, i, 1)

    # ----------------------------------------------------------- readout
    @property
    def count(self) -> int:
        return int(self.counts.sum())

    def bucket_upper(self, i: int) -> float:
        """Upper edge of bucket ``i`` — the conservative readout value."""
        return self.lo * self.growth ** (i + 1)

    def percentile(self, q: float) -> float:
        """Upper-bound latency (seconds) of the ``q``-quantile sample,
        ``q`` in [0, 1].  NaN on an empty histogram — callers printing
        or exporting stats must not crash on a request-free stream."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must lie in [0, 1], got {q}")
        total = self.count
        if total == 0:
            return math.nan
        rank = max(1, math.ceil(q * total))      # 1-based order statistic
        cum = np.cumsum(self.counts)
        return self.bucket_upper(int(np.searchsorted(cum, rank)))

    # convenience for stats export / printing (milliseconds)
    @property
    def p50_ms(self) -> float:
        return self.percentile(0.50) * 1e3

    @property
    def p99_ms(self) -> float:
        return self.percentile(0.99) * 1e3

    @property
    def p999_ms(self) -> float:
        return self.percentile(0.999) * 1e3

    # ------------------------------------------------------------- merge
    def compatible(self, other: "LatencyHistogram") -> bool:
        return (self.lo == other.lo and self.growth == other.growth
                and self.n_buckets == other.n_buckets)

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Elementwise-sum merge (new histogram; operands untouched).
        Exact at bucket granularity: merge(h1, h2) has the bucket
        counts of a histogram fed both sample streams."""
        if not self.compatible(other):
            raise ValueError(
                "cannot merge histograms with different bucket schemes "
                f"(lo {self.lo} vs {other.lo}, growth {self.growth} vs "
                f"{other.growth}, buckets {self.n_buckets} vs "
                f"{other.n_buckets})")
        out = LatencyHistogram(self.lo, self.growth, self.n_buckets)
        out.counts = self.counts + other.counts
        return out

    # ------------------------------------------------------------ export
    def as_dict(self) -> Dict:
        """Compact export: summary percentiles + the nonzero buckets
        (index -> count), enough to reconstruct the histogram."""
        nz = np.nonzero(self.counts)[0]
        return {
            "count": self.count,
            "p50_ms": self.p50_ms,
            "p99_ms": self.p99_ms,
            "p999_ms": self.p999_ms,
            "lo_s": self.lo,
            "growth": self.growth,
            "nonzero_buckets": {int(i): int(self.counts[i]) for i in nz},
        }

    def __repr__(self) -> str:
        if self.count == 0:
            return "LatencyHistogram(empty)"
        return (f"LatencyHistogram(n={self.count}, p50={self.p50_ms:.3f}ms,"
                f" p99={self.p99_ms:.3f}ms, p999={self.p999_ms:.3f}ms)")


def percentile_exact(samples: Sequence[float],
                     q: float) -> Optional[float]:
    """Reference order-statistic percentile (testing aid): the
    rank-⌈q·n⌉ smallest sample, or None when empty — the value a
    histogram readout must upper-bound within one bucket width."""
    s = sorted(samples)
    if not s:
        return None
    return s[max(1, math.ceil(q * len(s))) - 1]
