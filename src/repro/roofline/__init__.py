from repro.roofline.hlo import (HloCost, analyze, collective_bytes,
                                collective_counts)
from repro.roofline.model import (HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16,
                                  RooflineTerms, kernel_roofline,
                                  lm_forward_model_flops,
                                  lm_train_model_flops,
                                  terms_from_analysis, terms_from_hlo)

__all__ = [
    "HloCost", "analyze", "collective_bytes", "collective_counts",
    "RooflineTerms", "kernel_roofline", "terms_from_analysis",
    "terms_from_hlo", "lm_train_model_flops", "lm_forward_model_flops",
    "PEAK_FLOPS_BF16", "HBM_BW", "ICI_LINK_BW",
]
