"""Loop-weighted cost analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every while-loop (lax.scan) body
ONCE — a 62-layer scanned transformer reports ~1/62 of its real FLOPs.
This module re-derives the three roofline inputs from
``compiled.as_text()`` with correct loop multiplicities:

  * parse the module into computations,
  * build the call graph (fusion ``calls=``, while ``body=/condition=``
    with ``backend_config known_trip_count``, conditional branches),
  * weight every op by its computation's multiplicity,
  * sum:  flops  — dot ops: 2 * |out| * k  (+ |out| per elementwise op)
          bytes  — operands + outputs of top-level ops (fusion innards
                   excluded: a fusion reads its operands and writes its
                   outputs once — XLA's own HBM model)
          collective_bytes — operand bytes of all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute

Everything is per-device (the partitioned module).  While loops with
unknown trip counts are counted once and reported in ``warnings``.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# opcodes that move no bytes / do no work
_FREE_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter",
             "constant", "after-all", "iota"}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "and",
    "or", "xor", "not", "select", "compare", "convert", "floor", "ceil",
    "sign", "cosine", "sine", "clamp", "remainder", "atan2", "expm1",
    "log1p", "logistic", "round-nearest-even", "cbrt", "erf",
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\],{}]+)\s+"
    r"([\w\-]+)\((.*)")
_SHAPE = re.compile(r"^([a-z][a-z0-9]*)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUEB = re.compile(r"true_computation=%?([\w.\-]+)")
_FALSEB = re.compile(r"false_computation=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[^\d]*(\d+)')
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _parse_shape(s: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _SHAPE.match(s)
    if not m:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
    return m.group(1), dims


def _shape_bytes(shape_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(shape_str: str) -> int:
    m = _SHAPE.match(shape_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class _Op:
    name: str
    shape: str           # raw type string ("f32[4,256]{1,0}" or tuple)
    opcode: str
    rest: str            # everything after the opening paren


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]


def _split_computations(text: str) -> Tuple[Dict[str, _Computation], str]:
    comps: Dict[str, _Computation] = {}
    entry = ""
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HDR.match(stripped)
                if m:
                    cur = _Computation(m.group(2), [])
                    if m.group(1):
                        entry = m.group(2)
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            cur.ops.append(_Op(m.group(1), m.group(2), m.group(3),
                               m.group(4)))
    return comps, entry


def _multiplicities(comps: Dict[str, _Computation], entry: str
                    ) -> Tuple[Dict[str, float], Dict[str, bool], List[str]]:
    """Computation -> times executed per step; fusion-body flags;
    warnings for unknown trip counts."""
    mult: Dict[str, float] = defaultdict(float)
    is_fusion_body: Dict[str, bool] = defaultdict(bool)
    warnings: List[str] = []
    if entry not in comps:
        return mult, is_fusion_body, ["no entry computation found"]
    mult[entry] = 1.0

    # breadth-first over the call graph; HLO computation graphs are DAGs
    order = [entry]
    seen = {entry}
    idx = 0
    while idx < len(order):
        cname = order[idx]
        idx += 1
        cm = mult[cname]
        for op in comps[cname].ops:
            callees: List[Tuple[str, float, bool]] = []
            if op.opcode == "while":
                b = _BODY.search(op.rest)
                c = _COND.search(op.rest)
                t = _TRIP.search(op.rest)
                n = float(t.group(1)) if t else 1.0
                if not t:
                    warnings.append(
                        f"while {op.name} in {cname}: unknown trip count")
                if b:
                    callees.append((b.group(1), cm * n, False))
                if c:
                    callees.append((c.group(1), cm * (n + 1), False))
            elif op.opcode == "fusion":
                c = _CALLS.search(op.rest)
                if c:
                    callees.append((c.group(1), cm, True))
            elif op.opcode == "conditional":
                for m_ in (_BRANCHES, ):
                    br = m_.search(op.rest)
                    if br:
                        for b in br.group(1).split(","):
                            callees.append((b.strip().lstrip("%"), cm, False))
                tb, fb = _TRUEB.search(op.rest), _FALSEB.search(op.rest)
                if tb:
                    callees.append((tb.group(1), cm, False))
                if fb:
                    callees.append((fb.group(1), cm, False))
            elif op.opcode in ("call", "custom-call", "reduce", "sort",
                               "scatter", "select-and-scatter", "map",
                               "reduce-window", "all-reduce",
                               "reduce-scatter"):
                c = _TO_APPLY.search(op.rest)
                if c:
                    # tiny scalar apply fns: count flops, never bytes
                    callees.append((c.group(1), cm, True))
            for callee, m_add, fus in callees:
                if callee not in comps:
                    continue
                mult[callee] += m_add
                if fus:
                    is_fusion_body[callee] = True
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    return mult, is_fusion_body, warnings


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    collective_bytes: float
    collective_by_kind: Dict[str, float]
    collective_counts: Dict[str, int]
    warnings: List[str]
    # loop-weighted per-source attribution (jax op_name prefix -> bytes)
    bytes_by_source: Optional[Dict[str, float]] = None
    collective_by_source: Optional[Dict[str, float]] = None


_METADATA_OPNAME = re.compile(r'op_name="([^"]*)"')


def _source_key(line_rest: str, depth: int = 4) -> str:
    m = _METADATA_OPNAME.search(line_rest)
    if not m:
        return "<no-metadata>"
    parts = m.group(1).split("/")
    return "/".join(parts[:depth])


def _slice_like_computations(comps: Dict[str, _Computation]
                             ) -> Tuple[set, set]:
    """Fusion bodies that are just a (dynamic-)slice / dynamic-update-
    slice (+ bitcasts): their callers must NOT be charged the full
    operand — a slice reads only its window, an update writes only its
    window.  Without this, every lax.scan layer-slice counts the whole
    (L, ...) stack once per iteration (a ~10x bytes overcount for
    stacked-layer models)."""
    ds, dus = set(), set()
    for name, comp in comps.items():
        real = [op for op in comp.ops
                if op.opcode not in _FREE_OPS and op.opcode != "copy"]
        if not real or len(real) > 3:
            continue
        kinds = {op.opcode for op in real}
        if kinds <= {"dynamic-slice", "slice", "reshape", "transpose"} \
                and ("dynamic-slice" in kinds or "slice" in kinds):
            ds.add(name)
        elif "dynamic-update-slice" in kinds and len(kinds) <= 2:
            dus.add(name)
    return ds, dus


def _convert_only_computations(comps: Dict[str, _Computation]) -> set:
    """Fusion bodies that only convert dtypes (+ broadcasts of consts).
    The CPU backend emulates bf16 arithmetic in f32, wrapping most bf16
    ops in convert fusions that DO NOT EXIST on TPU (bf16 is native);
    `analyze(..., tpu_fusion=True)` charges them 0 to approximate the
    TPU memory behaviour (used for the §Roofline calibration note)."""
    out = set()
    for name, comp in comps.items():
        real = [op for op in comp.ops if op.opcode not in _FREE_OPS]
        if real and {op.opcode for op in real} <= {"convert", "broadcast",
                                                   "copy"}:
            out.add(name)
    return out


def _smallest_tensor_operand(op: _Op, defs: Dict[str, str]) -> int:
    sizes = []
    for om in _OPERAND.finditer(op.rest.split(")")[0]):
        b = _shape_bytes(defs.get(om.group(1), ""))
        if b > 8:                                   # skip scalars/indices
            sizes.append(b)
    return min(sizes) if sizes else 0


def analyze(text: str, attribute: bool = False,
            tpu_fusion: bool = False) -> HloCost:
    comps, entry = _split_computations(text)
    mult, is_fusion_body, warnings = _multiplicities(comps, entry)
    ds_comps, dus_comps = _slice_like_computations(comps)
    cv_comps = _convert_only_computations(comps) if tpu_fusion else set()

    flops = 0.0
    nbytes = 0.0
    coll_b: Dict[str, float] = defaultdict(float)
    coll_n: Dict[str, int] = defaultdict(int)
    bytes_src: Dict[str, float] = defaultdict(float)
    coll_src: Dict[str, float] = defaultdict(float)

    for cname, comp in comps.items():
        cm = mult.get(cname, 0.0)
        if cm == 0.0:
            continue
        fusion_body = is_fusion_body.get(cname, False)
        # local def map for operand shape resolution
        defs = {op.name: op.shape for op in comp.ops}
        # parameters: shapes appear in the header — resolve lazily from
        # operand uses annotated inline when available (full HLO text
        # usually annotates operands of collectives; defs cover the rest)
        for op in comp.ops:
            oc = op.opcode
            if oc in _FREE_OPS:
                continue
            out_bytes = _shape_bytes(op.shape)
            out_elems = _numel(op.shape.split("{")[0]) \
                if not op.shape.startswith("(") else 0

            # ---- flops
            if oc in ("dot", "dot-general"):
                k = 1
                lhs_name_m = _OPERAND.search(op.rest)
                lc = _LHS_CONTRACT.search(op.rest)
                if lhs_name_m and lc and lc.group(1):
                    lhs_shape = defs.get(lhs_name_m.group(1))
                    if lhs_shape:
                        p = _parse_shape(lhs_shape)
                        if p:
                            dims = p[1]
                            for di in lc.group(1).split(","):
                                di = int(di)
                                if di < len(dims):
                                    k *= dims[di]
                flops += cm * 2.0 * out_elems * k
            elif oc in _ELEMENTWISE or oc in ("reduce", "broadcast",
                                              "transpose", "reverse",
                                              "exponential-minus-one"):
                flops += cm * out_elems

            # ---- bytes (HBM model: top-level ops only)
            if not fusion_body:
                callee = None
                if oc == "fusion":
                    cmatch = _CALLS.search(op.rest)
                    callee = cmatch.group(1) if cmatch else None
                if callee in cv_comps or (tpu_fusion and oc == "convert"):
                    op_bytes = 0                      # native-bf16 target
                elif oc in ("dynamic-slice", "slice") or callee in ds_comps:
                    op_bytes = 2 * out_bytes          # window read + write
                elif oc == "dynamic-update-slice" or callee in dus_comps:
                    upd = _smallest_tensor_operand(op, defs)
                    op_bytes = 2 * (upd or out_bytes)
                elif oc == "while":
                    # free: the body's producing ops already count every
                    # iteration's real traffic; charging the carry tuple
                    # (which aliases loop-invariant weight stacks) here
                    # would phantom-count TBs on nested scans
                    op_bytes = 0
                else:
                    in_bytes = 0
                    # operand list = everything before the first ')'
                    for om in _OPERAND.finditer(op.rest.split(")")[0]):
                        shp = defs.get(om.group(1))
                        if shp:
                            in_bytes += _shape_bytes(shp)
                    op_bytes = in_bytes + out_bytes
                nbytes += cm * op_bytes
                if attribute:
                    bytes_src[_source_key(op.rest)] += cm * op_bytes

            # ---- collectives
            base = oc.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not oc.endswith("-done"):
                in_b = 0
                for om in _OPERAND.finditer(op.rest.split(")")[0]):
                    shp = defs.get(om.group(1))
                    if shp:
                        in_b += _shape_bytes(shp)
                if in_b == 0:
                    # operand defined in another computation (rare) —
                    # fall back to output size
                    in_b = out_bytes
                coll_b[base] += cm * in_b
                coll_n[base] += int(cm)
                if attribute:
                    coll_src[_source_key(op.rest)] += cm * in_b

    return HloCost(flops=flops, bytes=nbytes,
                   collective_bytes=float(sum(coll_b.values())),
                   collective_by_kind=dict(coll_b),
                   collective_counts=dict(coll_n),
                   warnings=warnings,
                   bytes_by_source=dict(bytes_src) if attribute else None,
                   collective_by_source=dict(coll_src) if attribute else None)


# ------------------------------------------------------------------
# back-compat helpers (earlier interface)
# ------------------------------------------------------------------

def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    c = analyze(hlo_text)
    return c.collective_bytes, c.collective_by_kind


def collective_counts(hlo_text: str) -> Dict[str, int]:
    return analyze(hlo_text).collective_counts
