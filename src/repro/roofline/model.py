"""Three-term roofline model for TPU v5e (the target part).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-
program totals across all devices when compiled under SPMD — cost
analysis reports the per-device partitioned module, so we scale by the
device count explicitly where noted).  collective_bytes comes from the
HLO parser (hlo.py).  The terms are *seconds*; the largest is the
bottleneck a perfect overlap schedule cannot hide.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


# TPU v5e hardware constants (per chip) — from the assignment spec.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_LINK_BW = 50e9              # bytes/s per link (~, assignment spec)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float            # per-device partitioned-module FLOPs
    hlo_bytes: float            # per-device bytes accessed
    collective_bytes: float     # per-device collective operand bytes
    model_flops: float = 0.0    # 6*N*D useful FLOPs (whole step, global)
    chips: int = 1

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> Optional[float]:
        """MODEL_FLOPS / (HLO_FLOPs * chips): how much of the compiled
        compute is 'useful' — catches remat/redundancy waste."""
        if not self.model_flops or not self.hlo_flops:
            return None
        return self.model_flops / (self.hlo_flops * self.chips)

    @property
    def roofline_fraction(self) -> Optional[float]:
        """Fraction of the compute roofline the step achieves if it runs
        exactly at the bound: (useful FLOPs / chips / peak) / bound_s."""
        if not self.model_flops or self.bound_s <= 0:
            return None
        ideal_s = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        return ideal_s / self.bound_s

    def row(self) -> Dict:
        return {
            "compute_ms": self.compute_s * 1e3,
            "memory_ms": self.memory_s * 1e3,
            "collective_ms": self.collective_s * 1e3,
            "dominant": self.dominant,
            "useful_frac": self.useful_fraction,
            "roofline_frac": self.roofline_fraction,
        }


def terms_from_analysis(cost: Dict, collective_bytes: float, chips: int,
                        model_flops: float = 0.0) -> RooflineTerms:
    """cost: compiled.cost_analysis() dict of the per-device partitioned
    module.  collective_bytes: per-device bytes through collectives.

    NOTE: XLA's cost_analysis counts while (lax.scan) bodies once; for
    scanned models prefer ``terms_from_hlo`` (loop-weighted).
    """
    flops = float(cost.get("flops", 0.0))
    # bytes accessed: XLA reports 'bytes accessed' plus operand breakdowns
    nbytes = float(cost.get("bytes accessed", 0.0))
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=nbytes / HBM_BW,
        collective_s=collective_bytes / ICI_LINK_BW,
        hlo_flops=flops,
        hlo_bytes=nbytes,
        collective_bytes=collective_bytes,
        model_flops=model_flops,
        chips=chips,
    )


def terms_from_hlo(hlo_cost, chips: int,
                   model_flops: float = 0.0) -> RooflineTerms:
    """hlo_cost: roofline.hlo.HloCost of the per-device partitioned
    module (loop-weighted — the correct path for scanned models)."""
    return RooflineTerms(
        compute_s=hlo_cost.flops / PEAK_FLOPS_BF16,
        memory_s=hlo_cost.bytes / HBM_BW,
        collective_s=hlo_cost.collective_bytes / ICI_LINK_BW,
        hlo_flops=hlo_cost.flops,
        hlo_bytes=hlo_cost.bytes,
        collective_bytes=hlo_cost.collective_bytes,
        model_flops=model_flops,
        chips=chips,
    )


def kernel_roofline(hlo_text: str, measured_s: float,
                    chips: int = 1) -> Dict:
    """Achieved-vs-peak for one compiled kernel (DESIGN.md §11).

    ``bound_s`` is the three-term roofline floor of the kernel's
    per-device HLO under the v5e constants; ``roofline_fraction =
    min(1, bound_s / measured_s)`` is the share of that hardware bound
    the measured run achieves — 1.0 means running at the roofline.
    (On the CPU CI runner the v5e constants make the bound far below
    the measured time, so fractions are small — the *invariant* the
    bench gates is only that the fraction exists and sits in (0, 1];
    the absolute value is meaningful on the target part.)
    """
    from repro.roofline.hlo import analyze
    terms = terms_from_hlo(analyze(hlo_text), chips)
    bound = terms.bound_s
    frac = None
    if measured_s > 0 and bound > 0:
        frac = min(1.0, bound / measured_s)
    return {
        "bound_ms": bound * 1e3,
        "bound_kind": terms.dominant,
        "roofline_fraction": frac,
        "hlo_flops": terms.hlo_flops,
        "hlo_bytes": terms.hlo_bytes,
    }


# ----------------------------------------------------------------------
# MODEL_FLOPS estimates (useful FLOPs per step)
# ----------------------------------------------------------------------

def lm_train_model_flops(n_params_active: int, tokens: int) -> float:
    """6*N*D for a train step (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params_active * tokens

def lm_forward_model_flops(n_params_active: int, tokens: int) -> float:
    """2*N*D for inference (prefill: tokens = B*S; decode: tokens = B)."""
    return 2.0 * n_params_active * tokens
