"""Training substrate: optimizers (built from scratch — the container
has no optax), LR schedules, sharded checkpointing with auto-resume,
straggler detection, elastic restore, and gradient compression."""
