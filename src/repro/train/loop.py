"""The training loop: jit'd step, periodic checkpointing, auto-resume,
straggler monitoring, failure injection (for tests), metric logging.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import TrainState
from repro.train.resilience import (FailureInjector, StepTimer,
                                    StragglerDetector)


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    log_every: int = 50
    ckpt_every: int = 0           # 0 = no checkpointing
    ckpt_dir: str = ""
    ckpt_keep: int = 3
    metrics_hook: Optional[Callable[[int, Dict], None]] = None


def fit(state: TrainState,
        step_fn: Callable,
        data_iter: Iterator,
        cfg: LoopConfig,
        donate: bool = True,
        injector: Optional[FailureInjector] = None,
        resume: bool = True) -> (TrainState, List[Dict]):
    """Runs ``step_fn`` to ``total_steps``; resumes from the newest
    committed checkpoint in ``ckpt_dir`` when present."""
    jit_step = jax.jit(step_fn, donate_argnums=(0,) if donate else ())

    start_step = 0
    if resume and cfg.ckpt_dir:
        restored, step = ckpt_lib.restore_latest(cfg.ckpt_dir, state)
        if restored is not None:
            state = restored
            start_step = step
    history: List[Dict] = []
    timer = StepTimer()
    detector = StragglerDetector(num_hosts=1)

    for step in range(start_step, cfg.total_steps):
        batch = next(data_iter)
        batch = jax.tree.map(lambda x: jax.numpy.asarray(x), batch)
        timer.start()
        state, metrics = jit_step(state, batch)
        if injector is not None:
            # materialize before the failure point so the checkpoint
            # below is never torn mid-step
            jax.block_until_ready(jax.tree.leaves(state)[0])
            injector.maybe_fail(step)
        dt = timer.stop()
        detector.record(0, dt)

        if cfg.ckpt_every and cfg.ckpt_dir \
                and (step + 1) % cfg.ckpt_every == 0:
            ckpt_lib.save(cfg.ckpt_dir, step + 1, state, keep=cfg.ckpt_keep)

        if (step + 1) % cfg.log_every == 0 or step == cfg.total_steps - 1:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            m["step"] = step + 1
            m["step_time_s"] = dt
            history.append(m)
            if cfg.metrics_hook:
                cfg.metrics_hook(step + 1, m)
    return state, history
