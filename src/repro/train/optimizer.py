"""Optimizers as pure functions over parameter pytrees.

Supported: adam, adamw, adagrad (the classic for sparse recsys
embeddings), sgd (momentum).  All state lives in a pytree mirroring the
params, so it shards/checkpoints exactly like the params do.

Each optimizer is one :class:`OptimizerRule` registered under its kind
string (same pattern as the embedding-scheme registry, DESIGN.md §7):
``init``/``apply_updates`` resolve the rule from the registry instead
of branching per kind, so adding an optimizer is one class + one
decorator.  Moment-buffer keys (``m``/``v``/``acc``/``mom``) are part
of the rule, so checkpoints and the ZeRO-1 sharding rules
(sharding/rules.py) see the same state layout as before.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adam"          # adam | adamw | adagrad | sgd
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0   # adamw
    momentum: float = 0.9       # sgd
    grad_clip: Optional[float] = 1.0   # global-norm clip; None = off
    # schedule: constant | cosine | linear_warmup_cosine
    schedule: str = "constant"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule_lr(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    base = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.schedule == "constant":
        return base
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "linear_warmup_cosine" or cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
        return base * warm * frac
    raise ValueError(cfg.schedule)


# ----------------------------------------------------------------------
# optimizer-rule registry
# ----------------------------------------------------------------------

class OptimizerRule:
    """One optimizer: moment-buffer layout + the update math."""

    state_keys: Tuple[str, ...] = ()

    @classmethod
    def update(cls, cfg: OptimizerConfig, lr, step, params, grads,
               moments: Dict) -> Tuple[Any, Dict]:
        """-> (new_params, new_moments) with the same ``state_keys``."""
        raise NotImplementedError


_OPTIMIZERS: Dict[str, Type[OptimizerRule]] = {}


def register_optimizer(kind: str):
    def deco(cls: Type[OptimizerRule]) -> Type[OptimizerRule]:
        prev = _OPTIMIZERS.get(kind)
        if prev is not None and prev is not cls:
            raise ValueError(
                f"optimizer kind {kind!r} already registered to {prev}")
        _OPTIMIZERS[kind] = cls
        return cls
    return deco


def _rule(kind: str) -> Type[OptimizerRule]:
    try:
        return _OPTIMIZERS[kind]
    except KeyError:
        raise ValueError(
            f"unknown optimizer kind {kind!r}; registered: "
            f"{', '.join(sorted(_OPTIMIZERS))}") from None


@register_optimizer("adam")
class _Adam(OptimizerRule):
    state_keys = ("m", "v")
    decoupled_weight_decay = False

    @classmethod
    def update(cls, cfg, lr, step, params, grads, moments):
        t = (step + 1).astype(jnp.float32)
        bc1 = 1 - cfg.b1 ** t
        bc2 = 1 - cfg.b2 ** t
        # all moment math in fp32 (grads may be bf16)
        m = jax.tree.map(
            lambda mm, g: cfg.b1 * mm + (1 - cfg.b1) * g.astype(jnp.float32),
            moments["m"], grads)
        v = jax.tree.map(
            lambda vv, g: cfg.b2 * vv
            + (1 - cfg.b2) * jnp.square(g.astype(jnp.float32)),
            moments["v"], grads)

        def upd(p, mm, vv):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + cfg.eps)
            if cls.decoupled_weight_decay and cfg.weight_decay:
                u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32)
                    - lr * u).astype(p.dtype)

        return jax.tree.map(upd, params, m, v), {"m": m, "v": v}


@register_optimizer("adamw")
class _AdamW(_Adam):
    decoupled_weight_decay = True


@register_optimizer("adagrad")
class _Adagrad(OptimizerRule):
    state_keys = ("acc",)

    @classmethod
    def update(cls, cfg, lr, step, params, grads, moments):
        acc = jax.tree.map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)),
            moments["acc"], grads)
        new_params = jax.tree.map(
            lambda p, a, g: (p.astype(jnp.float32) - lr
                             * g.astype(jnp.float32)
                             / (jnp.sqrt(a) + cfg.eps)).astype(p.dtype),
            params, acc, grads)
        return new_params, {"acc": acc}


@register_optimizer("sgd")
class _SGD(OptimizerRule):
    state_keys = ("mom",)

    @classmethod
    def update(cls, cfg, lr, step, params, grads, moments):
        mom = jax.tree.map(lambda mm, g: cfg.momentum * mm + g,
                           moments["mom"], grads)
        new_params = jax.tree.map(
            lambda p, mm: p - lr.astype(p.dtype) * mm.astype(p.dtype),
            params, mom)
        return new_params, {"mom": mom}


def init(cfg: OptimizerConfig, params: Any) -> Dict:
    # Moment buffers are always fp32, independent of param dtype (bf16
    # params + fp32 moments is the standard mixed-precision recipe).
    rule = _rule(cfg.kind)
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state: Dict[str, Any] = {"step": jnp.zeros((), jnp.int32)}
    for k in rule.state_keys:
        state[k] = zeros()
    return state


def _global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.float32(0.0)))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(cfg: OptimizerConfig, params, grads,
                  state: Dict) -> Tuple[Any, Dict]:
    rule = _rule(cfg.kind)
    step = state["step"]
    lr = schedule_lr(cfg, step)
    if cfg.grad_clip is not None:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    moments = {k: state[k] for k in rule.state_keys}
    new_params, new_moments = rule.update(cfg, lr, step, params, grads,
                                          moments)
    return new_params, {"step": step + 1, **new_moments}


# convenience container ------------------------------------------------

@jax.tree_util.register_pytree_node_class
class TrainState:
    """(params, opt_state, step) bundle that jits/shards as one pytree."""

    def __init__(self, params, opt_state):
        self.params = params
        self.opt_state = opt_state

    @property
    def step(self):
        return self.opt_state["step"]

    def tree_flatten(self):
        return (self.params, self.opt_state), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @staticmethod
    def create(cfg: OptimizerConfig, params) -> "TrainState":
        return TrainState(params, init(cfg, params))


def make_step_fn(cfg: OptimizerConfig,
                 loss_fn: Callable) -> Callable:
    """Standard step: state, batch -> (state, metrics).  loss_fn must
    return (loss, metrics_dict)."""

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        new_params, new_opt = apply_updates(cfg, state.params, grads,
                                            state.opt_state)
        return TrainState(new_params, new_opt), metrics

    return step
