"""Fault-tolerance utilities: straggler detection and failure-injection
hooks for testing checkpoint/restart behaviour in-process.

On a real 1000+-node fleet the per-step barrier makes one slow host
drag the whole job; the detector below is the policy engine (who is
slow, for how long) — the *action* (evict + elastic restart from the
last checkpoint) is wired in launch/train.py.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class StragglerReport:
    host: int
    ratio: float         # host EMA / fleet median EMA
    consecutive: int


class StragglerDetector:
    """Tracks per-host step-time EMAs; flags hosts persistently slower
    than ``threshold`` x the fleet median for ``patience`` steps."""

    def __init__(self, num_hosts: int, alpha: float = 0.2,
                 threshold: float = 1.8, patience: int = 5):
        self.num_hosts = num_hosts
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.ema = [None] * num_hosts  # type: List[Optional[float]]
        self.strikes = [0] * num_hosts

    def record(self, host: int, step_time: float) -> None:
        prev = self.ema[host]
        self.ema[host] = (step_time if prev is None
                          else self.alpha * step_time + (1 - self.alpha) * prev)

    def check(self) -> List[StragglerReport]:
        known = [e for e in self.ema if e is not None]
        if len(known) < max(2, self.num_hosts // 2):
            return []
        med = sorted(known)[len(known) // 2]
        reports = []
        for h, e in enumerate(self.ema):
            if e is None:
                continue
            ratio = e / max(med, 1e-9)
            if ratio > self.threshold:
                self.strikes[h] += 1
            else:
                self.strikes[h] = 0
            if self.strikes[h] >= self.patience:
                reports.append(StragglerReport(h, ratio, self.strikes[h]))
        return reports


class SimulatedFailure(RuntimeError):
    """Raised by FailureInjector to emulate a host crash mid-run."""


class FailureInjector:
    """Deterministically kills the run at given steps — the test fixture
    for checkpoint/auto-resume."""

    def __init__(self, fail_at_steps: List[int]):
        self.fail_at = set(fail_at_steps)
        self.fired: set = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


class StepTimer:
    """Wall-clock per-step timing with percentile summaries."""

    def __init__(self, window: int = 200):
        self.times: Deque[float] = collections.deque(maxlen=window)
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        self.times.append(dt)
        return dt

    def summary(self) -> Dict[str, float]:
        if not self.times:
            return {}
        s = sorted(self.times)
        n = len(s)
        return {"p50": s[n // 2], "p90": s[int(n * 0.9)], "max": s[-1],
                "mean": sum(s) / n}
