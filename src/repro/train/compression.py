"""Gradient compression for data-parallel all-reduce: int8 quantization
with error feedback (1-bit-Adam-style residual correction).

Used inside a shard_map'd train step: each device quantizes its local
gradient, the psum runs over int-ish payloads (cast to fp for the
collective — TPU psum is float), and the error-feedback state keeps
the quantization bias from accumulating.  Wire savings are modeled at
8/32 of the gradient bytes in the roofline's collective term.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(grads: Any, err: Any,
                         axis_name: str) -> Tuple[Any, Any]:
    """Per-leaf int8 quantize (+error feedback) -> psum -> dequantize.

    Returns (mean_grads, new_err).  err has the same pytree structure
    as grads (init with zeros_like).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize(q, scale)
        new_e = g32 - deq
        # collective payload: int8 values (cast for the float psum) and
        # one scalar scale per leaf per device
        summed = jax.lax.psum(deq, axis_name)
        return (summed / n).astype(g.dtype), new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return mean, new_err


def init_error_state(grads_template: Any) -> Any:
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)
