"""Checkpointing built for fault tolerance on many hosts.

Layout:  <dir>/step_<N>/
             shard_<host>.npz       one file per host (its local arrays)
             manifest.json          paths, shapes, dtypes, crc32 per array
             COMMITTED              written last — a step dir without it
                                    is a torn checkpoint and is ignored

Restore is template-based: the caller supplies a pytree of the right
structure (from init or jax.eval_shape) and leaves are filled by path.
That makes restore robust to refactors of pytree container types and
enables **elastic restore** — arrays are saved unsharded, so a restart
may use a different mesh/DP-width and simply re-shards on device_put.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save(ckpt_dir: str, step: int, tree, host_id: int = 0,
         keep: int = 3) -> str:
    """Atomic checkpoint write; prunes old steps beyond ``keep``."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in leaves}
    shard_path = os.path.join(tmp_dir, f"shard_{host_id}.npz")
    np.savez(shard_path, **{k.replace("/", "|"): v
                            for k, v in arrays.items()})
    manifest = {
        "step": step,
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes())}
                   for k, v in arrays.items()},
    }
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp_dir, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    _prune(ckpt_dir, keep)
    return step_dir


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "COMMITTED")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def _validate(step_dir: str, arrays: Dict[str, np.ndarray]) -> None:
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    for k, meta in manifest["arrays"].items():
        v = arrays[k]
        crc = zlib.crc32(np.ascontiguousarray(v).tobytes())
        if crc != meta["crc32"]:
            raise IOError(f"checkpoint corruption: crc mismatch for {k}")


def restore(ckpt_dir: str, step: int, template, host_id: int = 0,
            validate: bool = True):
    """Fill ``template``'s leaves from the checkpoint (by path)."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(step_dir, f"shard_{host_id}.npz")) as z:
        arrays = {k.replace("|", "/"): z[k] for k in z.files}
    if validate:
        _validate(step_dir, arrays)
    leaves_t = _flatten_with_paths(template)
    filled = []
    for key, leaf in leaves_t:
        if key not in arrays:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = arrays[key]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: ckpt shape {arr.shape} != {want}")
        filled.append(arr.astype(leaf.dtype))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, filled)


def restore_latest(ckpt_dir: str, template, host_id: int = 0):
    """(tree, step) from the newest *valid* committed checkpoint.

    Falls back to older checkpoints when the newest fails CRC/shape
    validation (a torn or bit-rotted write must not take the job down —
    that is the whole point of keeping ``keep`` > 1).
    Returns (None, -1) when nothing restorable exists.
    """
    for step in reversed(list_steps(ckpt_dir)):
        try:
            return restore(ckpt_dir, step, template, host_id), step
        except Exception:                      # corrupt/torn: try older
            continue
    return None, -1


def elastic_restore(ckpt_dir: str, step: int, template, sharding_tree=None,
                    host_id: int = 0):
    """Restore + re-shard onto a (possibly different) mesh: arrays are
    stored unsharded, so moving from e.g. 256-chip DP=16 to DP=8 is a
    device_put with the new shardings."""
    tree = restore(ckpt_dir, step, template, host_id)
    if sharding_tree is None:
        return tree
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        tree, sharding_tree)
