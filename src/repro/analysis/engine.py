"""Repo-invariant lint engine (DESIGN.md §15).

Every bit-parity / wire-byte guarantee this reproduction makes rests on
code invariants that reviewers have re-fixed by hand across PRs:
import-time backend init, kind-string dispatch bypassing the scheme
registry, uint8 code upcasts, hardcoded block sizes, shard_map-in-jit,
recompile-hazard flush paths, unlocked engine state, bare asserts.
This module turns those into machine checks: an AST-based rule engine
with a ``@register_rule`` registry (mirroring ``core/schemes/`` and
``retrieval/``), per-line suppression comments, and a JSON baseline so
the CI gate lands at zero NEW violations.

Deliberately stdlib-only: ``python -m repro.analysis`` must never
initialize the JAX backend it lints for (rule ``import-time-jax``
would be a lie otherwise), and it has to run in a bare CI step before
heavyweight deps are importable.

Vocabulary:

  * :class:`Diagnostic` — one finding: file, line, rule id, message,
    plus a drift-tolerant ``key`` (path + rule + stripped source line)
    used for baseline matching.
  * :class:`Rule` — one invariant; subclasses registered with
    :func:`register_rule` implement ``check(ctx)`` over a
    :class:`FileContext` (path + AST + source lines).
  * suppression — ``# repro-lint: disable=<rule-id>[,<rule-id>]`` on
    the flagged line (or on a comment-only line directly above it)
    silences the named rules for that line; ``disable=all`` silences
    every rule.  Suppressions are for *sanctioned* exceptions and must
    carry a reason in the surrounding comment; the baseline is for
    *inherited* debt only.
  * baseline — a JSON map ``key -> count``.  Diagnostics matching a
    baseline entry (up to its count) are reported as "baselined" and
    do not fail the gate; everything else is NEW and does.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Type

__all__ = ["Diagnostic", "FileContext", "Rule", "analyze_file",
           "analyze_paths", "analyze_source", "filter_baseline",
           "load_baseline", "register_rule", "registered_rule_ids",
           "rule_class", "write_baseline"]

PARSE_ERROR_RULE = "parse-error"

_SUPPRESS = re.compile(r"#\s*repro-lint:\s*disable=([\w\-, ]+)")


# ----------------------------------------------------------------------
# diagnostics
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, order=True)
class Diagnostic:
    """One finding.  ``key`` identifies it for baseline matching by
    (path, rule, stripped source line) — stable under unrelated edits
    that shift line numbers, unlike a raw ``path:line`` key."""

    path: str          # repo-relative, posix separators
    line: int          # 1-based
    col: int           # 0-based
    rule_id: str
    message: str
    line_text: str = ""

    @property
    def key(self) -> str:
        return f"{self.path}::{self.rule_id}::{self.line_text}"

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: " \
               f"[{self.rule_id}] {self.message}"

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["key"] = self.key
        return d


class FileContext:
    """Everything a rule sees for one file: repo-relative path, parsed
    AST, raw source lines, and the :meth:`diag` factory stamping
    diagnostics with the flagged line's text (baseline key)."""

    def __init__(self, path: str, text: str, tree: ast.AST):
        self.path = path.replace(os.sep, "/")
        self.text = text
        self.tree = tree
        self.lines = text.splitlines()

    def in_dir(self, *prefixes: str) -> bool:
        return any(self.path.startswith(p) for p in prefixes)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def diag(self, node: ast.AST, rule_id: str, message: str) -> Diagnostic:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Diagnostic(path=self.path, line=line, col=col,
                          rule_id=rule_id, message=message,
                          line_text=self.line_text(line))


# ----------------------------------------------------------------------
# rule registry (same shape as core/schemes/ and retrieval/)
# ----------------------------------------------------------------------

class Rule:
    """Protocol every lint rule implements.

    Class attributes double as the documentation the CI registry-sync
    gate checks against the DESIGN.md §15 rule table:

      * ``rule_id`` — stable kebab-case id (suppression comments and
        the baseline reference it);
      * ``title`` — one-line statement of the invariant;
      * ``motivation`` — the historical bug class / PR that makes the
        invariant load-bearing.
    """

    rule_id: str = ""
    title: str = ""
    motivation: str = ""

    def check(self, ctx: FileContext) -> Iterable[Diagnostic]:
        raise NotImplementedError


_RULES: Dict[str, Type[Rule]] = {}


def register_rule(cls: Type[Rule]) -> Type[Rule]:
    """Class decorator: add one rule to the registry (import-time
    registration, exactly like ``@register_scheme``)."""
    rid = cls.rule_id
    if not rid or not re.fullmatch(r"[a-z][a-z0-9\-]*", rid):
        raise ValueError(f"rule {cls.__name__} needs a kebab-case "
                         f"rule_id, got {rid!r}")
    if rid == PARSE_ERROR_RULE:
        raise ValueError(f"rule id {rid!r} is reserved")
    if rid in _RULES:
        raise ValueError(f"duplicate rule id {rid!r} "
                         f"({_RULES[rid].__name__} vs {cls.__name__})")
    if not cls.title or not cls.motivation:
        raise ValueError(f"rule {rid!r} must document title + motivation")
    _RULES[rid] = cls
    return cls


def _ensure_registered() -> None:
    if not _RULES:
        import repro.analysis.rules  # noqa: F401  — registers on import


def registered_rule_ids() -> List[str]:
    _ensure_registered()
    return sorted(_RULES)


def rule_class(rule_id: str) -> Type[Rule]:
    _ensure_registered()
    if rule_id not in _RULES:
        raise KeyError(f"lint rule {rule_id!r} not registered; known: "
                       f"{registered_rule_ids()}")
    return _RULES[rule_id]


# ----------------------------------------------------------------------
# suppressions
# ----------------------------------------------------------------------

def _suppressions(text: str) -> Dict[int, frozenset]:
    """lineno -> rule ids silenced on that line.  A directive on a
    comment-only line also covers the next line (so long flagged lines
    can carry the reason above them)."""
    out: Dict[int, set] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _SUPPRESS.search(line)
        if not m:
            continue
        ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
        out.setdefault(i, set()).update(ids)
        if line.lstrip().startswith("#"):
            out.setdefault(i + 1, set()).update(ids)
    return {k: frozenset(v) for k, v in out.items()}


def _suppressed(diag: Diagnostic, supp: Dict[int, frozenset]) -> bool:
    ids = supp.get(diag.line, frozenset())
    return diag.rule_id in ids or "all" in ids


# ----------------------------------------------------------------------
# analysis drivers
# ----------------------------------------------------------------------

def analyze_source(path: str, text: str,
                   rule_ids: Optional[Sequence[str]] = None
                   ) -> List[Diagnostic]:
    """Analyze one file's source under a (possibly virtual) repo
    relative path — rules are path-scoped, so fixtures pass paths like
    ``src/repro/launch/foo.py``.  Never raises on bad source: a syntax
    error becomes a single ``parse-error`` diagnostic."""
    _ensure_registered()
    try:
        tree = ast.parse(text)
    except (SyntaxError, ValueError) as e:
        line = getattr(e, "lineno", 1) or 1
        return [Diagnostic(path=path.replace(os.sep, "/"), line=line,
                           col=0, rule_id=PARSE_ERROR_RULE,
                           message=f"could not parse: {e.msg if hasattr(e, 'msg') else e}",
                           line_text="")]
    ctx = FileContext(path, text, tree)
    supp = _suppressions(text)
    out: List[Diagnostic] = []
    for rid in (rule_ids or registered_rule_ids()):
        rule = rule_class(rid)()
        for d in rule.check(ctx):
            if not _suppressed(d, supp):
                out.append(d)
    return sorted(out)


def analyze_file(path: str, root: str = ".",
                 rule_ids: Optional[Sequence[str]] = None
                 ) -> List[Diagnostic]:
    rel = os.path.relpath(os.path.abspath(path), os.path.abspath(root))
    with open(path, encoding="utf-8") as f:
        text = f.read()
    return analyze_source(rel, text, rule_ids)


def iter_python_files(paths: Sequence[str],
                      exclude: Sequence[str] = ()) -> List[str]:
    """Expand files/directories into a sorted, deduped .py file list,
    dropping any file whose path ends with an ``exclude`` entry (the
    shared ruff/repro-lint exclusion list, ``repro.analysis.scope``)."""
    found: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in ("__pycache__",))
                found.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames)
                             if f.endswith(".py"))
        elif p.endswith(".py"):
            found.append(p)
    norm = []
    for f in sorted(dict.fromkeys(found)):
        posix = f.replace(os.sep, "/")
        if any(posix.endswith(e.lstrip("./")) for e in exclude):
            continue
        norm.append(f)
    return norm


def analyze_paths(paths: Sequence[str], root: str = ".",
                  exclude: Sequence[str] = (),
                  rule_ids: Optional[Sequence[str]] = None
                  ) -> Tuple[List[Diagnostic], int]:
    """Lint every .py under ``paths`` -> (diagnostics, files scanned)."""
    files = iter_python_files(paths, exclude=exclude)
    out: List[Diagnostic] = []
    for f in files:
        out.extend(analyze_file(f, root=root, rule_ids=rule_ids))
    return sorted(out), len(files)


# ----------------------------------------------------------------------
# baseline
# ----------------------------------------------------------------------

def load_baseline(path: Optional[str]) -> Dict[str, int]:
    """Baseline JSON -> {diagnostic key: allowed count}.  Missing file
    (or None) means an empty baseline — the committed state of this
    repo, where every diagnostic is NEW."""
    if not path or not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as f:
        raw = json.load(f)
    entries = raw.get("entries", raw) if isinstance(raw, dict) else {}
    if not isinstance(entries, dict) or not all(
            isinstance(k, str) and isinstance(v, int)
            for k, v in entries.items()):
        raise ValueError(f"baseline {path!r} is not a "
                         f"{{key: count}} JSON object")
    return dict(entries)


def write_baseline(path: str, diags: Sequence[Diagnostic]) -> Dict[str, int]:
    """Persist the current findings as the accepted debt."""
    counts: Dict[str, int] = {}
    for d in diags:
        counts[d.key] = counts.get(d.key, 0) + 1
    payload = {"version": 1, "entries": dict(sorted(counts.items()))}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return counts


def filter_baseline(diags: Sequence[Diagnostic], baseline: Dict[str, int]
                    ) -> Tuple[List[Diagnostic], List[Diagnostic]]:
    """Split findings into (new, baselined): each baseline key absorbs
    up to its count of matching diagnostics."""
    budget = dict(baseline)
    new: List[Diagnostic] = []
    old: List[Diagnostic] = []
    for d in diags:
        if budget.get(d.key, 0) > 0:
            budget[d.key] -= 1
            old.append(d)
        else:
            new.append(d)
    return new, old
