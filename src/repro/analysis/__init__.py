"""Repo-invariant static analysis (DESIGN.md §15).

Importing this package registers the built-in rules; project-specific
rules register themselves with :func:`register_rule` on import —
exactly the ``core/schemes/`` / ``retrieval/`` plugin shape.  The
package is stdlib-only by design: linting must never initialize the
JAX backend it checks for.
"""
from repro.analysis.engine import (Diagnostic, FileContext, Rule,
                                   analyze_file, analyze_paths,
                                   analyze_source, filter_baseline,
                                   load_baseline, register_rule,
                                   registered_rule_ids, rule_class,
                                   write_baseline)
from repro.analysis.scope import lint_exclusions

# built-in rules — importing the module registers every class
from repro.analysis import rules as _rules          # noqa: F401

__all__ = ["Diagnostic", "FileContext", "Rule", "analyze_file",
           "analyze_paths", "analyze_source", "filter_baseline",
           "lint_exclusions", "load_baseline", "register_rule",
           "registered_rule_ids", "rule_class", "write_baseline"]
