"""The built-in lint rules: one class per historical bug class.

Each rule encodes an invariant a past PR broke and re-fixed by hand
(``motivation`` names the incident; the DESIGN.md §15 table mirrors
these docstrings and the CI registry-sync gate keeps the two in sync).
Rules are AST heuristics, not proofs: they are tuned to be quiet on
the current tree (empty committed baseline) and loud on the exact
pattern that caused the original bug.  Sanctioned exceptions carry a
``# repro-lint: disable=<id>`` comment with a reason; tracer-level
invariants the AST cannot see run in the dynamic sanitizer lane
instead (``pytest --sanitize``, DESIGN.md §15).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.engine import Diagnostic, FileContext, Rule, register_rule

__all__ = ["SCHEME_KIND_NAMES"]


# Scheme + retrieval-index kind strings whose ``.kind ==`` comparison
# outside the registries is dispatch-by-string (rule kind-dispatch).
# Kept as a literal so the linter never imports jax; the registry test
# in tests/test_analysis.py asserts this stays a superset of the live
# registries.  "dhe" is pre-listed for the ROADMAP plugin.
SCHEME_KIND_NAMES = frozenset({
    "full", "lrf", "sq", "hash", "dpq", "mgqe", "rq", "mpe", "dhe",
    "flat_pq", "ivf_pq",
})

_BLOCK_PARAMS = frozenset({"block_b", "block_d", "block_n"})


def _dotted(node: ast.AST) -> Optional[str]:
    """Attribute chain -> dotted name ('jax.numpy.pad'), else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_self_attr(node: ast.AST) -> Optional[str]:
    """'self.X' attribute -> 'X', else None (nested attrs excluded)."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


# ----------------------------------------------------------------------
# 1. import-time backend init
# ----------------------------------------------------------------------

# meta/config helpers that never touch the XLA client
_SAFE_TAILS = frozenset({"iinfo", "finfo", "dtype", "promote_types",
                         "result_type"})
# lazy transform wrappers: applying them does not trace or compile
_SAFE_JAX_TOP = frozenset({"jit", "vmap", "pmap", "grad",
                           "value_and_grad", "checkpoint", "custom_vjp",
                           "custom_jvp", "named_call", "named_scope"})
_SAFE_EXACT = frozenset({"jax.sharding.PartitionSpec"})
_SAFE_PREFIXES = ("jax.tree_util.", "jax.config.", "jax.typing.")


def _is_backend_init_call(name: str) -> bool:
    root = name.split(".", 1)[0]
    if root not in ("jax", "jnp"):
        return False
    if name.rsplit(".", 1)[-1] in _SAFE_TAILS:
        return False
    if name in _SAFE_EXACT or name.startswith(_SAFE_PREFIXES):
        return False
    if root == "jax" and name.count(".") == 1 \
            and name.split(".")[1] in _SAFE_JAX_TOP:
        return False
    return True


def _import_time_stmts(tree: ast.Module) -> Iterable[ast.AST]:
    """Statements/expressions evaluated when the module is imported:
    module body and class bodies, plus decorator lists and argument
    defaults of function defs (their *bodies* run later)."""
    stack: List[ast.AST] = list(tree.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            stack.extend(node.decorator_list)
            stack.extend(d for d in node.args.defaults if d is not None)
            stack.extend(d for d in node.args.kw_defaults if d is not None)
        elif isinstance(node, ast.ClassDef):
            stack.extend(node.decorator_list)
            stack.extend(node.bases)
            stack.extend(node.body)
        else:
            yield node


def _walk_skip_lazy(node: ast.AST) -> Iterable[ast.AST]:
    """ast.walk that does not descend into lambda/def bodies (deferred
    execution) but still visits lambda argument defaults (eager)."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            for d in list(child.args.defaults) + \
                    [d for d in child.args.kw_defaults if d is not None]:
                yield d
                yield from _walk_skip_lazy(d)
            continue
        yield child
        yield from _walk_skip_lazy(child)


@register_rule
class ImportTimeJaxRule(Rule):
    """Module-level ``jnp.*``/``jax.*`` calls initialize the XLA
    backend at import."""

    rule_id = "import-time-jax"
    title = ("no module-level jnp/jax calls — they initialize the XLA "
             "backend at import time")
    motivation = ("PR 1/PR 2: module-level jnp constants "
                  "(core/baselines.py, nn/attention FULL_WINDOW) "
                  "initialized the backend before launch/serve.py could "
                  "force host device counts, breaking --mesh runs")

    def check(self, ctx: FileContext) -> Iterable[Diagnostic]:
        for stmt in _import_time_stmts(ctx.tree):
            nodes = [stmt] if isinstance(stmt, ast.expr) else []
            nodes += list(_walk_skip_lazy(stmt))
            for node in nodes:
                if not isinstance(node, ast.Call):
                    continue
                name = _dotted(node.func)
                if name and _is_backend_init_call(name):
                    yield ctx.diag(
                        node, self.rule_id,
                        f"module-level call {name}(...) runs at import "
                        f"and initializes the JAX backend; build it "
                        f"lazily inside a function")


# ----------------------------------------------------------------------
# 2. kind-string dispatch outside the registries
# ----------------------------------------------------------------------

@register_rule
class KindDispatchRule(Rule):
    """``cfg.kind == "dpq"``-style branching outside the scheme /
    index registries."""

    rule_id = "kind-dispatch"
    title = ("no scheme/index kind-string comparisons outside "
             "core/schemes/ and retrieval/ — dispatch through the "
             "registry")
    motivation = ("PR 3: per-kind if-chains drifted out of sync with "
                  "the scheme registry; grep 'cfg.kind ==' reaching 0 "
                  "was that PR's acceptance gate")

    _EXEMPT = ("src/repro/core/schemes/", "src/repro/retrieval/")

    @staticmethod
    def _kind_consts(node: ast.AST) -> Set[str]:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return {node.value} & SCHEME_KIND_NAMES
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            out: Set[str] = set()
            for e in node.elts:
                out |= KindDispatchRule._kind_consts(e)
            return out
        return set()

    def check(self, ctx: FileContext) -> Iterable[Diagnostic]:
        if ctx.in_dir(*self._EXEMPT):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Compare):
                continue
            sides = [node.left] + list(node.comparators)
            has_kind = any(isinstance(s, ast.Attribute) and s.attr == "kind"
                           for s in sides)
            if not has_kind:
                continue
            ok_ops = all(isinstance(op, (ast.Eq, ast.NotEq, ast.In,
                                         ast.NotIn)) for op in node.ops)
            kinds = set()
            for s in sides:
                kinds |= self._kind_consts(s)
            if ok_ops and kinds:
                yield ctx.diag(
                    node, self.rule_id,
                    f"kind-string comparison against {sorted(kinds)} "
                    f"bypasses the scheme registry; use "
                    f"get_scheme/scheme_class capabilities instead")


# ----------------------------------------------------------------------
# 3. uint8 code upcasts outside the kernels
# ----------------------------------------------------------------------

_INT32_NAMES = frozenset({"jnp.int32", "np.int32", "numpy.int32",
                          "jax.numpy.int32"})


@register_rule
class CodeUpcastRule(Rule):
    """Code tensors must cross the dispatch boundary at their stored
    uint8 dtype; widening belongs inside the kernel bodies."""

    rule_id = "code-upcast"
    title = ("no .astype(int32) on code tensors outside "
             "src/repro/kernels/ — codes stay uint8 across the "
             "dispatch boundary")
    motivation = ("PR 4: eager int32 copies of the O(vocab) code table "
                  "at call sites cost a 4x transient buffer per request "
                  "until the batched pq ops accepted stored-dtype codes")

    def check(self, ctx: FileContext) -> Iterable[Diagnostic]:
        if ctx.in_dir("src/repro/kernels/"):
            return
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype" and node.args):
                continue
            arg = node.args[0]
            target = _dotted(arg)
            is_i32 = (target in _INT32_NAMES
                      or (isinstance(arg, ast.Constant)
                          and arg.value == "int32"))
            if not is_i32:
                continue
            recv = ast.unparse(node.func.value).lower()
            if "code" in recv:
                yield ctx.diag(
                    node, self.rule_id,
                    f"upcasting {ast.unparse(node.func.value)!r} to "
                    f"int32 copies the code table 4x wide; pass stored "
                    f"uint8 codes through — kernels widen per block")


# ----------------------------------------------------------------------
# 4. hardcoded block-size literals at dispatch call sites
# ----------------------------------------------------------------------

@register_rule
class BlockLiteralRule(Rule):
    """Block geometry is None-pin-or-Tunable: call sites pass ``None``
    (autotune resolves) or a config pin, never a literal."""

    rule_id = "block-literal"
    title = ("no hardcoded block_b/block_d/block_n literals at kernel "
             "call sites or in non-kernel signatures — pass None "
             "(autotune) or a config pin")
    motivation = ("PR 6/PR 7: hand-picked block sizes at call sites "
                  "bypassed the autotune cache (sharded_decode pinned "
                  "block_b measured 8x slower than tuned)")

    @staticmethod
    def _kernel_names(tree: ast.Module) -> Set[str]:
        names: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module \
                    and node.module.startswith("repro.kernels"):
                names.update(a.asname or a.name for a in node.names)
        return names

    def check(self, ctx: FileContext) -> Iterable[Diagnostic]:
        in_kernels = ctx.in_dir("src/repro/kernels/")
        kernel_names = self._kernel_names(ctx.tree)
        for node in ast.walk(ctx.tree):
            # (a) literal defaults in non-kernel signatures
            if (not in_kernels
                    and isinstance(node, (ast.FunctionDef,
                                          ast.AsyncFunctionDef,
                                          ast.Lambda))):
                a = node.args
                pos = a.posonlyargs + a.args
                pairs = list(zip(pos[len(pos) - len(a.defaults):],
                                 a.defaults))
                pairs += [(k, d) for k, d in zip(a.kwonlyargs,
                                                 a.kw_defaults) if d]
                for arg, default in pairs:
                    if (arg.arg in _BLOCK_PARAMS
                            and isinstance(default, ast.Constant)
                            and isinstance(default.value, int)
                            and not isinstance(default.value, bool)):
                        yield ctx.diag(
                            default, self.rule_id,
                            f"literal default {arg.arg}="
                            f"{default.value} pins the block size; "
                            f"default to None so the autotune cache "
                            f"resolves it (DESIGN.md §11)")
            # (b) literal kwargs at dispatch / kernel-op call sites
            if isinstance(node, ast.Call):
                callee = _dotted(node.func) or ""
                is_kernel_call = (
                    callee == "dispatch" or callee.endswith(".dispatch")
                    or callee.split(".", 1)[0] in kernel_names)
                if not is_kernel_call:
                    continue
                for kw in node.keywords:
                    if (kw.arg in _BLOCK_PARAMS
                            and isinstance(kw.value, ast.Constant)
                            and isinstance(kw.value.value, int)
                            and not isinstance(kw.value.value, bool)):
                        yield ctx.diag(
                            kw.value, self.rule_id,
                            f"literal {kw.arg}={kw.value.value} at a "
                            f"kernel call site bypasses the autotune "
                            f"cache; pass None or a config pin")


# ----------------------------------------------------------------------
# 5. shard_map consumed inside an enclosing jit
# ----------------------------------------------------------------------

@register_rule
class ShardMapInJitRule(Rule):
    """A shard_map whose output feeds further ops inside the same jit
    miscounts under GSPMD; run it as its own jit."""

    rule_id = "shard-map-in-jit"
    title = ("no shard_map call lexically inside a jitted function — "
             "the shard_map decode runs as its OWN jit and its "
             "materialized output is consumed outside")
    motivation = ("PR 5: a shard_map decode consumed by the hot-cache "
                  "merge inside one jit made GSPMD double the sharded "
                  "operand (P() x P('data') concat); the fix split "
                  "_serve and _mesh_merge into separate jits")

    @staticmethod
    def _is_jit(name: Optional[str]) -> bool:
        return name in ("jit", "jax.jit")

    def _jitted_bodies(self, tree: ast.Module) -> List[ast.AST]:
        defs: Dict[str, ast.AST] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs[node.name] = node
        bodies: List[ast.AST] = []
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    name = _dotted(dec)
                    partial = (isinstance(dec, ast.Call)
                               and _dotted(dec.func) in
                               ("partial", "functools.partial")
                               and dec.args
                               and self._is_jit(_dotted(dec.args[0])))
                    if self._is_jit(name) or partial:
                        bodies.append(node)
            elif isinstance(node, ast.Call) and self._is_jit(
                    _dotted(node.func)) and node.args:
                target = node.args[0]
                if isinstance(target, ast.Lambda):
                    bodies.append(target.body)
                elif isinstance(target, ast.Name) and target.id in defs:
                    bodies.append(defs[target.id])
        return bodies

    def check(self, ctx: FileContext) -> Iterable[Diagnostic]:
        seen: Set[int] = set()
        for body in self._jitted_bodies(ctx.tree):
            for node in ast.walk(body):
                if not isinstance(node, ast.Call):
                    continue
                name = _dotted(node.func) or ""
                if (name == "shard_map" or name.endswith(".shard_map")) \
                        and id(node) not in seen:
                    seen.add(id(node))
                    yield ctx.diag(
                        node, self.rule_id,
                        "shard_map inside a jitted function: its output "
                        "consumed in-jit miscounts under GSPMD — run "
                        "the shard_map as its own jit and merge its "
                        "materialized output outside")


# ----------------------------------------------------------------------
# 6. device-side padding in the flush paths
# ----------------------------------------------------------------------

@register_rule
class PadInFlushRule(Rule):
    """Engine flush paths assemble and pad host-side (``run_flat``);
    device-side jnp.pad retraces per distinct request length."""

    rule_id = "pad-in-flush"
    title = ("no jnp.pad in src/repro/launch/ — flush paths assemble "
             "host-side (np.pad) and route through run_flat")
    motivation = ("PR 6: jnp.pad + per-length slices on the flush path "
                  "recompiled per distinct batch size (~40ms/flush, "
                  "the XLA-CPU recompile-per-length death spiral); "
                  "run_flat pads in numpy before ONE upload")

    def check(self, ctx: FileContext) -> Iterable[Diagnostic]:
        if not ctx.in_dir("src/repro/launch/"):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and _dotted(node.func) in (
                    "jnp.pad", "jax.numpy.pad"):
                yield ctx.diag(
                    node, self.rule_id,
                    "jnp.pad on a request-sized array re-dispatches "
                    "(and on a fresh length, recompiles) per flush; "
                    "pad host-side with np.pad and route through "
                    "run_flat")


# ----------------------------------------------------------------------
# 7. engine lock discipline
# ----------------------------------------------------------------------

_LOCK_CTORS = frozenset({"threading.Lock", "threading.RLock",
                         "threading.Condition"})


@register_rule
class LockDisciplineRule(Rule):
    """Engine attributes shared between the submit / flush / refresh
    threads are only written with the lock (or a condition built on
    it) held."""

    rule_id = "lock-discipline"
    title = ("in launch/ classes owning a Lock/Condition, attributes "
             "ever assigned under the lock are never assigned outside "
             "it (off-__init__)")
    motivation = ("PR 6: the async engine's queue/inflight/stop state "
                  "is read by three threads; unlocked writes tear the "
                  "FlushPolicy accounting and deadlock drain()")

    @staticmethod
    def _lock_attrs(cls: ast.ClassDef) -> Set[str]:
        locks: Set[str] = set()
        for node in ast.walk(cls):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.value, ast.Call)):
                continue
            attr = _is_self_attr(node.targets[0])
            if attr and _dotted(node.value.func) in _LOCK_CTORS:
                locks.add(attr)
        return locks

    @staticmethod
    def _guarded_nodes(method: ast.AST, locks: Set[str]) -> Set[int]:
        """ids of nodes lexically inside a ``with self.<lock>:`` body."""
        out: Set[int] = set()
        for node in ast.walk(method):
            if not isinstance(node, ast.With):
                continue
            if any(_is_self_attr(item.context_expr) in locks
                   for item in node.items):
                for sub in ast.walk(node):
                    out.add(id(sub))
        return out

    @staticmethod
    def _assigned_attrs(node: ast.AST) -> List[str]:
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            for t in node.targets:
                targets.extend(t.elts if isinstance(
                    t, (ast.Tuple, ast.List)) else [t])
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        return [a for a in (_is_self_attr(t) for t in targets) if a]

    def check(self, ctx: FileContext) -> Iterable[Diagnostic]:
        if not ctx.in_dir("src/repro/launch/"):
            return
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            locks = self._lock_attrs(cls)
            if not locks:
                continue
            methods = [n for n in cls.body
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))
                       and n.name != "__init__"]
            guarded_attrs: Set[str] = set()
            guarded_ids: Dict[str, Set[int]] = {}
            for m in methods:
                g = self._guarded_nodes(m, locks)
                guarded_ids[m.name] = g
                for node in ast.walk(m):
                    if id(node) in g:
                        guarded_attrs.update(self._assigned_attrs(node))
            guarded_attrs -= locks
            if not guarded_attrs:
                continue
            for m in methods:
                g = guarded_ids[m.name]
                for node in ast.walk(m):
                    if id(node) in g:
                        continue
                    for attr in self._assigned_attrs(node):
                        if attr in guarded_attrs:
                            yield ctx.diag(
                                node, self.rule_id,
                                f"self.{attr} is assigned under "
                                f"{sorted(locks)} elsewhere in "
                                f"{cls.name} but written here without "
                                f"the lock held")


# ----------------------------------------------------------------------
# 8. bare asserts in library code
# ----------------------------------------------------------------------

@register_rule
class BareAssertRule(Rule):
    """Library invariants raise typed errors; ``assert`` vanishes
    under ``python -O`` and reports tuples instead of messages."""

    rule_id = "bare-assert"
    title = ("no bare assert in src/ library code — raise "
             "ValueError/TypeError with a real message")
    motivation = ("PR 2: partition.validate_partition shipped asserts "
                  "that disappeared under -O and produced opaque "
                  "tuple-reprs; converted to ValueError with coverage "
                  "tests, then kept regressing in new modules")

    def check(self, ctx: FileContext) -> Iterable[Diagnostic]:
        if not ctx.in_dir("src/"):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assert):
                yield ctx.diag(
                    node, self.rule_id,
                    "bare assert in library code is stripped under "
                    "python -O; raise a typed error with a message")
