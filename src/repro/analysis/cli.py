"""CLI driver: ``python -m repro.analysis`` / ``tools/repro_lint.py``.

    python -m repro.analysis src tools            # lint, gate on NEW
    python -m repro.analysis --list-rules         # rule table
    python -m repro.analysis src --json report.json
    python -m repro.analysis src --write-baseline # accept current debt

Exit code 1 when any non-baselined diagnostic remains (the CI
``analysis`` job gate); 0 otherwise.  The committed baseline
(tools/lint_baseline.json) is EMPTY — every rule's violations were
fixed or explicitly suppressed when the gate landed, so any hit is a
regression.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis.engine import (analyze_paths, filter_baseline,
                                   load_baseline, registered_rule_ids,
                                   rule_class, write_baseline)
from repro.analysis.scope import find_repo_root, lint_exclusions

__all__ = ["main"]

DEFAULT_BASELINE = "tools/lint_baseline.json"


def _list_rules() -> str:
    lines = []
    for rid in registered_rule_ids():
        cls = rule_class(rid)
        lines.append(f"{rid}\n    {cls.title}\n    why: {cls.motivation}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="AST lint for this repo's serving invariants "
                    "(DESIGN.md §15)")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files/directories to lint (default: src tools)")
    ap.add_argument("--root", default=None,
                    help="repo root for relative paths/config "
                         "(default: nearest pyproject.toml)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline JSON (default: <root>/"
                         f"{DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept every current diagnostic into the "
                         "baseline file and exit 0")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the full machine-readable report here")
    ap.add_argument("--rule", action="append", default=None,
                    help="run only this rule id (repeatable)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0

    root = args.root or find_repo_root(".")
    paths = args.paths or [f"{root}/src", f"{root}/tools"]
    if args.rule:
        unknown = [r for r in args.rule
                   if r not in registered_rule_ids()]
        if unknown:
            ap.error(f"unknown rule ids {unknown}; known: "
                     f"{registered_rule_ids()}")
    diags, n_files = analyze_paths(paths, root=root,
                                   exclude=lint_exclusions(root),
                                   rule_ids=args.rule)

    baseline_path = args.baseline or f"{root}/{DEFAULT_BASELINE}"
    if args.write_baseline:
        counts = write_baseline(baseline_path, diags)
        print(f"repro-lint: wrote {sum(counts.values())} accepted "
              f"diagnostic(s) to {baseline_path}")
        return 0

    new, baselined = filter_baseline(diags, load_baseline(baseline_path))
    for d in new:
        print(d.format())

    report = {
        "files_scanned": n_files,
        "rules": registered_rule_ids(),
        "new": [d.as_dict() for d in new],
        "baselined": [d.as_dict() for d in baselined],
        "counts": {"new": len(new), "baselined": len(baselined)},
    }
    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1, sort_keys=True)
            f.write("\n")

    summary = (f"repro-lint: {n_files} files, "
               f"{len(new)} new diagnostic(s), "
               f"{len(baselined)} baselined")
    print(summary, file=sys.stderr)
    return 1 if new else 0
