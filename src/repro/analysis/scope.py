"""Shared lint scope: ONE exclusion list for ruff and repro-lint.

The two linters disagreeing on which files are in scope is its own
bug class (a file ruff skips but repro-lint scans, or vice versa,
makes "CI is green" ambiguous).  The single source of truth is
``[tool.ruff] extend-exclude`` in pyproject.toml: ruff reads it
natively, and :func:`lint_exclusions` parses the same list for
repro-lint.

Parsed with a deliberately small regex rather than a TOML library —
the repo pins Python 3.10 (no stdlib tomllib) and the list is a flat
array of string literals under our own control.  An unreadable
pyproject degrades to the built-in default so the linter keeps
working from a partial checkout.
"""
from __future__ import annotations

import os
import re
from typing import Tuple

__all__ = ["DEFAULT_EXCLUSIONS", "find_repo_root", "lint_exclusions"]

# mirrors pyproject [tool.ruff] extend-exclude — compat shims and
# generated files that neither linter should hold to style rules
DEFAULT_EXCLUSIONS: Tuple[str, ...] = ("tests/_hypothesis_compat.py",)

_EXTEND_EXCLUDE = re.compile(
    r"^extend-exclude\s*=\s*\[(?P<body>[^\]]*)\]", re.MULTILINE)
_STRING = re.compile(r"""["']([^"']+)["']""")


def find_repo_root(start: str = ".") -> str:
    """Nearest ancestor of ``start`` containing pyproject.toml (falls
    back to ``start`` itself)."""
    cur = os.path.abspath(start)
    while True:
        if os.path.exists(os.path.join(cur, "pyproject.toml")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start)
        cur = parent


def lint_exclusions(root: str = ".") -> Tuple[str, ...]:
    """The shared exclusion list from ``[tool.ruff] extend-exclude``
    (posix-relative path suffixes), or :data:`DEFAULT_EXCLUSIONS` when
    pyproject.toml is missing/unparseable."""
    path = os.path.join(find_repo_root(root), "pyproject.toml")
    try:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    except OSError:
        return DEFAULT_EXCLUSIONS
    m = _EXTEND_EXCLUDE.search(text)
    if not m:
        return DEFAULT_EXCLUSIONS
    return tuple(_STRING.findall(m.group("body"))) or DEFAULT_EXCLUSIONS
