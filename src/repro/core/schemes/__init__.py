"""Scheme plugin registry (DESIGN.md §7).

Importing this package registers every built-in scheme; third-party
schemes register themselves with :func:`register_scheme` on import.
"""
from repro.core.schemes.base import (ArtifactLeaf, QuantizedScheme, Scheme,
                                     get_scheme, register_scheme,
                                     registered_kinds, scheme_class)

# built-in schemes — importing the module registers the class
from repro.core.schemes import baselines as _baselines   # noqa: F401
from repro.core.schemes import dpq as _dpq               # noqa: F401
from repro.core.schemes import mgqe as _mgqe             # noqa: F401
from repro.core.schemes import mpe as _mpe               # noqa: F401
from repro.core.schemes import rq as _rq                 # noqa: F401

__all__ = ["ArtifactLeaf", "QuantizedScheme", "Scheme", "get_scheme",
           "register_scheme", "registered_kinds", "scheme_class"]
