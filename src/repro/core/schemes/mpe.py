"""Mixed-Precision Embeddings (arXiv 2409.20305) as a registry plugin.

MGQE's capacity knob one level down the stack: instead of varying the
number of centroids or subspaces per frequency tier, ``mpe`` varies the
*bitwidth* of the stored codes — tier i uses ``K_i = 2**tier_bits[i]``
centroids per subspace and stores its codes bit-packed at
``tier_bits[i]`` bits per code (int8 head, int4/int2 tail).  Tiering
reuses ``core/partition.py``; packing reuses
``kernels/packed_decode/pack.py``; serving decodes through the fused
unpack-and-decode kernel (``kernels/packed_decode``), so the 2-4x
tail-tier HBM byte cut survives end to end (DESIGN.md §13).

Storage follows the ``mgqe`` ``private_d`` precedent: each tier keeps a
FULL (n, W_i) packed table so decode stays one fused kernel call per
tier blended by tier masks, while ``logical_bits`` account only the
rows in tier i at their packed width (paper §1.1-style accounting).
Because every leaf is a plain ``ArtifactLeaf`` with ``rows=True``
codes, sharded serving, the hot-row cache, both engines, and size
accounting all come from the generic machinery with no glue.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dpq
from repro.core.partition import tier_of_ids
from repro.core.schemes.base import (PIN_TO_CONFIG, ArtifactLeaf,
                                     QuantizedScheme, register_scheme)
from repro.kernels.packed_decode import (PACK_BITS, decode, pack_codes,
                                         packed_width)


@register_scheme("mpe")
class MixedPrecisionEmbedding(QuantizedScheme):
    """Per-frequency-tier code bitwidths with bit-packed storage:
    frequent items get int8 codes, the tail int4/int2."""

    @classmethod
    def validate(cls, cfg):
        if cfg.dim % cfg.num_subspaces != 0:
            raise ValueError(
                f"dim={cfg.dim} not divisible by D={cfg.num_subspaces}")
        m = len(cfg.tier_boundaries) + 1
        if len(cfg.tier_bits) != m:
            raise ValueError(
                f"tier_bits must have {m} entries, got "
                f"{len(cfg.tier_bits)}")
        for b in cfg.tier_bits:
            if b not in PACK_BITS:
                raise ValueError(
                    f"tier_bits entries must be one of {PACK_BITS}, "
                    f"got {b}")
        if any(cfg.tier_bits[i] < cfg.tier_bits[i + 1]
               for i in range(len(cfg.tier_bits) - 1)):
            raise ValueError("tier_bits must be non-increasing")
        if any(b <= 0 or b >= cfg.vocab_size for b in cfg.tier_boundaries):
            raise ValueError("tier boundaries must lie inside (0, vocab)")
        if any(cfg.tier_boundaries[i] >= cfg.tier_boundaries[i + 1]
               for i in range(len(cfg.tier_boundaries) - 1)):
            raise ValueError("tier boundaries must be strictly ascending")

    # ------------------------------------------------------------ train
    def init(self, key, dtype):
        cfg = self.cfg
        k_emb, k_cent = jax.random.split(key)
        keys = jax.random.split(k_cent, cfg.num_tiers)
        return {
            "emb": dpq.init_full_table(k_emb, cfg.vocab_size, cfg.dim,
                                       dtype=dtype),
            "centroids": [
                dpq.init_centroids(keys[i], cfg.num_subspaces, 2 ** b_i,
                                   cfg.subspace_dim, scale=cfg.dim ** -0.5,
                                   dtype=dtype)
                for i, b_i in enumerate(cfg.tier_bits)],
        }

    def apply(self, params, ids):
        """Training path: per-tier codebook quantization blended by tier
        masks (same static loop as the mgqe private variants)."""
        from repro.sharding.gather import row_gather
        cfg = self.cfg
        e = row_gather(params["emb"], ids, sharded=cfg.sharded_rows)
        tiers = tier_of_ids(ids, cfg.tier_boundaries)
        out = jnp.zeros_like(e)
        aux = jnp.asarray(0.0, dtype=jnp.float32)
        for i, cent in enumerate(params["centroids"]):
            q_i, _, aux_i = dpq.quantize(e, cent, beta=cfg.beta)
            mask = (tiers == i)
            out = jnp.where(mask[..., None], q_i, out)
            aux = aux + aux_i * jnp.mean(mask.astype(jnp.float32))
        return out, aux

    # ------------------------------------------------------------ serve
    def export(self, params):
        """Discard the full table; per tier, assign codes against the
        tier codebook over the whole vocab and bit-pack them."""
        cfg = self.cfg
        out = {"codes": [], "centroids": params["centroids"]}
        for b_i, cent in zip(cfg.tier_bits, params["centroids"]):
            codes = dpq.export_codes(
                {"emb": params["emb"], "centroids": cent})
            out["codes"].append(pack_codes(codes, b_i))
        return out

    def decode(self, artifact, ids, tier_ids=None,
               block_b=PIN_TO_CONFIG):
        """Fused unpack-and-decode per tier, blended by tier masks.

        The gathered rows stay PACKED across the kernel boundary — each
        tier's (B, W_i) words go straight into the dispatched
        ``packed_decode`` kernel, which unpacks per VMEM block (tier
        membership keys on the GLOBAL frequency-sorted id — see
        QuantizedScheme.decode)."""
        cfg = self.cfg
        bb = self.resolve_block_b(block_b)
        tiers = tier_of_ids(ids if tier_ids is None else tier_ids,
                            cfg.tier_boundaries)
        out = None
        for i, (b_i, cent) in enumerate(zip(cfg.tier_bits,
                                            artifact["centroids"])):
            packed = jnp.take(artifact["codes"][i], ids, axis=0)
            w_i = packed.shape[-1]
            flat = decode(packed.reshape(-1, w_i), cent, b_i,
                          block_b=bb, backend=cfg.kernel_backend)
            out_i = flat.reshape(ids.shape + (cfg.dim,))
            out = out_i if out is None \
                else jnp.where((tiers == i)[..., None], out_i, out)
        return out

    # -------------------------------------------------------- structure
    def cold_artifact_spec(self):
        cfg = self.cfg
        n, D = cfg.vocab_size, cfg.num_subspaces
        sizes = cfg.tier_sizes()
        return {
            "codes": [
                ArtifactLeaf((n, packed_width(D, b_i)), jnp.uint8,
                             rows=True, logical_bits=sz * D * b_i)
                for sz, b_i in zip(sizes, cfg.tier_bits)],
            "centroids": [
                ArtifactLeaf((D, 2 ** b_i, cfg.subspace_dim),
                             cfg.param_dtype)
                for b_i in cfg.tier_bits],
        }

    def training_param_count(self):
        cfg = self.cfg
        return (cfg.vocab_size * cfg.dim
                + cfg.dim * sum(2 ** b for b in cfg.tier_bits))

    @classmethod
    def probe_config(cls, variant="-"):
        from repro.core.types import EmbeddingConfig
        return EmbeddingConfig(vocab_size=32, dim=8, kind="mpe",
                               num_subspaces=4, tier_boundaries=(8, 16),
                               tier_bits=(8, 4, 2))
