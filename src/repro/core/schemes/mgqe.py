"""MGQE (paper §2) as a registry plugin over ``repro.core.mgqe``.

The three capacity-allocation variants share one scheme class; the
variant-specific artifact layouts (per-tier codebook lists, per-tier
code tables for ``private_d``) are encoded in :meth:`artifact_spec`,
from which struct/placement/size all derive.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import dpq, mgqe
from repro.core.partition import tier_of_ids
from repro.core.schemes.base import (PIN_TO_CONFIG, ArtifactLeaf,
                                     QuantizedScheme, log2ceil,
                                     register_scheme)
from repro.core.types import MGQE_VARIANTS


@register_scheme("mgqe")
class MultiGranularQuantizedEmbedding(QuantizedScheme):
    """Multi-granular DPQ: frequent items get more centroids
    (``shared_k``/``private_k``) or more subspaces (``private_d``)."""

    @classmethod
    def validate(cls, cfg):
        if cfg.dim % cfg.num_subspaces != 0:
            raise ValueError(
                f"dim={cfg.dim} not divisible by D={cfg.num_subspaces}")
        if cfg.mgqe_variant not in MGQE_VARIANTS:
            raise ValueError(f"unknown MGQE variant {cfg.mgqe_variant!r}")
        m = len(cfg.tier_boundaries) + 1
        if cfg.mgqe_variant in ("shared_k", "private_k"):
            if len(cfg.tier_num_centroids) != m:
                raise ValueError(
                    f"tier_num_centroids must have {m} entries, got "
                    f"{len(cfg.tier_num_centroids)}")
            ks = cfg.tier_num_centroids
            if any(ks[i] < ks[i + 1] for i in range(len(ks) - 1)):
                raise ValueError("tier_num_centroids must be non-increasing")
            if max(ks) > cfg.num_centroids:
                raise ValueError("tier K_i exceeds num_centroids")
        if cfg.mgqe_variant == "private_d":
            if len(cfg.tier_num_subspaces) != m:
                raise ValueError(
                    f"tier_num_subspaces must have {m} entries, got "
                    f"{len(cfg.tier_num_subspaces)}")
            for d_i in cfg.tier_num_subspaces:
                if cfg.dim % d_i != 0:
                    raise ValueError(
                        f"dim={cfg.dim} not divisible by tier D={d_i}")
        if any(b <= 0 or b >= cfg.vocab_size for b in cfg.tier_boundaries):
            raise ValueError("tier boundaries must lie inside (0, vocab)")
        if any(cfg.tier_boundaries[i] >= cfg.tier_boundaries[i + 1]
               for i in range(len(cfg.tier_boundaries) - 1)):
            raise ValueError("tier boundaries must be strictly ascending")

    @classmethod
    def variants(cls):
        return MGQE_VARIANTS

    @property
    def variant_label(self):
        return self.cfg.mgqe_variant

    # ------------------------------------------------------------ train
    def init(self, key, dtype):
        return mgqe.init(key, self.cfg, dtype=dtype)

    def apply(self, params, ids):
        return mgqe.lookup_train(params, ids, self.cfg)

    # ------------------------------------------------------------ serve
    def export(self, params):
        return mgqe.export_serving(params, self.cfg)

    def decode(self, artifact, ids, tier_ids=None,
               block_b=PIN_TO_CONFIG):
        """Decode through the dispatched fused kernel, blending
        private-variant tiers by mask (tier membership keys on the
        GLOBAL frequency-sorted id — see QuantizedScheme.decode)."""
        cfg = self.cfg
        bb = self.resolve_block_b(block_b)
        if cfg.mgqe_variant == "shared_k":
            return dpq.serving_lookup(artifact["codes"],
                                      artifact["centroids"], ids,
                                      backend=cfg.kernel_backend,
                                      block_b=bb)
        tiers = tier_of_ids(ids if tier_ids is None else tier_ids,
                            cfg.tier_boundaries)
        outs = []
        for i, cent in enumerate(artifact["centroids"]):
            codes_i = (artifact["codes"][i]
                       if isinstance(artifact["codes"], (list, tuple))
                       else artifact["codes"])
            outs.append(dpq.serving_lookup(codes_i, cent, ids,
                                           backend=cfg.kernel_backend,
                                           block_b=bb))
        out = outs[0]
        for i in range(1, len(outs)):
            out = jnp.where((tiers == i)[..., None], outs[i], out)
        return out

    # -------------------------------------------------------- structure
    def cold_artifact_spec(self):
        cfg = self.cfg
        n, d, D = cfg.vocab_size, cfg.dim, cfg.num_subspaces
        sizes = cfg.tier_sizes()
        cd = self.code_dtype
        if cfg.mgqe_variant in ("shared_k", "private_k"):
            # one (n, D) code table; packed width varies per tier
            code_bits = sum(sz * D * log2ceil(k)
                            for sz, k in zip(sizes, cfg.tier_num_centroids))
            codes = ArtifactLeaf((n, D), cd, rows=True,
                                 logical_bits=code_bits)
            if cfg.mgqe_variant == "shared_k":
                cents = ArtifactLeaf(
                    (D, cfg.num_centroids, cfg.subspace_dim),
                    cfg.param_dtype)
            else:
                cents = [ArtifactLeaf((D, k_i, cfg.subspace_dim),
                                      cfg.param_dtype)
                         for k_i in cfg.tier_num_centroids]
            return {"codes": codes, "centroids": cents}
        # private_d: per-tier (n, D_i) code tables, each row-sharded.
        # Paper accounting (§1.1) packs only the rows IN tier i for
        # table i; storage keeps full tables so decode stays one fused
        # kernel per tier — logical_bits record the paper's number.
        return {
            "codes": [
                ArtifactLeaf((n, d_i), cd, rows=True,
                             logical_bits=sz * d_i
                             * log2ceil(cfg.num_centroids))
                for sz, d_i in zip(sizes, cfg.tier_num_subspaces)],
            "centroids": [
                ArtifactLeaf((d_i, cfg.num_centroids, d // d_i),
                             cfg.param_dtype)
                for d_i in cfg.tier_num_subspaces],
        }

    def training_param_count(self):
        cfg = self.cfg
        n, d = cfg.vocab_size, cfg.dim
        if cfg.mgqe_variant == "shared_k":
            return n * d + cfg.num_centroids * d
        if cfg.mgqe_variant == "private_k":
            return n * d + d * sum(cfg.tier_num_centroids)
        return n * d + d * cfg.num_centroids * cfg.num_tiers

    @classmethod
    def probe_config(cls, variant="shared_k"):
        from repro.core.types import EmbeddingConfig
        kw = dict(vocab_size=32, dim=8, kind="mgqe", num_subspaces=4,
                  num_centroids=4, mgqe_variant=variant,
                  tier_boundaries=(8,))
        if variant in ("shared_k", "private_k", "-"):
            kw["mgqe_variant"] = "shared_k" if variant == "-" else variant
            kw["tier_num_centroids"] = (4, 2)
        else:
            kw["tier_num_subspaces"] = (4, 2)
        return EmbeddingConfig(**kw)
