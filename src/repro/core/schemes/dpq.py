"""DPQ (paper §1.1) as a registry plugin over ``repro.core.dpq``."""
from __future__ import annotations

from repro.core import dpq
from repro.core.schemes.base import (PIN_TO_CONFIG, ArtifactLeaf,
                                     QuantizedScheme, log2ceil,
                                     register_scheme)


@register_scheme("dpq")
class DifferentiableProductQuantization(QuantizedScheme):
    """Product quantization learned end-to-end with a straight-through
    estimator; serving artifact = codes (n, D) + centroids (D, K, S)."""

    @classmethod
    def validate(cls, cfg):
        if cfg.dim % cfg.num_subspaces != 0:
            raise ValueError(
                f"dim={cfg.dim} not divisible by D={cfg.num_subspaces}")

    def init(self, key, dtype):
        cfg = self.cfg
        return dpq.init(key, cfg.vocab_size, cfg.dim, cfg.num_subspaces,
                        cfg.num_centroids, dtype=dtype)

    def apply(self, params, ids):
        cfg = self.cfg
        return dpq.lookup_train(params, ids, beta=cfg.beta,
                                sharded_rows=cfg.sharded_rows)

    def export(self, params):
        codes = dpq.export_codes(params)
        return {"codes": codes.astype(self.code_dtype),
                "centroids": params["centroids"]}

    def decode(self, artifact, ids, tier_ids=None,
               block_b=PIN_TO_CONFIG):
        cfg = self.cfg
        return dpq.serving_lookup(artifact["codes"], artifact["centroids"],
                                  ids, backend=cfg.kernel_backend,
                                  block_b=self.resolve_block_b(block_b))

    def cold_artifact_spec(self):
        cfg = self.cfg
        return {
            "codes": ArtifactLeaf(
                (cfg.vocab_size, cfg.num_subspaces), self.code_dtype,
                rows=True,
                logical_bits=cfg.vocab_size * cfg.num_subspaces
                * log2ceil(cfg.num_centroids)),
            "centroids": ArtifactLeaf(
                (cfg.num_subspaces, cfg.num_centroids, cfg.subspace_dim),
                cfg.param_dtype),
        }

    def training_param_count(self):
        cfg = self.cfg
        return cfg.vocab_size * cfg.dim + cfg.num_centroids * cfg.dim

    @classmethod
    def probe_config(cls, variant="-"):
        from repro.core.types import EmbeddingConfig
        return EmbeddingConfig(vocab_size=32, dim=8, kind="dpq",
                               num_subspaces=4, num_centroids=4)
