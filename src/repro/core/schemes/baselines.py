"""Baseline schemes (paper §3.4) as registry plugins.

Thin classes over the functional implementations in
``repro.core.baselines`` — the math stays where it was; the plugin
layer owns dispatch, artifact specs, and size accounting.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import baselines
from repro.core.schemes.base import ArtifactLeaf, Scheme, register_scheme


@register_scheme("full")
class FullEmbedding(Scheme):
    """FE — the conventional (n, d) table; the 100% size baseline."""

    def init(self, key, dtype):
        return baselines.full_init(key, self.cfg, dtype)

    def apply(self, params, ids):
        return baselines.full_lookup(params, ids, self.cfg)

    def export(self, params):
        return params  # nothing to strip

    def serve(self, artifact, ids):
        return jnp.take(artifact["emb"], ids, axis=0)

    def cold_artifact_spec(self):
        cfg = self.cfg
        return {"emb": ArtifactLeaf((cfg.vocab_size, cfg.dim),
                                    cfg.param_dtype)}

    def training_param_count(self):
        return self.cfg.vocab_size * self.cfg.dim

    @classmethod
    def probe_config(cls, variant="-"):
        from repro.core.types import EmbeddingConfig
        return EmbeddingConfig(vocab_size=32, dim=8)


@register_scheme("lrf")
class LowRankFactorization(Scheme):
    """(n, r) @ (r, d) factorized table."""

    @classmethod
    def validate(cls, cfg):
        if cfg.rank <= 0:
            raise ValueError("lrf embedding needs rank > 0")

    def init(self, key, dtype):
        return baselines.lrf_init(key, self.cfg, dtype)

    def apply(self, params, ids):
        return baselines.lrf_lookup(params, ids, self.cfg)

    def export(self, params):
        return params

    def serve(self, artifact, ids):
        return baselines.lrf_lookup(artifact, ids, self.cfg)[0]

    def cold_artifact_spec(self):
        cfg = self.cfg
        return {"u": ArtifactLeaf((cfg.vocab_size, cfg.rank),
                                  cfg.param_dtype),
                "v": ArtifactLeaf((cfg.rank, cfg.dim), cfg.param_dtype)}

    def training_param_count(self):
        cfg = self.cfg
        return cfg.vocab_size * cfg.rank + cfg.rank * cfg.dim

    @classmethod
    def probe_config(cls, variant="-"):
        from repro.core.types import EmbeddingConfig
        return EmbeddingConfig(vocab_size=32, dim=8, kind="lrf", rank=2)


@register_scheme("sq")
class ScalarQuantization(Scheme):
    """Post-training per-dim uniform quantization; trains exactly like
    FE, quantizes at export."""

    @classmethod
    def validate(cls, cfg):
        if not 1 <= cfg.sq_bits <= 32:
            raise ValueError(f"sq_bits must be in [1, 32], got {cfg.sq_bits}")

    def init(self, key, dtype):
        return baselines.sq_init(key, self.cfg, dtype)

    def apply(self, params, ids):
        return baselines.sq_lookup(params, ids, self.cfg)

    def export(self, params):
        return baselines.sq_export(params, self.cfg)

    def serve(self, artifact, ids):
        return baselines.sq_serving_lookup(artifact, ids, self.cfg)

    @property
    def hot_dtype(self):
        # serve dequantizes against fp32 lo/scale (sq_export), so the
        # hot block is fp32 regardless of param_dtype
        return jnp.float32

    def cold_artifact_spec(self):
        cfg = self.cfg
        qd = jnp.uint8 if cfg.sq_bits <= 8 else jnp.int32
        # q is stored at uint8/int32 granularity but accounted at
        # sq_bits per element; lo/scale are fp32 by construction
        # (sq_export) regardless of param_dtype.
        return {
            "q": ArtifactLeaf((cfg.vocab_size, cfg.dim), qd,
                              logical_bits=cfg.vocab_size * cfg.dim
                              * cfg.sq_bits),
            "lo": ArtifactLeaf((cfg.dim,), jnp.float32),
            "scale": ArtifactLeaf((cfg.dim,), jnp.float32),
        }

    def training_param_count(self):
        return self.cfg.vocab_size * self.cfg.dim

    @classmethod
    def probe_config(cls, variant="-"):
        from repro.core.types import EmbeddingConfig
        return EmbeddingConfig(vocab_size=32, dim=8, kind="sq", sq_bits=8)


@register_scheme("hash")
class HashingTrick(Scheme):
    """Ids hashed into a smaller table (Weinberger et al. 2009)."""

    @classmethod
    def validate(cls, cfg):
        if cfg.hash_buckets <= 0:
            raise ValueError("hash embedding needs hash_buckets > 0")

    def init(self, key, dtype):
        return baselines.hash_init(key, self.cfg, dtype)

    def apply(self, params, ids):
        return baselines.hash_lookup(params, ids, self.cfg)

    def export(self, params):
        return params

    def serve(self, artifact, ids):
        return baselines.hash_lookup(artifact, ids, self.cfg)[0]

    def cold_artifact_spec(self):
        cfg = self.cfg
        return {"emb": ArtifactLeaf((cfg.hash_buckets, cfg.dim),
                                    cfg.param_dtype)}

    def training_param_count(self):
        return self.cfg.hash_buckets * self.cfg.dim

    @classmethod
    def probe_config(cls, variant="-"):
        from repro.core.types import EmbeddingConfig
        return EmbeddingConfig(vocab_size=32, dim=8, kind="hash",
                               hash_buckets=16)
