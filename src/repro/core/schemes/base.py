"""Scheme plugin protocol + registry (DESIGN.md §7).

A *scheme* is one embedding-compression technique — the paper's
DPQ/MGQE, the baselines they are compared against, or anything the
survey literature suggests next (residual quantization lives in
``rq.py``).  Each scheme is ONE class registered under its
``EmbeddingConfig.kind`` string:

    @register_scheme("rq")
    class ResidualQuantization(QuantizedScheme):
        ...

Every integration layer resolves schemes through this registry instead
of ``cfg.kind ==`` chains: ``Embedding`` (core/api.py), the
``ServingEngine``, the sharded quantized gather
(sharding/quantized.py), the placement rules (sharding/rules.py), the
README support matrix (tools/gen_tables.py) and the dry-run all pick
up a new scheme with zero edits — adding one is a one-file change.

The single source of truth for a scheme's serving artifact is
:meth:`Scheme.artifact_spec`: a pytree of :class:`ArtifactLeaf`
carrying shape, dtype, sharding placement, and the *logical* (packed)
bit count per leaf.  The three consumers that used to re-encode this
by hand are all DERIVED from it on the base class, so they can never
drift:

  * ``serving_artifact_struct()`` — ShapeDtypeStruct pytree (dry-run
    lowering, export validation);
  * ``artifact_shard_specs()``    — PartitionSpec pytree (device_put
    placement + shard_map in_specs, DESIGN.md §6);
  * ``serving_size_bits()``      — the paper's §1.1/§3.5 accounting,
    with float widths taken from the leaf dtype (``param_dtype``
    aware: bfloat16 tables count 16 bits, not a hardcoded 32).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple, Type

import jax
import jax.numpy as jnp


def log2ceil(k: int) -> int:
    """Bits to address k code slots (min 1)."""
    return max(1, math.ceil(math.log2(k)))


# ``QuantizedScheme.decode`` block_b sentinel: pin the decode kernel's
# batch block to ``cfg.decode_block_b``.  Right default for the
# single-device serve path (the engine pads every flush to exactly
# that size); the sharded gather passes ``block_b=None`` instead so the
# autotune cache (DESIGN.md §11) picks the block for the shard-local
# batch shape.
PIN_TO_CONFIG: Any = "pin-to-config"


@dataclasses.dataclass(frozen=True)
class ArtifactLeaf:
    """One leaf of a serving artifact, fully described.

    ``rows=True`` marks O(vocab) leaves that are row-sharded over the
    model mesh axis when the artifact is distributed; everything else
    is replicated.  ``logical_bits`` overrides the storage-derived bit
    count for the size accounting — code tables are *stored* at
    uint8/int32 granularity but *accounted* at their packed width
    (``log2ceil(K)`` bits per code, paper §1.1).
    """

    shape: Tuple[int, ...]
    dtype: Any
    rows: bool = False
    logical_bits: Optional[int] = None

    @property
    def storage_bits(self) -> int:
        return math.prod(self.shape) * jnp.dtype(self.dtype).itemsize * 8

    @property
    def size_bits(self) -> int:
        return self.storage_bits if self.logical_bits is None \
            else self.logical_bits


def _is_leaf(x) -> bool:
    return isinstance(x, ArtifactLeaf)


class Scheme:
    """Protocol every embedding scheme implements.

    Required overrides: ``init`` / ``apply`` / ``export`` / ``serve`` /
    ``cold_artifact_spec`` / ``training_param_count`` (plus
    ``validate`` / ``variants`` / ``probe_config`` classmethods where
    the defaults don't fit).  ``artifact_spec`` (cold spec + the
    optional hot-row cache leaf), ``serving_artifact_struct``,
    ``artifact_shard_specs``, ``serving_size_bits``, and
    ``precompute_hot_rows`` / ``attach_hot_rows`` are derived — do not
    override them (``precompute_hot_rows`` derives from ``serve``; only
    override it to pin a different decode path, as QuantizedScheme
    does).
    """

    kind: str = "?"                    # set by @register_scheme
    # True for codes+codebooks schemes whose code tables the sharded
    # quantized gather (sharding/quantized.py) can row-shard.
    supports_sharded_codes: bool = False

    def __init__(self, cfg):
        self.cfg = cfg

    # ------------------------------------------------------- class hooks
    @classmethod
    def validate(cls, cfg) -> None:
        """Kind-specific config validation (EmbeddingConfig.__post_init__
        calls this through the registry)."""

    @classmethod
    def variants(cls) -> Tuple[str, ...]:
        """Sub-variant labels for enumeration (support matrix, sharded
        parity sweeps).  "-" means the scheme has no variants."""
        return ("-",)

    @classmethod
    def probe_config(cls, variant: str = "-"):
        """A tiny EmbeddingConfig for capability probing / conformance
        (init -> apply -> export -> serve must run in milliseconds)."""
        raise NotImplementedError(cls)

    # --------------------------------------------------------- required
    def init(self, key: jax.Array, dtype) -> dict:
        raise NotImplementedError

    def apply(self, params: dict, ids: jax.Array):
        """Training path: (emb (..., d), aux_loss scalar)."""
        raise NotImplementedError

    def export(self, params: dict) -> dict:
        raise NotImplementedError

    def serve(self, artifact: dict, ids: jax.Array) -> jax.Array:
        raise NotImplementedError

    def cold_artifact_spec(self):
        """Pytree of :class:`ArtifactLeaf` matching the scheme's own
        ``export()`` leaf-for-leaf — the single source of truth for
        artifact shape, dtype, placement, and size accounting.  "Cold"
        because the optional hot-row cache leaf is composed on top by
        :meth:`artifact_spec`."""
        raise NotImplementedError

    def training_param_count(self) -> int:
        raise NotImplementedError

    # ------------------------------------------------- hot-row cache
    @property
    def hot_dtype(self):
        """dtype of ``serve()``'s output rows — the hot block stores
        serve output verbatim (bit-identical to the cold decode), so
        the leaf dtype must match it.  Defaults to ``param_dtype``;
        schemes that dequantize to a fixed width (sq) override."""
        return self.cfg.param_dtype

    def precompute_hot_rows(self, artifact: dict) -> jax.Array:
        """Decode-ahead block for the power-law head (DESIGN.md §9):
        the ``cfg.hot_rows`` hottest ids — ids ``< hot_rows``, valid
        because the framework convention is frequency-sorted ids —
        pre-decoded into a dense ``(hot_rows, dim)`` block.  Derived
        generically from ``serve``, so any registered scheme supports
        the cache with zero edits.  Jitted: the block must be
        bit-identical to the (always jitted) serving path, and eager
        XLA fuses float elementwise chains differently (no FMA)."""
        ids = jnp.arange(self.cfg.hot_rows, dtype=jnp.int32)
        return jax.jit(self.serve)(artifact, ids)

    def attach_hot_rows(self, artifact: dict) -> dict:
        """Return the artifact with the ``hot`` leaf attached when the
        config asks for one (``Embedding.export`` calls this; the spec
        machinery below accounts for the leaf automatically)."""
        if not self.cfg.hot_rows:
            return artifact
        return dict(artifact, hot=self.precompute_hot_rows(artifact))

    # ---------------------------------------------------------- derived
    def artifact_spec(self):
        """Full artifact spec: the scheme's cold spec plus, when
        ``cfg.hot_rows`` > 0, a dense replicated ``hot`` leaf —
        ``rows=False`` so the existing placement rules replicate the
        cache block on every device while the O(vocab) cold codes stay
        row-sharded, and the size accounting charges the cache's
        memory honestly."""
        spec = self.cold_artifact_spec()
        if self.cfg.hot_rows:
            spec = dict(spec, hot=ArtifactLeaf(
                (self.cfg.hot_rows, self.cfg.dim), self.hot_dtype))
        return spec

    @property
    def variant_label(self) -> str:
        """Active variant for reporting ("" when the scheme has none)."""
        return ""

    def artifact_leaves(self) -> List[ArtifactLeaf]:
        return jax.tree.leaves(self.artifact_spec(), is_leaf=_is_leaf)

    def serving_artifact_struct(self):
        """ShapeDtypeStruct pytree of the serving artifact — lets the
        dry-run lower the serving path without materializing a table."""
        return jax.tree.map(
            lambda leaf: jax.ShapeDtypeStruct(leaf.shape,
                                              jnp.dtype(leaf.dtype)),
            self.artifact_spec(), is_leaf=_is_leaf)

    def artifact_shard_specs(self, model_axis: str = "model"):
        """PartitionSpec pytree: ``rows`` leaves row-sharded over
        ``model_axis``, everything else replicated (DESIGN.md §6)."""
        from jax.sharding import PartitionSpec as P
        if not self.supports_sharded_codes:
            raise ValueError(
                f"no quantized artifact for kind={self.kind!r}")
        return jax.tree.map(
            lambda leaf: P(model_axis, *((None,) * (len(leaf.shape) - 1)))
            if leaf.rows else P(),
            self.artifact_spec(), is_leaf=_is_leaf)

    def serving_size_bits(self) -> int:
        """Paper §1.1/§3.5 serving-size accounting, summed over the
        artifact spec (packed code widths, dtype-true float widths)."""
        return sum(leaf.size_bits for leaf in self.artifact_leaves())


class QuantizedScheme(Scheme):
    """Base for codes+codebooks schemes (dpq, mgqe, rq).

    Serving decodes through the dispatched fused kernel; code tables
    may be row-sharded over the model axis, in which case ``serve``
    routes through the shard_map quantized gather (DESIGN.md §6) with
    a single-device fallback inside — call sites never branch.
    """

    supports_sharded_codes = True

    @property
    def code_dtype(self):
        return jnp.uint8 if self.cfg.num_centroids <= 256 else jnp.int32

    def serve(self, artifact: dict, ids: jax.Array) -> jax.Array:
        if self.cfg.sharded_codes:
            from repro.sharding.quantized import quantized_gather
            return quantized_gather(artifact, ids, self.cfg)
        return self.decode(artifact, ids)

    def precompute_hot_rows(self, artifact: dict) -> jax.Array:
        """Pin the export-time pre-decode to the single-device fused
        ``decode`` path: ``serve`` may route through the sharded gather
        when a mesh is ambient, but export happens before placement —
        the hot block must exist to BE placed (replicated, per
        ``artifact_spec``).  Jitted for bit-parity with the serving
        path (see the base hook)."""
        ids = jnp.arange(self.cfg.hot_rows, dtype=jnp.int32)
        return jax.jit(self.decode)(artifact, ids)

    def resolve_block_b(self, block_b) -> Optional[int]:
        """Map the ``decode`` block_b argument to a concrete value:
        :data:`PIN_TO_CONFIG` -> ``cfg.decode_block_b``; anything else
        (None = autotune cache, or an explicit int) passes through."""
        return self.cfg.decode_block_b if block_b is PIN_TO_CONFIG \
            else block_b

    def decode(self, artifact: dict, ids: jax.Array,
               tier_ids: Optional[jax.Array] = None,
               block_b=PIN_TO_CONFIG) -> jax.Array:
        """Single-device fused decode of ``ids`` against the artifact's
        code tables.  ``tier_ids`` defaults to ``ids``; the sharded
        gather passes GLOBAL ids there while ``ids`` are shard-local
        row offsets — any frequency-rank-dependent blending must key on
        the global id.  ``block_b`` is the decode kernel's batch block:
        the default pins ``cfg.decode_block_b`` (flush batches are
        padded to it), ``None`` defers to the autotune cache, an int
        pins explicitly — resolve via :meth:`resolve_block_b`.  ONE
        implementation shared by the single-device serve path and each
        shard's local decode, so they cannot drift."""
        raise NotImplementedError


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Scheme]] = {}


def register_scheme(kind: str):
    """Class decorator: register a Scheme under its kind string."""
    def deco(cls: Type[Scheme]) -> Type[Scheme]:
        prev = _REGISTRY.get(kind)
        if prev is not None and prev is not cls:
            raise ValueError(
                f"scheme kind {kind!r} already registered to {prev}")
        cls.kind = kind
        _REGISTRY[kind] = cls
        return cls
    return deco


def registered_kinds() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def scheme_class(kind: str) -> Type[Scheme]:
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise KeyError(
            f"unknown embedding kind {kind!r}; registered schemes: "
            f"{', '.join(registered_kinds()) or '(none)'}") from None


def get_scheme(cfg) -> Scheme:
    """Resolve a config to its scheme instance."""
    return scheme_class(cfg.kind)(cfg)
