"""Residual quantization (``rq``) — the registry's proof-of-abstraction.

This file is the ONLY place ``rq`` exists: registration here is enough
for ``Embedding``, the ServingEngine, the sharded quantized gather,
the placement rules, the README support matrix, and the dry-run to
pick the scheme up — the "one-file plugin" the registry promises
(DESIGN.md §7).  Pointers: RecJPQ (arXiv:2312.06165) and the
embedding-compression survey (arXiv:2408.02304) both flag
residual/joint quantization as the natural next family after PQ.

Training (straight-through, VQ-VAE-style like DPQ): M = ``num_levels``
sequential *full-width* codebooks ``C_m (K, d)``; stage m quantizes
the residual left by stages < m:

    r_0 = e
    c_m = argmin_k ||r_m - C_m[k]||^2
    r_{m+1} = r_m - sg(C_m[c_m])
    out = e + sg(sum_m C_m[c_m] - e)

Codebook gradients flow through the differentiable gather in the
per-stage codebook loss; commitment gradients reach ``e`` through the
residual chain — exactly the ``dpq.quantize`` recipe, applied
sequentially instead of per-subspace.

Serving artifact: codes ``(n, M)`` + codebooks ``(M, K, d)``.  Serving
decodes through the single-pass ``rq_decode_stages`` op (DESIGN.md
§11) on EVERY backend: on pallas/interpret the M-stage sum accumulates
in the kernel's revisited VMEM output block (one launch, no (B, M·d)
intermediate in HBM); the XLA reference is the per-stage row-gather
chain XLA fuses into one pass.  The old shape — one ``mgqe_decode``
launch with S = d emitting (B, M·d), summed outside — measured 0.27x
of the gather chain in BENCH_kernels.json ``rq_decode``; the bench now
gates the fused path at >= 1x.  Versus PQ at equal code bytes, RQ
spends ``M·K·d`` floats of codebook (vs ``K·d``) to quantize the
*joint* space instead of independent subspaces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dpq
from repro.core.schemes.base import (PIN_TO_CONFIG, ArtifactLeaf,
                                     QuantizedScheme, log2ceil,
                                     register_scheme)


def _stage_assign(r: jax.Array, codebook: jax.Array) -> jax.Array:
    """Nearest-codeword ids for residuals r (..., d) against (K, d)."""
    # dpq's MXU-friendly distance with a single full-width "subspace"
    return dpq.assign_codes(r[..., None, :], codebook[None])[..., 0]


@register_scheme("rq")
class ResidualQuantization(QuantizedScheme):
    """M sequential full-width codebooks over residuals."""

    @classmethod
    def validate(cls, cfg):
        if cfg.num_levels < 1:
            raise ValueError(
                f"rq needs num_levels >= 1, got {cfg.num_levels}")
        if cfg.num_centroids < 2:
            raise ValueError("rq needs num_centroids >= 2")

    # ------------------------------------------------------------ train
    def init(self, key, dtype):
        cfg = self.cfg
        k_emb, k_cb = jax.random.split(key)
        emb = dpq.init_full_table(k_emb, cfg.vocab_size, cfg.dim,
                                  dtype=dtype)
        # stage-0 codebook at embedding scale; later stages model
        # residuals, which shrink — geometric damping keeps early
        # argmins spread at every level
        scales = jnp.asarray([cfg.dim ** -0.5 * 0.5 ** m
                              for m in range(cfg.num_levels)], dtype=dtype)
        cbs = jax.random.normal(
            k_cb, (cfg.num_levels, cfg.num_centroids, cfg.dim),
            dtype=dtype) * scales[:, None, None]
        return {"emb": emb, "codebooks": cbs}

    def _quantize(self, e: jax.Array, codebooks: jax.Array):
        """Residual-quantize rows e (..., d); returns
        (quantized (..., d), codes (..., M), aux_loss scalar)."""
        beta = self.cfg.beta
        r, q_total = e, jnp.zeros_like(e)
        codes, aux = [], jnp.asarray(0.0, jnp.float32)
        for m in range(codebooks.shape[0]):
            cb = codebooks[m]
            code = _stage_assign(r, cb)
            c = jnp.take(cb, code, axis=0)            # differentiable
            codebook_loss = jnp.mean(jnp.sum(jnp.square(
                jax.lax.stop_gradient(r) - c), axis=-1))
            commit = jnp.mean(jnp.sum(jnp.square(
                r - jax.lax.stop_gradient(c)), axis=-1))
            aux = aux + codebook_loss + beta * commit
            q_total = q_total + c
            r = r - jax.lax.stop_gradient(c)
            codes.append(code)
        out = e + jax.lax.stop_gradient(q_total) - jax.lax.stop_gradient(e)
        return out, jnp.stack(codes, axis=-1), aux

    def apply(self, params, ids):
        from repro.sharding.gather import row_gather
        e = row_gather(params["emb"], ids, sharded=self.cfg.sharded_rows)
        out, _, aux = self._quantize(e, params["codebooks"])
        return out, aux

    # ------------------------------------------------------------ serve
    def export(self, params, batch: int = 65536):
        emb, cbs = params["emb"], params["codebooks"]

        @jax.jit
        def codes_of(rows):
            return self._quantize(rows, cbs)[1]

        outs = [codes_of(emb[s:s + batch])
                for s in range(0, emb.shape[0], batch)]
        return {"codes": jnp.concatenate(outs).astype(self.code_dtype),
                "codebooks": cbs}

    def decode(self, artifact, ids, tier_ids=None,
               block_b=PIN_TO_CONFIG):
        cfg = self.cfg
        from repro.kernels.mgqe_decode import decode_stages
        # codes keep their stored dtype (uint8) end-to-end; the kernel
        # widens per block, the XLA ref per gather.
        codes = jnp.take(artifact["codes"], ids, axis=0)
        m = codes.shape[-1]
        # block_b defaults to the decode_block_b pin (the engine pads
        # flush batches to it); block_d is left for the autotune cache.
        out = decode_stages(codes.reshape(-1, m), artifact["codebooks"],
                            block_b=self.resolve_block_b(block_b),
                            backend=cfg.kernel_backend)
        return out.reshape(ids.shape + (cfg.dim,))

    # -------------------------------------------------------- structure
    def cold_artifact_spec(self):
        cfg = self.cfg
        return {
            "codebooks": ArtifactLeaf(
                (cfg.num_levels, cfg.num_centroids, cfg.dim),
                cfg.param_dtype),
            "codes": ArtifactLeaf(
                (cfg.vocab_size, cfg.num_levels), self.code_dtype,
                rows=True,
                logical_bits=cfg.vocab_size * cfg.num_levels
                * log2ceil(cfg.num_centroids)),
        }

    def training_param_count(self):
        cfg = self.cfg
        return (cfg.vocab_size * cfg.dim
                + cfg.num_levels * cfg.num_centroids * cfg.dim)

    @classmethod
    def probe_config(cls, variant="-"):
        from repro.core.types import EmbeddingConfig
        return EmbeddingConfig(vocab_size=32, dim=8, kind="rq",
                               num_levels=2, num_centroids=4)
