"""Configuration types for the embedding subsystem.

Every embedding scheme in the framework (the paper's DPQ/MGQE, the
baselines it compares against, and registry plugins such as ``rq``) is
described by a single frozen :class:`EmbeddingConfig`.  The config is
hashable so it can be closed over by ``jax.jit`` without retracing
surprises.

Valid ``kind`` strings are whatever the scheme registry
(``repro.core.schemes``) currently holds — there is no frozen kind
tuple here, so a scheme plugin is usable the moment it registers.
The registry is imported lazily inside ``__post_init__`` (and the
size-accounting delegates) so this module stays importable without
the scheme package.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# Kernel backends for the serving decode path (mirrors
# repro.kernels.dispatch.BACKENDS; duplicated so config types stay
# importable without pulling in the kernel packages).
KERNEL_BACKENDS = ("auto", "pallas", "xla", "interpret")

# MGQE capacity-allocation variants (paper §2.2).
MGQE_VARIANTS = ("shared_k", "private_k", "private_d")


@dataclasses.dataclass(frozen=True)
class EmbeddingConfig:
    """Declarative description of one embedding table.

    Attributes mirror the paper's notation: ``num_subspaces`` is D,
    ``num_centroids`` is K, ``tier_num_centroids`` is K-tilde,
    ``tier_num_subspaces`` is D-tilde.  ``tier_boundaries`` are item-id
    thresholds under the convention that ids are frequency-sorted
    (id 0 = most popular); tier of id x = number of boundaries <= x.
    """

    vocab_size: int
    dim: int
    kind: str = "full"

    # --- DPQ / MGQE ---
    num_subspaces: int = 8          # D
    num_centroids: int = 256        # K
    beta: float = 0.25              # commitment-loss weight (VQ-VAE style)
    mgqe_variant: str = "shared_k"  # paper's default: shared centroids, variable K
    tier_boundaries: Tuple[int, ...] = ()       # len m-1, ascending ids
    tier_num_centroids: Tuple[int, ...] = ()    # len m, non-increasing
    tier_num_subspaces: Tuple[int, ...] = ()    # len m, non-increasing (private_d)

    # --- mixed-precision packed codes (mpe) ---
    # per-tier code bitwidth (len m, non-increasing, each in {8, 4, 2});
    # tier i stores K_i = 2**tier_bits[i] centroids per subspace and its
    # codes bit-packed at tier_bits[i] bits per code (DESIGN.md §13)
    tier_bits: Tuple[int, ...] = ()

    # --- residual quantization (rq) ---
    num_levels: int = 4             # M sequential full-width codebooks

    # --- low-rank factorization baseline ---
    rank: int = 16

    # --- scalar quantization baseline ---
    sq_bits: int = 8

    # --- hashing-trick baseline ---
    hash_buckets: int = 0

    # parameter dtype for the dense tables ("float32" | "bfloat16")
    param_dtype: str = "float32"

    # training-path row gathers via the shard_map model-parallel path
    # (repro.sharding.gather) instead of plain take — §Perf hillclimb
    sharded_rows: bool = False

    # serving-path code tables row-sharded over the "model" mesh axis
    # (repro.sharding.quantized; DESIGN.md §6).  When True,
    # ``Embedding.serve`` routes through the shard_map quantized gather
    # whenever a >1-device mesh with a "model" axis is ambient, and
    # falls back to the single-device fused decode otherwise — so the
    # flag is safe to leave on in single-device tests/tools.
    sharded_codes: bool = False

    # hot-row decode-ahead cache (DESIGN.md §9): pre-decode the hottest
    # ``hot_rows`` ids (ids < hot_rows under the frequency-sorted id
    # convention) into a dense (hot_rows, dim) float block at export
    # time — the artifact gains a replicated ``hot`` leaf and the
    # ServingEngine serves those ids with a plain gather instead of the
    # fused decode.  0 disables the cache.
    hot_rows: int = 0

    # kernel backend for the serving decode hot path (DESIGN.md §5):
    # "auto" defers to the REPRO_KERNEL_BACKEND env var when set, else
    # picks pallas on TPU and the XLA reference elsewhere; "interpret"
    # forces Pallas interpret mode (what CI uses).  A concrete value
    # here pins the backend regardless of the env var.
    kernel_backend: str = "auto"

    # rows per grid step for the fused decode kernel; batches are
    # padded to this granularity inside the kernel wrapper.
    decode_block_b: int = 256

    def __post_init__(self):
        from repro.core.schemes import registered_kinds, scheme_class
        try:
            scheme = scheme_class(self.kind)
        except KeyError:
            raise ValueError(
                f"unknown embedding kind {self.kind!r}; registered "
                f"schemes: {', '.join(registered_kinds())}") from None
        if self.kernel_backend not in KERNEL_BACKENDS:
            raise ValueError(
                f"unknown kernel backend {self.kernel_backend!r}; "
                f"expected one of {KERNEL_BACKENDS}")
        if not 0 <= self.hot_rows <= self.vocab_size:
            raise ValueError(
                f"hot_rows must lie in [0, vocab_size], got "
                f"{self.hot_rows} for vocab_size={self.vocab_size}")
        scheme.validate(self)

    # ------------------------------------------------------------------
    @property
    def num_tiers(self) -> int:
        return len(self.tier_boundaries) + 1

    @property
    def subspace_dim(self) -> int:
        return self.dim // self.num_subspaces

    def tier_sizes(self) -> Tuple[int, ...]:
        """Number of vocabulary rows in each tier."""
        edges = (0,) + tuple(self.tier_boundaries) + (self.vocab_size,)
        return tuple(edges[i + 1] - edges[i] for i in range(len(edges) - 1))

    # ------------------------------------------------------------------
    # Size accounting (paper §1.1/§3.5) — delegated to the scheme,
    # which derives it from its artifact spec (core/schemes/base.py).
    # ------------------------------------------------------------------
    def serving_size_bits(self) -> int:
        from repro.core.schemes import get_scheme
        return get_scheme(self).serving_size_bits()

    def training_param_count(self) -> int:
        """Dense parameters alive during training (full table included)."""
        from repro.core.schemes import get_scheme
        return get_scheme(self).training_param_count()
