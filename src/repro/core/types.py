"""Configuration types for the embedding subsystem.

Every embedding scheme in the framework (the paper's DPQ/MGQE and the
baselines it compares against) is described by a single frozen
:class:`EmbeddingConfig`.  The config is hashable so it can be closed
over by ``jax.jit`` without retracing surprises.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

# Supported embedding schemes.  "full" is the paper's FE baseline.
KINDS = ("full", "dpq", "mgqe", "lrf", "sq", "hash")

# Kernel backends for the serving decode path (mirrors
# repro.kernels.dispatch.BACKENDS; duplicated so config types stay
# importable without pulling in the kernel packages).
KERNEL_BACKENDS = ("auto", "pallas", "xla", "interpret")

# MGQE capacity-allocation variants (paper §2.2).
MGQE_VARIANTS = ("shared_k", "private_k", "private_d")


@dataclasses.dataclass(frozen=True)
class EmbeddingConfig:
    """Declarative description of one embedding table.

    Attributes mirror the paper's notation: ``num_subspaces`` is D,
    ``num_centroids`` is K, ``tier_num_centroids`` is K-tilde,
    ``tier_num_subspaces`` is D-tilde.  ``tier_boundaries`` are item-id
    thresholds under the convention that ids are frequency-sorted
    (id 0 = most popular); tier of id x = number of boundaries <= x.
    """

    vocab_size: int
    dim: int
    kind: str = "full"

    # --- DPQ / MGQE ---
    num_subspaces: int = 8          # D
    num_centroids: int = 256        # K
    beta: float = 0.25              # commitment-loss weight (VQ-VAE style)
    mgqe_variant: str = "shared_k"  # paper's default: shared centroids, variable K
    tier_boundaries: Tuple[int, ...] = ()       # len m-1, ascending ids
    tier_num_centroids: Tuple[int, ...] = ()    # len m, non-increasing
    tier_num_subspaces: Tuple[int, ...] = ()    # len m, non-increasing (private_d)

    # --- low-rank factorization baseline ---
    rank: int = 16

    # --- scalar quantization baseline ---
    sq_bits: int = 8

    # --- hashing-trick baseline ---
    hash_buckets: int = 0

    # parameter dtype for the dense tables ("float32" | "bfloat16")
    param_dtype: str = "float32"

    # training-path row gathers via the shard_map model-parallel path
    # (repro.sharding.gather) instead of plain take — §Perf hillclimb
    sharded_rows: bool = False

    # serving-path code tables row-sharded over the "model" mesh axis
    # (repro.sharding.quantized; DESIGN.md §6).  When True,
    # ``Embedding.serve`` routes through the shard_map quantized gather
    # whenever a >1-device mesh with a "model" axis is ambient, and
    # falls back to the single-device fused decode otherwise — so the
    # flag is safe to leave on in single-device tests/tools.
    sharded_codes: bool = False

    # kernel backend for the serving decode hot path (DESIGN.md §5):
    # "auto" defers to the REPRO_KERNEL_BACKEND env var when set, else
    # picks pallas on TPU and the XLA reference elsewhere; "interpret"
    # forces Pallas interpret mode (what CI uses).  A concrete value
    # here pins the backend regardless of the env var.
    kernel_backend: str = "auto"

    # rows per grid step for the fused decode kernel; batches are
    # padded to this granularity inside the kernel wrapper.
    decode_block_b: int = 256

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown embedding kind {self.kind!r}")
        if self.kernel_backend not in KERNEL_BACKENDS:
            raise ValueError(
                f"unknown kernel backend {self.kernel_backend!r}; "
                f"expected one of {KERNEL_BACKENDS}")
        if self.kind in ("dpq", "mgqe"):
            if self.dim % self.num_subspaces != 0:
                raise ValueError(
                    f"dim={self.dim} not divisible by D={self.num_subspaces}")
        if self.kind == "mgqe":
            if self.mgqe_variant not in MGQE_VARIANTS:
                raise ValueError(f"unknown MGQE variant {self.mgqe_variant!r}")
            m = len(self.tier_boundaries) + 1
            if self.mgqe_variant in ("shared_k", "private_k"):
                if len(self.tier_num_centroids) != m:
                    raise ValueError(
                        f"tier_num_centroids must have {m} entries, got "
                        f"{len(self.tier_num_centroids)}")
                ks = self.tier_num_centroids
                if any(ks[i] < ks[i + 1] for i in range(len(ks) - 1)):
                    raise ValueError("tier_num_centroids must be non-increasing")
                if max(ks) > self.num_centroids:
                    raise ValueError("tier K_i exceeds num_centroids")
            if self.mgqe_variant == "private_d":
                if len(self.tier_num_subspaces) != m:
                    raise ValueError(
                        f"tier_num_subspaces must have {m} entries, got "
                        f"{len(self.tier_num_subspaces)}")
                for d_i in self.tier_num_subspaces:
                    if self.dim % d_i != 0:
                        raise ValueError(
                            f"dim={self.dim} not divisible by tier D={d_i}")
            if any(b <= 0 or b >= self.vocab_size for b in self.tier_boundaries):
                raise ValueError("tier boundaries must lie inside (0, vocab)")
            if any(self.tier_boundaries[i] >= self.tier_boundaries[i + 1]
                   for i in range(len(self.tier_boundaries) - 1)):
                raise ValueError("tier boundaries must be strictly ascending")
        if self.kind == "hash" and self.hash_buckets <= 0:
            raise ValueError("hash embedding needs hash_buckets > 0")

    # ------------------------------------------------------------------
    @property
    def num_tiers(self) -> int:
        return len(self.tier_boundaries) + 1

    @property
    def subspace_dim(self) -> int:
        return self.dim // self.num_subspaces

    def tier_sizes(self) -> Tuple[int, ...]:
        """Number of vocabulary rows in each tier."""
        edges = (0,) + tuple(self.tier_boundaries) + (self.vocab_size,)
        return tuple(edges[i + 1] - edges[i] for i in range(len(edges) - 1))

    # ------------------------------------------------------------------
    # Serving-size accounting (bits), following paper §1.1 / §3.5.
    # ------------------------------------------------------------------
    def serving_size_bits(self) -> int:
        n, d = self.vocab_size, self.dim
        if self.kind == "full":
            return n * d * 32
        if self.kind == "lrf":
            return (n * self.rank + self.rank * d) * 32
        if self.kind == "sq":
            # per-dim min/max fp32 + b bits per element
            return n * d * self.sq_bits + 2 * d * 32
        if self.kind == "hash":
            return self.hash_buckets * d * 32
        if self.kind == "dpq":
            code_bits = n * self.num_subspaces * _log2ceil(self.num_centroids)
            centroid_bits = 32 * self.num_centroids * d   # K*D*(d/D)*32
            return code_bits + centroid_bits
        if self.kind == "mgqe":
            sizes = self.tier_sizes()
            if self.mgqe_variant == "shared_k":
                code_bits = sum(
                    sz * self.num_subspaces * _log2ceil(k)
                    for sz, k in zip(sizes, self.tier_num_centroids))
                centroid_bits = 32 * self.num_centroids * d
                return code_bits + centroid_bits
            if self.mgqe_variant == "private_k":
                code_bits = sum(
                    sz * self.num_subspaces * _log2ceil(k)
                    for sz, k in zip(sizes, self.tier_num_centroids))
                centroid_bits = 32 * d * sum(self.tier_num_centroids)
                return code_bits + centroid_bits
            # private_d: fixed K per tier, D_i subspaces of dim d/D_i
            code_bits = sum(
                sz * d_i * _log2ceil(self.num_centroids)
                for sz, d_i in zip(sizes, self.tier_num_subspaces))
            centroid_bits = 32 * d * self.num_centroids * self.num_tiers
            return code_bits + centroid_bits
        raise AssertionError(self.kind)

    def training_param_count(self) -> int:
        """Dense parameters alive during training (full table included)."""
        n, d = self.vocab_size, self.dim
        if self.kind in ("full", "sq"):
            return n * d
        if self.kind == "lrf":
            return n * self.rank + self.rank * d
        if self.kind == "hash":
            return self.hash_buckets * d
        if self.kind == "dpq":
            return n * d + self.num_centroids * d
        if self.kind == "mgqe":
            if self.mgqe_variant == "shared_k":
                return n * d + self.num_centroids * d
            if self.mgqe_variant == "private_k":
                return n * d + d * sum(self.tier_num_centroids)
            return n * d + d * self.num_centroids * self.num_tiers
        raise AssertionError(self.kind)


def _log2ceil(k: int) -> int:
    return max(1, math.ceil(math.log2(k)))
