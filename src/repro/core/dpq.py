"""Differentiable Product Quantization (DPQ) — VQ variant (paper §1.1).

Training keeps a full embedding table ``emb`` of shape (n, d).  Each row
is viewed as D subvectors of dim S = d/D.  Per subspace there are K
learnable centroids.  The forward pass snaps each subvector to its
nearest centroid (argmin over L2 distance), with a straight-through
estimator so gradients flow to the full table, and VQ-VAE-style
auxiliary losses so gradients flow to the centroids:

    out      = e + sg(c - e)                      (STE)
    aux_loss = mean ||sg(e) - c||^2  +  beta * mean ||e - sg(c)||^2

At serving time the full table is discarded; only the integer codes and
the centroid tables remain (see serving.py).

MGQE (mgqe.py) reuses every function here via the ``k_limit`` argument:
items restricted to the first K_i centroids simply mask distance slots
k >= K_i to +inf before the argmin.  This masked single pass is the
TPU-native replacement for the paper's dynamic group-split lookup
(DESIGN.md §3).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def init_centroids(key: jax.Array, num_subspaces: int, num_centroids: int,
                   subspace_dim: int, scale: float = 1.0,
                   dtype=jnp.float32) -> jax.Array:
    """Centroid tables, shape (D, K, S)."""
    return (jax.random.normal(key, (num_subspaces, num_centroids, subspace_dim),
                              dtype=dtype) * scale)


def init_full_table(key: jax.Array, vocab_size: int, dim: int,
                    scale: Optional[float] = None, dtype=jnp.float32) -> jax.Array:
    if scale is None:
        scale = dim ** -0.5
    return jax.random.normal(key, (vocab_size, dim), dtype=dtype) * scale


# ----------------------------------------------------------------------
# Quantization primitives (shape-polymorphic over leading batch dims).
# ----------------------------------------------------------------------

def subspace_distances(e_sub: jax.Array, centroids: jax.Array) -> jax.Array:
    """Squared-L2 distances from subvectors to centroids, MXU-friendly.

    e_sub:     (..., D, S)
    centroids: (D, K, S)
    returns    (..., D, K)

    ||e - c||^2 = ||e||^2 - 2 e.c + ||c||^2; the ||e||^2 term is
    constant w.r.t. the argmin so it is dropped — what remains is a
    batched matmul plus a bias, exactly what the MXU wants.
    """
    dots = jnp.einsum("...ds,dks->...dk", e_sub, centroids)
    c_sq = jnp.sum(jnp.square(centroids), axis=-1)  # (D, K)
    # explicit rank match (sanitizer lane runs rank_promotion='raise')
    c_sq = c_sq.reshape((1,) * (dots.ndim - 2) + c_sq.shape)
    return c_sq - 2.0 * dots


def assign_codes(e_sub: jax.Array, centroids: jax.Array,
                 k_limit: Optional[jax.Array] = None) -> jax.Array:
    """Nearest-centroid codes, shape (..., D), int32.

    k_limit: optional per-item centroid budget (broadcastable to the
    leading dims of e_sub).  Slots k >= k_limit are masked to +inf —
    the MGQE shared-variable-K rule ("use only the first K_i
    centroids").
    """
    dist = subspace_distances(e_sub, centroids)
    if k_limit is not None:
        k = dist.shape[-1]
        # explicit rank match (sanitizer lane runs rank_promotion=
        # 'raise'): slot (..1.., K) vs limits broadcast to (..., 1, 1)
        slot = jnp.arange(k, dtype=jnp.int32).reshape(
            (1,) * (dist.ndim - 1) + (k,))
        lim = jnp.broadcast_to(k_limit, dist.shape[:-2])[..., None, None]
        dist = jnp.where(slot >= lim, jnp.inf, dist)
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)


def decode_codes(codes: jax.Array, centroids: jax.Array) -> jax.Array:
    """codes (..., D) -> concatenated centroid vectors (..., D, S)."""
    # take_along_axis over the K axis of (D, K, S)
    d = centroids.shape[0]
    gathered = jnp.take_along_axis(
        centroids[None], codes[..., None, None].reshape((-1, d, 1, 1)),
        axis=2)                                   # (B*, D, 1, S)
    out = gathered[:, :, 0, :]
    return out.reshape(codes.shape + (centroids.shape[-1],))


def quantize(e: jax.Array, centroids: jax.Array,
             k_limit: Optional[jax.Array] = None,
             beta: float = 0.25) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full DPQ forward for pre-gathered rows.

    e: (..., d) full-table rows;  centroids: (D, K, S) with D*S == d.
    Returns (quantized (..., d), codes (..., D), aux_loss scalar).
    """
    num_sub, _, sub_dim = centroids.shape
    lead = e.shape[:-1]
    e_sub = e.reshape(lead + (num_sub, sub_dim))
    codes = assign_codes(e_sub, centroids, k_limit)
    c_sel = decode_codes(codes, centroids)        # (..., D, S)
    # Straight-through: forward value is the centroid, gradient hits e.
    q_sub = e_sub + jax.lax.stop_gradient(c_sel - e_sub)
    # Codebook + commitment losses (gradients: codebook term -> centroids
    # via the differentiable gather in c_sel; commitment -> e).
    codebook = jnp.mean(jnp.sum(
        jnp.square(jax.lax.stop_gradient(e_sub) - c_sel), axis=-1))
    commit = jnp.mean(jnp.sum(
        jnp.square(e_sub - jax.lax.stop_gradient(c_sel)), axis=-1))
    aux = codebook + beta * commit
    return q_sub.reshape(e.shape), codes, aux


# ----------------------------------------------------------------------
# Table-level API used by the model layers.
# ----------------------------------------------------------------------

def init(key: jax.Array, vocab_size: int, dim: int, num_subspaces: int,
         num_centroids: int, dtype=jnp.float32) -> dict:
    k_emb, k_cent = jax.random.split(key)
    emb = init_full_table(k_emb, vocab_size, dim, dtype=dtype)
    # Centroids init'd at the scale of the embeddings so early argmins
    # spread over the codebook rather than collapsing to one centroid.
    cent = init_centroids(k_cent, num_subspaces, num_centroids,
                          dim // num_subspaces, scale=dim ** -0.5, dtype=dtype)
    return {"emb": emb, "centroids": cent}


def lookup_train(params: dict, ids: jax.Array,
                 k_limit: Optional[jax.Array] = None,
                 beta: float = 0.25,
                 sharded_rows: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Training-path lookup: gather full rows, quantize, STE.

    ids: (...,) int; returns (emb (..., d), aux_loss scalar).
    """
    from repro.sharding.gather import row_gather
    e = row_gather(params["emb"], ids, sharded=sharded_rows)
    q, _, aux = quantize(e, params["centroids"], k_limit=k_limit, beta=beta)
    return q, aux


def export_codes(params: dict, k_limit_per_row: Optional[jax.Array] = None,
                 batch: int = 65536,
                 backend: Optional[str] = None) -> jax.Array:
    """Materialize serving codes for the whole vocab, shape (n, D) int32.

    Batched over rows so exporting a 10M-row table doesn't allocate a
    (n, D, K) distance tensor at once.  The nearest-centroid search
    runs through the dispatched ``dpq_assign`` kernel.
    """
    from repro.kernels.dpq_assign import assign
    emb = params["emb"]
    centroids = params["centroids"]
    n = emb.shape[0]
    num_sub, _, sub_dim = centroids.shape

    @jax.jit
    def one(rows, lim):
        # backend resolution happens at trace time (static per export)
        e_sub = rows.reshape(rows.shape[0], num_sub, sub_dim)
        return assign(e_sub, centroids, lim, backend=backend)

    outs = []
    for start in range(0, n, batch):
        rows = emb[start:start + batch]
        lim = None
        if k_limit_per_row is not None:
            lim = k_limit_per_row[start:start + batch]
        outs.append(one(rows, lim))
    return jnp.concatenate(outs, axis=0)


def serving_lookup(codes_table: jax.Array, centroids: jax.Array,
                   ids: jax.Array, backend: Optional[str] = None,
                   block_b: Optional[int] = None) -> jax.Array:
    """Serving-path lookup: codes + centroids only (full table gone).

    The decode runs through the kernel dispatch layer (DESIGN.md §5):
    the fused Pallas ``mgqe_decode`` kernel on TPU — one-hot matmul in
    VMEM instead of a per-row HBM gather — with the jnp reference as
    the XLA fallback.  ``backend``/``block_b`` usually come from
    ``EmbeddingConfig.kernel_backend`` / ``decode_block_b``; left as
    None, ``block_b`` resolves through the autotune cache.
    """
    from repro.kernels.mgqe_decode import decode
    # gather at the STORED dtype (uint8 for K<=256); the kernels widen
    # per block in VMEM — an int32 batch here quadruples gather traffic
    codes = jnp.take(codes_table, ids, axis=0)        # (..., D)
    d = codes.shape[-1]
    flat = decode(codes.reshape(-1, d), centroids,
                  block_b=block_b, backend=backend)
    return flat.reshape(ids.shape + (centroids.shape[0] * centroids.shape[-1],))
