"""Unified embedding API — the integration surface for every model.

``Embedding(cfg)`` exposes:

    init(key)                  -> params pytree (training)
    apply(params, ids)         -> (emb, aux_loss)          # training path
    export(params)             -> serving artifact pytree
    serve(artifact, ids)       -> emb                      # serving path
    serving_size_bits()        -> int

Models call ``apply`` during training (aux_loss must be added to the
task loss) and ``serve`` during inference.  Swapping FE -> MGQE is a
one-line config change, which is the paper's "drop-in replacement"
claim made concrete.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import baselines, dpq, mgqe
from repro.core.types import EmbeddingConfig


class Embedding:
    def __init__(self, cfg: EmbeddingConfig):
        self.cfg = cfg

    # ------------------------------------------------------------ train
    def init(self, key: jax.Array, dtype=jnp.float32) -> dict:
        cfg = self.cfg
        if cfg.kind == "full":
            return baselines.full_init(key, cfg, dtype)
        if cfg.kind == "lrf":
            return baselines.lrf_init(key, cfg, dtype)
        if cfg.kind == "sq":
            return baselines.sq_init(key, cfg, dtype)
        if cfg.kind == "hash":
            return baselines.hash_init(key, cfg, dtype)
        if cfg.kind == "dpq":
            return dpq.init(key, cfg.vocab_size, cfg.dim, cfg.num_subspaces,
                            cfg.num_centroids, dtype=dtype)
        if cfg.kind == "mgqe":
            return mgqe.init(key, cfg, dtype=dtype)
        raise AssertionError(cfg.kind)

    def apply(self, params: dict, ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        if cfg.kind == "full":
            return baselines.full_lookup(params, ids, cfg)
        if cfg.kind == "lrf":
            return baselines.lrf_lookup(params, ids, cfg)
        if cfg.kind == "sq":
            return baselines.sq_lookup(params, ids, cfg)
        if cfg.kind == "hash":
            return baselines.hash_lookup(params, ids, cfg)
        if cfg.kind == "dpq":
            return dpq.lookup_train(params, ids, beta=cfg.beta,
                                    sharded_rows=cfg.sharded_rows)
        if cfg.kind == "mgqe":
            return mgqe.lookup_train(params, ids, cfg)
        raise AssertionError(cfg.kind)

    # ------------------------------------------------------------ serve
    def export(self, params: dict) -> dict:
        cfg = self.cfg
        if cfg.kind in ("full", "lrf", "hash"):
            return params  # nothing to strip
        if cfg.kind == "sq":
            return baselines.sq_export(params, cfg)
        if cfg.kind == "dpq":
            codes = dpq.export_codes(params)
            dtype = jnp.uint8 if cfg.num_centroids <= 256 else jnp.int32
            return {"codes": codes.astype(dtype),
                    "centroids": params["centroids"]}
        if cfg.kind == "mgqe":
            return mgqe.export_serving(params, cfg)
        raise AssertionError(cfg.kind)

    def serve(self, artifact: dict, ids: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.kind == "full":
            return jnp.take(artifact["emb"], ids, axis=0)
        if cfg.kind == "lrf":
            return baselines.lrf_lookup(artifact, ids, cfg)[0]
        if cfg.kind == "hash":
            return baselines.hash_lookup(artifact, ids, cfg)[0]
        if cfg.kind == "sq":
            return baselines.sq_serving_lookup(artifact, ids, cfg)
        if cfg.kind in ("dpq", "mgqe") and cfg.sharded_codes:
            # distributed codes: shard_map gather over the ambient mesh
            # (single-device fallback inside) — DESIGN.md §6
            from repro.sharding.quantized import quantized_gather
            return quantized_gather(artifact, ids, cfg)
        if cfg.kind == "dpq":
            return dpq.serving_lookup(artifact["codes"], artifact["centroids"],
                                      ids, backend=cfg.kernel_backend,
                                      block_b=cfg.decode_block_b)
        if cfg.kind == "mgqe":
            return mgqe.serving_lookup(artifact, ids, cfg)
        raise AssertionError(cfg.kind)

    # -------------------------------------------------- abstract shapes
    def serving_artifact_struct(self) -> dict:
        """ShapeDtypeStruct pytree of the serving artifact — lets the
        dry-run lower the serving path without materializing/exporting
        a real table."""
        cfg = self.cfg
        S = jax.ShapeDtypeStruct
        d = jnp.dtype(cfg.param_dtype)
        if cfg.kind == "full":
            return {"emb": S((cfg.vocab_size, cfg.dim), d)}
        if cfg.kind == "lrf":
            return {"u": S((cfg.vocab_size, cfg.rank), d),
                    "v": S((cfg.rank, cfg.dim), d)}
        if cfg.kind == "hash":
            return {"emb": S((cfg.hash_buckets, cfg.dim), d)}
        if cfg.kind == "sq":
            qd = jnp.uint8 if cfg.sq_bits <= 8 else jnp.int32
            return {"q": S((cfg.vocab_size, cfg.dim), qd),
                    "lo": S((cfg.dim,), jnp.float32),
                    "scale": S((cfg.dim,), jnp.float32)}
        code_dtype = jnp.uint8 if cfg.num_centroids <= 256 else jnp.int32
        if cfg.kind == "dpq" or (cfg.kind == "mgqe"
                                 and cfg.mgqe_variant == "shared_k"):
            return {
                "codes": S((cfg.vocab_size, cfg.num_subspaces), code_dtype),
                "centroids": S((cfg.num_subspaces, cfg.num_centroids,
                                cfg.subspace_dim), d),
            }
        if cfg.kind == "mgqe" and cfg.mgqe_variant == "private_k":
            return {
                "codes": S((cfg.vocab_size, cfg.num_subspaces), code_dtype),
                "centroids": [
                    S((cfg.num_subspaces, k_i, cfg.subspace_dim), d)
                    for k_i in cfg.tier_num_centroids],
            }
        if cfg.kind == "mgqe" and cfg.mgqe_variant == "private_d":
            return {
                "codes": [
                    S((cfg.vocab_size, d_i), code_dtype)
                    for d_i in cfg.tier_num_subspaces],
                "centroids": [
                    S((d_i, cfg.num_centroids, cfg.dim // d_i), d)
                    for d_i in cfg.tier_num_subspaces],
            }
        raise AssertionError(cfg.kind)

    # ------------------------------------------------------------ sizes
    def serving_size_bits(self) -> int:
        return self.cfg.serving_size_bits()

    def training_param_count(self) -> int:
        return self.cfg.training_param_count()


def make_embedding(cfg: EmbeddingConfig) -> Embedding:
    return Embedding(cfg)
