"""Unified embedding API — the integration surface for every model.

``Embedding(cfg)`` exposes:

    init(key)                  -> params pytree (training)
    apply(params, ids)         -> (emb, aux_loss)          # training path
    export(params)             -> serving artifact pytree
    serve(artifact, ids)       -> emb                      # serving path
    serving_size_bits()        -> int

Models call ``apply`` during training (aux_loss must be added to the
task loss) and ``serve`` during inference.  Swapping FE -> MGQE is a
one-line config change, which is the paper's "drop-in replacement"
claim made concrete.

Every method dispatches through the scheme plugin registry
(``repro.core.schemes``, DESIGN.md §7): the config's ``kind`` resolves
to one Scheme class, so adding a compression scheme is a one-file
change and this facade never grows per-kind branches.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.schemes import Scheme, get_scheme
from repro.core.types import EmbeddingConfig


class Embedding:
    def __init__(self, cfg: EmbeddingConfig):
        self.cfg = cfg
        self.scheme: Scheme = get_scheme(cfg)

    # ------------------------------------------------------------ train
    def init(self, key: jax.Array, dtype=None) -> dict:
        """Training params.  ``dtype`` defaults to ``cfg.param_dtype``."""
        if dtype is None:
            dtype = jnp.dtype(self.cfg.param_dtype)
        return self.scheme.init(key, dtype)

    def apply(self, params: dict, ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        return self.scheme.apply(params, ids)

    # ------------------------------------------------------------ serve
    def export(self, params: dict) -> dict:
        """Serving artifact; when ``cfg.hot_rows`` > 0 the scheme's
        hot-row hook pre-decodes the power-law head into a dense
        ``hot`` block attached alongside the cold codes (DESIGN.md §9)."""
        return self.scheme.attach_hot_rows(self.scheme.export(params))

    def serve(self, artifact: dict, ids: jax.Array) -> jax.Array:
        return self.scheme.serve(artifact, ids)

    # -------------------------------------------------- abstract shapes
    def serving_artifact_struct(self) -> dict:
        """ShapeDtypeStruct pytree of the serving artifact — lets the
        dry-run lower the serving path without materializing/exporting
        a real table.  Derived from the scheme's artifact spec, so it
        cannot drift from what ``export`` produces."""
        return self.scheme.serving_artifact_struct()

    # ------------------------------------------------------------ sizes
    def serving_size_bits(self) -> int:
        return self.scheme.serving_size_bits()

    def training_param_count(self) -> int:
        return self.scheme.training_param_count()


def make_embedding(cfg: EmbeddingConfig) -> Embedding:
    return Embedding(cfg)
