"""Serving-side utilities: model-size accounting and artifact packing.

The paper evaluates "model size" as bits needed to store the embedding
at *serving* time, normalized to Full Embedding = 100% (§3.5).  This
module produces that table for any set of EmbeddingConfigs.
"""
from __future__ import annotations

from typing import Dict, Iterable, List

import numpy as np

from repro.core.schemes import get_scheme
from repro.core.types import EmbeddingConfig


def size_row(cfg: EmbeddingConfig, baseline_bits: int) -> Dict:
    bits = cfg.serving_size_bits()
    return {
        "kind": cfg.kind,
        "variant": get_scheme(cfg).variant_label,
        "bits": bits,
        "mbytes": bits / 8 / 1e6,
        "pct_of_full": 100.0 * bits / baseline_bits,
    }


def size_table(cfgs: Iterable[EmbeddingConfig]) -> List[Dict]:
    cfgs = list(cfgs)
    full_bits = None
    for c in cfgs:
        # not scheme dispatch — picking the uncompressed row as the
        # size-table baseline; behavior lives in core/schemes/
        if c.kind == "full":  # repro-lint: disable=kind-dispatch
            full_bits = c.serving_size_bits()
            break
    if full_bits is None:
        full_bits = EmbeddingConfig(
            vocab_size=cfgs[0].vocab_size, dim=cfgs[0].dim).serving_size_bits()
    return [size_row(c, full_bits) for c in cfgs]


def pack_codes_uint8(codes: np.ndarray) -> np.ndarray:
    """Pack int codes (n, D), K<=256, into a uint8 array for storage."""
    if codes.max(initial=0) > 255:
        raise ValueError("codes exceed uint8 range; store as int16/int32")
    return codes.astype(np.uint8)


def format_size_table(rows: List[Dict]) -> str:
    hdr = f"{'scheme':14s} {'bits':>14s} {'MB':>10s} {'% of FE':>8s}"
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        name = r["kind"] + (f"/{r['variant']}" if r["variant"] else "")
        lines.append(f"{name:14s} {r['bits']:>14d} {r['mbytes']:>10.3f} "
                     f"{r['pct_of_full']:>8.2f}")
    return "\n".join(lines)
