"""Multi-Granular Quantized Embeddings (paper §2).

Three variants, all built on dpq.py:

* ``shared_k``  (paper default): one codebook (D, K); items in tier i may
  only use the first K_i centroids.  Implemented as a *masked single
  pass* — per-item ``k_limit = K_tier(id)`` fed to ``dpq.assign_codes``
  — instead of the paper's dynamic group-split loop (Algorithm 1),
  which would force dynamic shapes on TPU.  See DESIGN.md §3
  ("masked single pass"); serving decodes through the fused kernel
  (DESIGN.md §5) and, when codes are distributed, the sharded gather
  (DESIGN.md §6).

* ``private_k``: tier i owns a private codebook with K_i centroids
  (allocated at K_max and masked).  Static python loop over tiers.

* ``private_d``: tier i owns a private codebook with D_i subspaces of
  dim d/D_i (K fixed).  Static python loop over tiers; outputs blended
  with tier masks.

Tier membership is pure arithmetic over frequency-sorted ids
(partition.tier_of_ids) — no membership table.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import dpq
from repro.core.partition import tier_of_ids
from repro.core.types import EmbeddingConfig


def _tier_k_limits(cfg: EmbeddingConfig, ids: jax.Array) -> jax.Array:
    """Per-item centroid budget K_{tier(id)} (int32, same shape as ids)."""
    tiers = tier_of_ids(ids, cfg.tier_boundaries)
    ks = jnp.asarray(cfg.tier_num_centroids, dtype=jnp.int32)
    return jnp.take(ks, tiers, axis=0)


def k_limit_for_all_rows(cfg: EmbeddingConfig) -> jax.Array:
    """(n,) per-row K budget — used at code-export time."""
    return _tier_k_limits(cfg, jnp.arange(cfg.vocab_size, dtype=jnp.int32))


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------

def init(key: jax.Array, cfg: EmbeddingConfig, dtype=jnp.float32) -> dict:
    if cfg.mgqe_variant == "shared_k":
        return dpq.init(key, cfg.vocab_size, cfg.dim, cfg.num_subspaces,
                        cfg.num_centroids, dtype=dtype)
    k_emb, k_cent = jax.random.split(key)
    params = {"emb": dpq.init_full_table(k_emb, cfg.vocab_size, cfg.dim,
                                         dtype=dtype)}
    keys = jax.random.split(k_cent, cfg.num_tiers)
    if cfg.mgqe_variant == "private_k":
        # allocate every tier codebook at its own K_i (static shapes per tier)
        params["centroids"] = [
            dpq.init_centroids(keys[i], cfg.num_subspaces,
                               cfg.tier_num_centroids[i],
                               cfg.subspace_dim, scale=cfg.dim ** -0.5,
                               dtype=dtype)
            for i in range(cfg.num_tiers)]
    else:  # private_d
        params["centroids"] = [
            dpq.init_centroids(keys[i], cfg.tier_num_subspaces[i],
                               cfg.num_centroids,
                               cfg.dim // cfg.tier_num_subspaces[i],
                               scale=cfg.dim ** -0.5, dtype=dtype)
            for i in range(cfg.num_tiers)]
    return params


# ----------------------------------------------------------------------
# training lookup
# ----------------------------------------------------------------------

def lookup_train(params: dict, ids: jax.Array,
                 cfg: EmbeddingConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (embeddings (..., d), aux_loss scalar)."""
    if cfg.mgqe_variant == "shared_k":
        k_limit = _tier_k_limits(cfg, ids)
        return dpq.lookup_train(params, ids, k_limit=k_limit, beta=cfg.beta,
                                sharded_rows=cfg.sharded_rows)

    # private variants: static loop over tiers, blend with masks.  The
    # full-table row gather takes the shard_map model-parallel path
    # when the table is row-sharded (same as shared_k via dpq).
    from repro.sharding.gather import row_gather
    e = row_gather(params["emb"], ids, sharded=cfg.sharded_rows)  # (..., d)
    tiers = tier_of_ids(ids, cfg.tier_boundaries)       # (...,)
    out = jnp.zeros_like(e)
    aux = jnp.asarray(0.0, dtype=jnp.float32)
    for i, cent in enumerate(params["centroids"]):
        q_i, _, aux_i = dpq.quantize(e, cent, beta=cfg.beta)
        mask = (tiers == i)
        out = jnp.where(mask[..., None], q_i, out)
        # weight tier aux by the fraction of items in the tier so the
        # total matches the masked-mean of per-item losses.
        frac = jnp.mean(mask.astype(jnp.float32))
        aux = aux + aux_i * frac
    return out, aux


# ----------------------------------------------------------------------
# serving export / lookup
# ----------------------------------------------------------------------

def export_serving(params: dict, cfg: EmbeddingConfig) -> dict:
    """Discard the full table; keep codes + centroids (paper Fig. 1)."""
    if cfg.mgqe_variant == "shared_k":
        codes = dpq.export_codes(params, k_limit_for_all_rows(cfg))
        dtype = jnp.uint8 if cfg.num_centroids <= 256 else jnp.int32
        return {"codes": codes.astype(dtype),
                "centroids": params["centroids"]}
    if cfg.mgqe_variant == "private_k":
        rows = jnp.arange(cfg.vocab_size, dtype=jnp.int32)
        tiers = tier_of_ids(rows, cfg.tier_boundaries)
        codes = jnp.zeros((cfg.vocab_size, cfg.num_subspaces), jnp.int32)
        for i, cent in enumerate(params["centroids"]):
            c_i = dpq.export_codes({"emb": params["emb"], "centroids": cent})
            codes = jnp.where((tiers == i)[:, None], c_i, codes)
        dtype = jnp.uint8 if cfg.num_centroids <= 256 else jnp.int32
        return {"codes": codes.astype(dtype),
                "centroids": params["centroids"]}
    # private_d: ragged D_i per tier — keep per-tier code arrays.
    out = {"codes": [], "centroids": params["centroids"]}
    for i, cent in enumerate(params["centroids"]):
        out["codes"].append(
            dpq.export_codes({"emb": params["emb"], "centroids": cent})
            .astype(jnp.uint8 if cfg.num_centroids <= 256 else jnp.int32))
    return out


# The serving decode (fused kernel + private-variant tier blending)
# lives on the scheme class — core/schemes/mgqe.py ``decode`` — shared
# by the single-device serve path and each shard's local decode
# (sharding/quantized.py), so the two cannot drift.
