"""Paper contribution: DPQ + MGQE embedding compression (Kang et al.,
WWW'20 Companion), plus the baselines it is evaluated against.

Public surface:
    EmbeddingConfig   — declarative table description
    Embedding         — init/apply/export/serve
    make_embedding    — factory
"""
from repro.core.api import Embedding, make_embedding
from repro.core.types import EmbeddingConfig

__all__ = ["Embedding", "EmbeddingConfig", "make_embedding"]
