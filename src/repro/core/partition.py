"""Frequency-based vocabulary partitioning (paper §2.1).

The framework convention: item ids are *frequency-sorted* — id 0 is the
most frequent item.  ``rank_by_frequency`` produces the remap for raw
datasets; ``frequency_boundaries`` converts fractional tier splits (the
paper's "top 10% = head") into id thresholds.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def rank_by_frequency(counts: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Return (remap, inverse) so that ``new_id = remap[old_id]`` is
    frequency-descending (ties broken by old id, deterministically).

    ``inverse[new_id] = old_id``.
    """
    counts = np.asarray(counts)
    # stable argsort on -counts keeps tie order deterministic
    inverse = np.argsort(-counts, kind="stable")
    remap = np.empty_like(inverse)
    remap[inverse] = np.arange(len(counts))
    return remap, inverse


def frequency_boundaries(vocab_size: int,
                         head_fractions: Sequence[float]) -> Tuple[int, ...]:
    """Convert cumulative head fractions to id thresholds.

    ``head_fractions=(0.1,)`` reproduces the paper's default two-tier
    split: V1 = top 10% of items, V2 = the rest.  Returned boundaries
    are strictly ascending and lie in [1, vocab-1].

    Degenerate requests raise: every fraction must lie strictly inside
    (0, 1) — a 0% or 100% head tier is an empty tier, not a rounding
    artifact — and the cumulative fractions must be strictly
    increasing.  The only silent adjustment kept is the rounding nudge:
    two valid fractions that round to the SAME id (tiny vocabularies)
    are separated by one id so every tier stays non-empty.
    """
    fracs = tuple(float(f) for f in head_fractions)
    for f in fracs:
        # `not (0 < f < 1)` also catches NaN (all comparisons False)
        if not 0.0 < f < 1.0:
            raise ValueError(
                f"head fraction {f} outside (0, 1): a 0%/100% tier is "
                f"empty, not a rounding artifact")
    for lo, hi in zip(fracs, fracs[1:]):
        if hi <= lo:
            raise ValueError(
                f"head_fractions must be strictly increasing "
                f"(cumulative), got {fracs}")
    bounds = []
    prev = 0
    for frac in fracs:
        b = int(round(vocab_size * frac))
        # legitimate rounding collision only: nudge into [prev+1, v-1]
        b = max(prev + 1, min(b, vocab_size - 1))
        bounds.append(b)
        prev = b
    # tiny vocab + many fractions can exhaust the id range even after
    # nudging; fail like any other impossible partition
    validate_partition(vocab_size, bounds)
    return tuple(bounds)


def validate_partition(vocab_size: int, boundaries: Sequence[int]) -> None:
    """Raise ValueError unless the partition disjointly covers [0, vocab)."""
    edges = (0,) + tuple(boundaries) + (vocab_size,)
    for lo, hi in zip(edges, edges[1:]):
        if hi <= lo:
            raise ValueError(f"empty/inverted tier [{lo}, {hi})")
    sizes = [hi - lo for lo, hi in zip(edges, edges[1:])]
    # Defensive coverage check (non-numeric/NaN boundaries slip past the
    # pairwise comparisons above).  A ValueError, not an assert — it
    # must survive ``python -O``.
    if sum(sizes) != vocab_size:
        raise ValueError(
            f"tiers cover {sum(sizes)} ids, expected {vocab_size}")


def tier_of_ids(ids, boundaries: Sequence[int]):
    """Vectorized tier index: number of boundaries <= id.

    Works on numpy or jax arrays (uses the array's own namespace);
    plain Python lists and scalars are coerced to numpy first —
    ``ids * 0`` on a list is ``[]``, not a zero array, so duck-typing
    them through the array path silently returns garbage.
    Pure arithmetic — no table lookup — because ids are frequency-sorted.
    """
    if not hasattr(ids, "dtype"):
        ids = np.asarray(ids)
    total = ids * 0
    for b in boundaries:
        total = total + (ids >= b).astype(total.dtype)
    return total
