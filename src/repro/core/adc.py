"""ADC retrieval over a PQ-coded corpus (beyond-paper serving path).

The paper stops at compressing the *embedding table*.  For the
retrieval-scoring cell (1 query x 1M candidates) the same PQ machinery
compresses the *candidate tower outputs*: fit per-subspace k-means over
the corpus vectors once offline, store only codes, and score queries by
LUT summation — ``score(i) = sum_d <q_d, c_codes[i,d]^(d)>`` — which is
exact for the dot product up to quantization error and never
reconstructs a candidate vector.  (Jegou et al.'s classic PQ-ADC,
applied to the paper's quantized-embedding serving story.)

The hot loop is the ``pq_score`` Pallas kernel; this module owns the
offline corpus-coding step (Lloyd's k-means per subspace, pure JAX).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.dpq_assign import assign as dpq_assign_op
from repro.kernels.pq_score import score_candidates


def fit_pq(key: jax.Array, vectors: jax.Array, num_subspaces: int,
           num_centroids: int, iters: int = 10) -> jax.Array:
    """Per-subspace k-means over corpus vectors.

    vectors (N, d) -> centroids (D, K, S), S = d / D.
    """
    n, d = vectors.shape
    assert d % num_subspaces == 0, (d, num_subspaces)
    s = d // num_subspaces
    x = vectors.reshape(n, num_subspaces, s).transpose(1, 0, 2)  # (D, N, S)

    # init: distinct random rows per subspace — sampling WITHOUT
    # replacement; duplicate seeds collapse into dead centroids that
    # Lloyd's update can never split, which measurably hurts recall.
    # (Tiny corpora with n < K must sample with replacement.)
    keys = jax.random.split(key, num_subspaces)
    idx = jnp.stack([jax.random.choice(kk, n, (num_centroids,),
                                       replace=n < num_centroids)
                     for kk in keys])
    cent = jnp.take_along_axis(x, idx[..., None], axis=1)        # (D, K, S)

    def step(cent, _):
        # assign: nearest centroid per subspace
        dots = jnp.einsum("dns,dks->dnk", x, cent)
        c_sq = jnp.sum(jnp.square(cent), axis=-1)                # (D, K)
        codes = jnp.argmin(c_sq[:, None, :] - 2 * dots, axis=-1)  # (D, N)
        onehot = jax.nn.one_hot(codes, cent.shape[1], dtype=x.dtype)
        counts = jnp.sum(onehot, axis=1)                         # (D, K)
        sums = jnp.einsum("dnk,dns->dks", onehot, x)
        new = jnp.where(counts[..., None] > 0,
                        sums / jnp.maximum(counts[..., None], 1.0), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    return cent


def encode_corpus(vectors: jax.Array, centroids: jax.Array,
                  backend: Optional[str] = None) -> jax.Array:
    """vectors (N, d) -> codes (N, D) int32 (dispatched dpq_assign)."""
    n, d = vectors.shape
    n_sub, _, s = centroids.shape
    e_sub = vectors.reshape(n, n_sub, s)
    return dpq_assign_op(e_sub, centroids, backend=backend)


def build_corpus_artifact(key: jax.Array, vectors: jax.Array,
                          num_subspaces: int = 8, num_centroids: int = 256,
                          iters: int = 10,
                          backend: Optional[str] = None) -> Dict:
    """Offline step: corpus vectors -> {codes, centroids} artifact."""
    cent = fit_pq(key, vectors, num_subspaces, num_centroids, iters)
    codes = encode_corpus(vectors, cent, backend=backend)
    dtype = jnp.uint8 if num_centroids <= 256 else jnp.int32
    return {"codes": codes.astype(dtype), "centroids": cent}


def adc_scores(artifact: Dict, query: jax.Array,
               backend: Optional[str] = None,
               block_n: int = 1024) -> jax.Array:
    """query (d,) -> scores (N,) over the coded corpus.

    Scoring runs through the dispatched ``pq_score`` kernel — the LUT
    stays in VMEM on TPU; the XLA reference is the CPU fallback.
    """
    return score_candidates(query, artifact["centroids"],
                            artifact["codes"].astype(jnp.int32),
                            block_n=block_n, backend=backend)


def reconstruction_mse(artifact: Dict, vectors: jax.Array) -> jax.Array:
    """Mean squared quantization error of the coded corpus."""
    from repro.kernels.mgqe_decode.ref import mgqe_decode_ref
    rec = mgqe_decode_ref(artifact["codes"].astype(jnp.int32),
                          artifact["centroids"])
    return jnp.mean(jnp.square(rec - vectors))
