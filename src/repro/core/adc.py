"""ADC retrieval over a PQ-coded corpus (beyond-paper serving path).

The paper stops at compressing the *embedding table*.  For the
retrieval-scoring cell (1 query x 1M candidates) the same PQ machinery
compresses the *candidate tower outputs*: fit per-subspace k-means over
the corpus vectors once offline, store only codes, and score queries by
LUT summation — ``score(i) = sum_d <q_d, c_codes[i,d]^(d)>`` — which is
exact for the dot product up to quantization error and never
reconstructs a candidate vector.  (Jegou et al.'s classic PQ-ADC,
applied to the paper's quantized-embedding serving story.)

The hot loop is the ``pq_score`` Pallas kernel; this module owns the
offline corpus-coding step (Lloyd's k-means per subspace, pure JAX).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dpq_assign.ref import dpq_assign_ref
from repro.kernels.pq_score import score_candidates


def fit_pq(key: jax.Array, vectors: jax.Array, num_subspaces: int,
           num_centroids: int, iters: int = 10) -> jax.Array:
    """Per-subspace k-means over corpus vectors.

    vectors (N, d) -> centroids (D, K, S), S = d / D.
    """
    n, d = vectors.shape
    assert d % num_subspaces == 0, (d, num_subspaces)
    s = d // num_subspaces
    x = vectors.reshape(n, num_subspaces, s).transpose(1, 0, 2)  # (D, N, S)

    # init: random rows per subspace
    idx = jax.random.randint(key, (num_subspaces, num_centroids), 0, n)
    cent = jnp.take_along_axis(x, idx[..., None], axis=1)        # (D, K, S)

    def step(cent, _):
        # assign: nearest centroid per subspace
        dots = jnp.einsum("dns,dks->dnk", x, cent)
        c_sq = jnp.sum(jnp.square(cent), axis=-1)                # (D, K)
        codes = jnp.argmin(c_sq[:, None, :] - 2 * dots, axis=-1)  # (D, N)
        onehot = jax.nn.one_hot(codes, cent.shape[1], dtype=x.dtype)
        counts = jnp.sum(onehot, axis=1)                         # (D, K)
        sums = jnp.einsum("dnk,dns->dks", onehot, x)
        new = jnp.where(counts[..., None] > 0,
                        sums / jnp.maximum(counts[..., None], 1.0), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    return cent


def encode_corpus(vectors: jax.Array, centroids: jax.Array) -> jax.Array:
    """vectors (N, d) -> codes (N, D) int32."""
    n, d = vectors.shape
    n_sub, _, s = centroids.shape
    e_sub = vectors.reshape(n, n_sub, s)
    return dpq_assign_ref(e_sub, centroids)


def build_corpus_artifact(key: jax.Array, vectors: jax.Array,
                          num_subspaces: int = 8, num_centroids: int = 256,
                          iters: int = 10) -> Dict:
    """Offline step: corpus vectors -> {codes, centroids} artifact."""
    cent = fit_pq(key, vectors, num_subspaces, num_centroids, iters)
    codes = encode_corpus(vectors, cent)
    dtype = jnp.uint8 if num_centroids <= 256 else jnp.int32
    return {"codes": codes.astype(dtype), "centroids": cent}


def adc_scores(artifact: Dict, query: jax.Array) -> jax.Array:
    """query (d,) -> scores (N,) over the coded corpus."""
    return score_candidates(query, artifact["centroids"],
                            artifact["codes"].astype(jnp.int32))


def reconstruction_mse(artifact: Dict, vectors: jax.Array) -> jax.Array:
    """Mean squared quantization error of the coded corpus."""
    from repro.kernels.mgqe_decode.ref import mgqe_decode_ref
    rec = mgqe_decode_ref(artifact["codes"].astype(jnp.int32),
                          artifact["centroids"])
    return jnp.mean(jnp.square(rec - vectors))
