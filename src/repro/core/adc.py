"""Compatibility shim — ADC moved to the retrieval subsystem.

The single-query ADC helpers that used to live here grew into the
``repro.retrieval`` package (DESIGN.md §8): an index registry with
``flat_pq`` (the exact scan this module implemented) and ``ivf_pq``,
batched fused top-k kernels, and sharded search.  This module
re-exports the original surface so existing imports keep working;
new code should use ``repro.retrieval`` directly.
"""
from repro.retrieval.flat_pq import (adc_scores, build_corpus_artifact,
                                     encode_corpus, fit_pq,
                                     reconstruction_mse)

__all__ = ["adc_scores", "build_corpus_artifact", "encode_corpus",
           "fit_pq", "reconstruction_mse"]
