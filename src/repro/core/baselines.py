"""Compression baselines the paper compares against (§3.4).

* Full Embedding (FE)       — the conventional (n, d) table.
* Low-rank Factorization    — (n, r) @ (r, d).
* Scalar Quantization (SQ)  — post-training per-dim uniform quantization.
* Hashing trick             — ids hashed into a smaller table (Weinberger
  et al. 2009; cited as [15] in the paper's intro).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import EmbeddingConfig


def _zero():
    """Aux-loss placeholder.  Built per call, NOT at module scope:
    a module-level jnp constant would initialize the jax backend at
    import time, breaking tools that must set XLA_FLAGS first
    (launch/dryrun.py, launch/serve.py --mesh)."""
    return jnp.float32(0.0)


# ---------------------------------------------------------------- full
def full_init(key, cfg: EmbeddingConfig, dtype=jnp.float32) -> dict:
    scale = cfg.dim ** -0.5
    return {"emb": jax.random.normal(key, (cfg.vocab_size, cfg.dim),
                                     dtype=dtype) * scale}


def full_lookup(params, ids, cfg) -> Tuple[jax.Array, jax.Array]:
    from repro.sharding.gather import row_gather
    return row_gather(params["emb"], ids,
                      sharded=cfg.sharded_rows), _zero()


# ----------------------------------------------------------------- lrf
def lrf_init(key, cfg: EmbeddingConfig, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "u": jax.random.normal(k1, (cfg.vocab_size, cfg.rank), dtype=dtype)
        * (cfg.rank ** -0.5),
        "v": jax.random.normal(k2, (cfg.rank, cfg.dim), dtype=dtype)
        * (cfg.dim ** -0.5),
    }


def lrf_lookup(params, ids, cfg) -> Tuple[jax.Array, jax.Array]:
    rows = jnp.take(params["u"], ids, axis=0)
    return rows @ params["v"], _zero()


# ------------------------------------------------------------------ sq
# SQ trains exactly like FE; quantization happens at export time.
sq_init = full_init
sq_lookup = full_lookup


def sq_export(params, cfg: EmbeddingConfig) -> dict:
    emb = params["emb"].astype(jnp.float32)
    lo = jnp.min(emb, axis=0)                      # (d,)
    hi = jnp.max(emb, axis=0)
    buckets = (1 << cfg.sq_bits) - 1
    scale = jnp.where(hi > lo, (hi - lo) / buckets, 1.0)
    # explicit rank match (sanitizer lane runs rank_promotion='raise')
    q = jnp.round((emb - lo[None, :]) / scale[None, :]).astype(
        jnp.uint8 if cfg.sq_bits <= 8 else jnp.int32)
    return {"q": q, "lo": lo, "scale": scale}


def sq_serving_lookup(artifact, ids, cfg) -> jax.Array:
    rows = jnp.take(artifact["q"], ids, axis=0).astype(jnp.float32)
    lead = (1,) * (rows.ndim - 1)
    return (rows * artifact["scale"].reshape(lead + (-1,))
            + artifact["lo"].reshape(lead + (-1,)))


# ---------------------------------------------------------------- hash
def hash_init(key, cfg: EmbeddingConfig, dtype=jnp.float32) -> dict:
    scale = cfg.dim ** -0.5
    return {"emb": jax.random.normal(key, (cfg.hash_buckets, cfg.dim),
                                     dtype=dtype) * scale}


def _hash_ids(ids, buckets: int):
    # Knuth multiplicative hash keeps head items from colliding with the
    # identity layout a plain modulo would give on frequency-sorted ids.
    h = (ids.astype(jnp.uint32) * jnp.uint32(2654435761))
    return (h % jnp.uint32(buckets)).astype(jnp.int32)


def hash_lookup(params, ids, cfg) -> Tuple[jax.Array, jax.Array]:
    return jnp.take(params["emb"], _hash_ids(ids, cfg.hash_buckets),
                    axis=0), _zero()
