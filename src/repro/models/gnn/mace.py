"""MACE [arXiv:2206.07697]: higher-order equivariant (E(3)-ACE) message
passing, adapted to TPU/JAX.

Per layer:
  1. edge tensor product  phi_e = sum_paths W_r(r_e) . CG . (X_sender (x) Y(r_e))
  2. A-basis              A_i   = segment_sum(phi_e -> receiver)      (scatter!)
  3. higher-order B-basis B2 = CG.(A (x) A), B3 = CG.(B2 (x) A)       (corr. order 3)
  4. message + update     X <- Linear_l(B1,B2,B3) + residual
  5. per-layer readout from the invariant (l=0) channels.

TPU adaptation notes (DESIGN.md): message passing is
``jax.ops.segment_sum`` over the edge index (JAX has no SpMM path);
the per-path CG contractions are static python loops over the 15
allowed (l1,l2,l3) couplings — small dense einsums the MXU likes,
instead of e3nn's gather-based irrep kernels.

MGQE applicability: the only categorical table is the species
embedding (vocab ~100) — the paper's technique targets large vocabs,
so MACE runs WITHOUT it (DESIGN.md §4).

Non-geometric graph shapes (Cora-like, ogb-products-like) are run with
synthetic 3D coordinates + a feature projection — the cell exercises
the gather/TP/scatter structure, not chemistry.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.models.gnn import so3
from repro.nn import initializers as init
from repro.nn.mlp import mlp, mlp_init


# ----------------------------------------------------------------------
# radial basis
# ----------------------------------------------------------------------

def bessel_basis(dist: jax.Array, n_rbf: int, r_cut: float) -> jax.Array:
    """(E,) -> (E, n_rbf); sin(n pi r / rc) / r with smooth cutoff."""
    d = jnp.maximum(dist, 1e-6)[..., None]
    # explicit rank match (sanitizer lane runs rank_promotion='raise')
    n = jnp.arange(1, n_rbf + 1, dtype=d.dtype).reshape(
        (1,) * (d.ndim - 1) + (-1,))
    rb = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * d / r_cut) / d
    # polynomial envelope (p=5) going smoothly to 0 at r_cut
    x = jnp.clip(dist / r_cut, 0.0, 1.0)[..., None]
    env = 1.0 - 10.0 * x ** 3 + 15.0 * x ** 4 - 6.0 * x ** 5
    return rb * env


class MACE:
    def __init__(self, cfg: GNNConfig):
        self.cfg = cfg
        self.paths = so3.coupling_table(cfg.l_max)
        self.n_paths = len(self.paths)
        self.n_sh = so3.num_sh(cfg.l_max)
        self.slices = so3.irrep_slices(cfg.l_max)
        # per-l output channel-mix indices
        self.cgs = [jnp.asarray(cg, jnp.float32) for (_, _, _, cg) in self.paths]

    # ------------------------------------------------------------- init
    def init(self, key, n_feat: Optional[int] = None) -> Dict:
        cfg = self.cfg
        c = cfg.d_hidden
        keys = jax.random.split(key, 4 + cfg.num_layers)
        params: Dict = {}
        if n_feat:
            params["feat_proj"] = init.dense_init(keys[0], n_feat, c)
        params["species_emb"] = init.normal(
            keys[1], (cfg.num_species, c), c ** -0.5)
        layers = []
        for t in range(cfg.num_layers):
            lk = jax.random.split(keys[4 + t], 8)
            layer = {
                # radial MLP: rbf -> per-channel per-path edge weights
                "radial": mlp_init(lk[0], (cfg.n_rbf, 64, c * self.n_paths),
                                   bias=False),
                # channel mix of A per l
                "a_mix": init.normal(lk[1], (cfg.l_max + 1, c, c), c ** -0.5),
                # per-channel per-path weights for B2/B3 contractions
                "u2": init.normal(lk[2], (c, self.n_paths), self.n_paths ** -0.5),
                "u3": init.normal(lk[3], (c, self.n_paths), self.n_paths ** -0.5),
                # message channel-mix per l for B1/B2/B3
                "m1": init.normal(lk[4], (cfg.l_max + 1, c, c), (3 * c) ** -0.5),
                "m2": init.normal(lk[5], (cfg.l_max + 1, c, c), (3 * c) ** -0.5),
                "m3": init.normal(lk[6], (cfg.l_max + 1, c, c), (3 * c) ** -0.5),
                "readout": mlp_init(lk[7], (c, 64, cfg.d_readout)),
            }
            layers.append(layer)
        params["layers"] = layers
        return params

    # -------------------------------------------------------- helpers
    def _mix_per_l(self, w: jax.Array, x: jax.Array) -> jax.Array:
        """w (L+1, C, C); x (N, C, S) -> per-l channel mix."""
        outs = []
        for l, sl in enumerate(self.slices):
            outs.append(jnp.einsum("ncs,cd->nds", x[:, :, sl], w[l]))
        return jnp.concatenate(outs, axis=-1)

    def _pairwise(self, x: jax.Array, y: jax.Array, u: jax.Array) -> jax.Array:
        """CG-contract two irrep features channel-wise.
        x, y (N, C, S); u (C, n_paths) path weights -> (N, C, S)."""
        out = jnp.zeros_like(x)
        for p, (l1, l2, l3, _) in enumerate(self.paths):
            cg = self.cgs[p]
            contrib = jnp.einsum("zca,zcb,abk->zck",
                                 x[:, :, self.slices[l1]],
                                 y[:, :, self.slices[l2]], cg)
            out = out.at[:, :, self.slices[l3]].add(contrib * u[:, p][None, :, None])
        return out

    # -------------------------------------------------------- forward
    def apply(self, params: Dict, graph: Dict) -> Dict:
        """graph: positions (N,3), edge_index (2,E) [send, recv],
        species (N,) and/or node_feats (N,F), optional graph_id (N,).

        Returns {"node_out": (N, d_readout), "energy": per-graph sums}.
        """
        cfg = self.cfg
        pos = graph["positions"]
        send, recv = graph["edge_index"][0], graph["edge_index"][1]
        n = pos.shape[0]
        c = cfg.d_hidden

        h = jnp.take(params["species_emb"], graph["species"], axis=0)
        if "node_feats" in graph and "feat_proj" in params:
            h = h + init.dense(params["feat_proj"], graph["node_feats"])

        # initial irrep features: invariant channel only
        x = jnp.zeros((n, c, self.n_sh), h.dtype).at[:, :, 0].set(h)

        rij = pos[recv] - pos[send]
        dist = jnp.linalg.norm(rij, axis=-1)
        rbf = bessel_basis(dist, cfg.n_rbf, cfg.r_cut)          # (E, n_rbf)
        y_sh = so3.spherical_harmonics(cfg.l_max, rij)          # (E, S)
        # Self-loop / padding edges (r == 0) MUST be masked: Y(0) is a
        # constant non-rotating vector with a non-zero l=2 component —
        # letting it through contaminates the A-basis and silently
        # breaks E(3) equivariance.  Samplers pad with self-loops, so
        # this mask is a correctness requirement, not an optimization.
        edge_mask = (dist > 1e-6).astype(y_sh.dtype)            # (E,)

        node_out = jnp.zeros((n, cfg.d_readout), jnp.float32)
        for layer in params["layers"]:
            w_r = mlp(layer["radial"], rbf, act="silu")          # (E, C*P)
            w_r = w_r.reshape(-1, c, self.n_paths) \
                * edge_mask[:, None, None]
            x_send = jnp.take(x, send, axis=0)                   # (E, C, S)
            # edge tensor product over allowed paths
            phi = jnp.zeros_like(x_send)
            for p, (l1, l2, l3, _) in enumerate(self.paths):
                cg = self.cgs[p]
                contrib = jnp.einsum(
                    "eca,eb,abk->eck",
                    x_send[:, :, self.slices[l1]],
                    y_sh[:, self.slices[l2]], cg)
                phi = phi.at[:, :, self.slices[l3]].add(
                    contrib * w_r[:, :, p][..., None])
            # A-basis: scatter-sum messages to receivers
            a = jax.ops.segment_sum(phi, recv, num_segments=n)   # (N, C, S)
            a = self._mix_per_l(layer["a_mix"], a)
            # higher-order B-basis (correlation order 3)
            b2 = self._pairwise(a, a, layer["u2"])
            b3 = self._pairwise(b2, a, layer["u3"])
            msg = (self._mix_per_l(layer["m1"], a)
                   + self._mix_per_l(layer["m2"], b2)
                   + self._mix_per_l(layer["m3"], b3))
            x = x + msg                                          # residual
            node_out = node_out + mlp(layer["readout"], x[:, :, 0],
                                      act="silu").astype(jnp.float32)

        out = {"node_out": node_out}
        if "graph_id" in graph:
            out["energy"] = jax.ops.segment_sum(
                node_out[:, 0], graph["graph_id"],
                num_segments=graph["n_graphs"])
        return out

    # ---------------------------------------------------------- losses
    def energy_loss(self, params, graph) -> Tuple[jax.Array, Dict]:
        out = self.apply(params, graph)
        err = out["energy"] - graph["energy"]
        loss = jnp.mean(jnp.square(err))
        return loss, {"loss": loss, "rmse": jnp.sqrt(loss)}

    def node_class_loss(self, params, graph) -> Tuple[jax.Array, Dict]:
        out = self.apply(params, graph)
        logits = out["node_out"]
        labels = graph["labels"]
        mask = graph.get("label_mask", jnp.ones_like(labels, jnp.float32))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
        loss = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) \
            / jnp.maximum(jnp.sum(mask), 1.0)
        return loss, {"loss": loss, "acc": acc}
