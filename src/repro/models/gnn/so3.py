"""SO(3) algebra for MACE: real spherical harmonics (l <= 4) and real
Clebsch-Gordan coupling tensors.

Complex CG coefficients come from the standard Racah closed form; the
real-basis coupling tensors are obtained by conjugating with the
complex->real unitary.  For every allowed (l1, l2, l3) the resulting
tensor is purely real or purely imaginary — we keep the realized
(phase-fixed) tensor.  Everything is precomputed in numpy at trace
time; only the contractions themselves run on device.

Conventions: real SH index order m = (-l, ..., 0, ..., +l); harmonics
are L2-normalized on the sphere up to a common constant (Racah
normalization Y_00 = 1), which MACE's learnable weights absorb.
"""
from __future__ import annotations

import math
from functools import lru_cache
from typing import List, Tuple

import numpy as np


# ----------------------------------------------------------------------
# Complex Clebsch-Gordan (Racah formula)
# ----------------------------------------------------------------------

def _f(n: int) -> float:
    return float(math.factorial(n))


def cg_complex(j1: int, m1: int, j2: int, m2: int, j3: int, m3: int) -> float:
    """<j1 m1 j2 m2 | j3 m3> (Condon-Shortley)."""
    if m3 != m1 + m2:
        return 0.0
    if not (abs(j1 - j2) <= j3 <= j1 + j2):
        return 0.0
    if abs(m1) > j1 or abs(m2) > j2 or abs(m3) > j3:
        return 0.0
    pref = math.sqrt(
        (2 * j3 + 1) * _f(j3 + j1 - j2) * _f(j3 - j1 + j2) * _f(j1 + j2 - j3)
        / _f(j1 + j2 + j3 + 1))
    pref *= math.sqrt(_f(j3 + m3) * _f(j3 - m3) * _f(j1 - m1) * _f(j1 + m1)
                      * _f(j2 - m2) * _f(j2 + m2))
    total = 0.0
    for k in range(0, j1 + j2 - j3 + 1):
        denoms = [k, j1 + j2 - j3 - k, j1 - m1 - k, j2 + m2 - k,
                  j3 - j2 + m1 + k, j3 - j1 - m2 + k]
        if any(d < 0 for d in denoms):
            continue
        total += (-1.0) ** k / np.prod([_f(d) for d in denoms])
    return pref * total


# ----------------------------------------------------------------------
# Complex -> real unitary for spherical harmonics.
# Real index mu in (-l..l): mu<0 -> sin-type, mu>0 -> cos-type.
# ----------------------------------------------------------------------

@lru_cache(maxsize=None)
def real_unitary(l: int) -> np.ndarray:
    """U with Y^real_mu = sum_m U[mu+l, m+l] Y^complex_m."""
    dim = 2 * l + 1
    u = np.zeros((dim, dim), dtype=np.complex128)
    s2 = 1.0 / math.sqrt(2.0)
    for mu in range(-l, l + 1):
        if mu > 0:
            u[mu + l, mu + l] = (-1) ** mu * s2
            u[mu + l, -mu + l] = s2
        elif mu == 0:
            u[l, l] = 1.0
        else:  # mu < 0:  Y^real_mu = (i/sqrt2)(Y^{mu} - (-1)^mu Y^{-mu})
            u[mu + l, mu + l] = 1j * s2
            u[mu + l, -mu + l] = -1j * s2 * (-1) ** mu
    return u


@lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis coupling tensor, shape (2l1+1, 2l2+1, 2l3+1)."""
    c = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1), dtype=np.complex128)
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if abs(m3) > l3:
                continue
            c[m1 + l1, m2 + l2, m3 + l3] = cg_complex(l1, m1, l2, m2, l3, m3)
    u1, u2, u3 = real_unitary(l1), real_unitary(l2), real_unitary(l3)
    cr = np.einsum("am,bn,ck,mnk->abc", u1, u2, np.conj(u3), c)
    re, im = np.real(cr), np.imag(cr)
    if np.abs(im).max() > np.abs(re).max() * 1e-8 + 1e-12:
        if np.abs(re).max() >= np.abs(im).max() * 1e-8 + 1e-12:
            raise ValueError(
                f"coupling tensor ({l1},{l2},{l3}) is neither pure-real "
                f"nor pure-imaginary: |re|={np.abs(re).max():.3e} "
                f"|im|={np.abs(im).max():.3e}")
        return np.ascontiguousarray(im)
    return np.ascontiguousarray(re)


# ----------------------------------------------------------------------
# Real spherical harmonics (hard-coded cartesian forms up to l=4 not
# needed — MACE uses l<=3; we provide l<=2 + l=3 for headroom).
# Racah-normalized: Y_0 = 1, |Y_l|^2 summed over m = 2l+1 ... absorbed
# into learnable radial weights, so only *consistency* with real_cg's
# basis matters: both use the same complex->real unitary.
# ----------------------------------------------------------------------

def sh_l1(xyz: np.ndarray):
    # complex Y_1^m in Condon-Shortley, transformed by real_unitary(1):
    # order (mu=-1, 0, +1) == (y, z, x) up to a common constant.
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    return [y, z, x]


def sh_l2(xyz: np.ndarray):
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    s3 = math.sqrt(3.0)
    return [
        s3 * x * y,                       # mu=-2
        s3 * y * z,                       # mu=-1
        0.5 * (3 * z * z - 1.0),          # mu=0   (|r|=1 assumed)
        s3 * x * z,                       # mu=+1
        0.5 * s3 * (x * x - y * y),       # mu=+2
    ]


def spherical_harmonics(l_max: int, vectors) -> "jnp.ndarray":
    """Concatenated real SH for unit vectors (..., 3) -> (..., (l_max+1)^2).
    Accepts jax or numpy arrays (uses jnp ops)."""
    import jax.numpy as jnp
    r = vectors
    norm = jnp.maximum(jnp.linalg.norm(r, axis=-1, keepdims=True), 1e-9)
    u = r / norm
    outs = [jnp.ones(u.shape[:-1], u.dtype)]
    if l_max >= 1:
        outs += sh_l1(u)
    if l_max >= 2:
        outs += sh_l2(u)
    if l_max >= 3:
        raise NotImplementedError("l_max <= 2 supported (config uses 2)")
    return jnp.stack(outs, axis=-1)


# ----------------------------------------------------------------------
# Irrep bookkeeping for concatenated (l, m) axes.
# ----------------------------------------------------------------------

def irrep_slices(l_max: int) -> List[slice]:
    out, off = [], 0
    for l in range(l_max + 1):
        out.append(slice(off, off + 2 * l + 1))
        off += 2 * l + 1
    return out


def num_sh(l_max: int) -> int:
    return (l_max + 1) ** 2


@lru_cache(maxsize=None)
def coupling_table(l_max: int) -> List[Tuple[int, int, int, np.ndarray]]:
    """All allowed (l1, l2, l3 <= l_max) couplings with their real CG."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                out.append((l1, l2, l3, real_cg(l1, l2, l3)))
    return out


@lru_cache(maxsize=None)
def dense_coupling(l_max: int) -> np.ndarray:
    """Dense coupling tensor W (S, S, S) with S=(l_max+1)^2 combining all
    allowed (l1,l2->l3) paths (each path weight 1; learnable per-path
    weights are applied by the model before contraction)."""
    s = num_sh(l_max)
    w = np.zeros((s, s, s), dtype=np.float64)
    sl = irrep_slices(l_max)
    for l1, l2, l3, cg in coupling_table(l_max):
        w[sl[l1], sl[l2], sl[l3]] += cg
    return w


def wigner_d_from_rotation(l: int, rot: np.ndarray, n_samples: int = 200,
                           seed: int = 0) -> np.ndarray:
    """Real Wigner D for rotation matrix ``rot``: solves the linear
    system Y(R r) = D Y(r) over sampled unit vectors.  Test utility."""
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(n_samples, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    import jax.numpy as jnp
    sl = irrep_slices(l)[l]
    y = np.asarray(spherical_harmonics(l, jnp.asarray(v)))[:, sl]
    y_rot = np.asarray(spherical_harmonics(l, jnp.asarray(v @ rot.T)))[:, sl]
    d, *_ = np.linalg.lstsq(y, y_rot, rcond=None)
    return d.T
