"""Equivariant GNN family: MACE (higher-order E(3)-ACE message passing).
Message passing is built on jax.ops.segment_sum over an edge index —
JAX has no sparse-matrix message passing, so the scatter path IS part
of the system (kernel_taxonomy §B.3)."""
