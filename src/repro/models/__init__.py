"""Model zoo: LM family, MACE GNN, recsys rankers/retrievers, and the
paper's backbone recommenders (GMF, NeuMF, SASRec)."""
