"""Decoder-only LM family covering the five assigned architectures.

Design notes (DESIGN.md §5):

* Layers are **stacked** and driven by ``lax.scan`` so the HLO stays
  small at 512-device lowering.  Sliding-window size and RoPE theta are
  *traced per-layer scalars*, letting local and global layers share one
  scan body.
* gemma3's 5:1 local:global pattern gets a dedicated "pattern" layout —
  groups of (p locals + 1 global) scanned together — which is what
  makes the **split KV cache** possible: local layers keep a
  window-sized ring buffer, global layers a full-length cache.  With
  ``split_local_global_cache=False`` the same weights run with one
  uniform max-length cache (the baseline the §Perf log climbs from).
* The token-embedding table goes through ``repro.core`` — swapping
  full ↔ DPQ ↔ MGQE is a config change (the paper's claim).
* Vocab softmax is chunked over the sequence with remat so the
  (B, S, 262k) logits tensor never materializes.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.core import Embedding
from repro.nn import attention as attn
from repro.nn import initializers as init
from repro.nn import moe as moe_lib
from repro.nn.mlp import glu_ffn, glu_ffn_init
from repro.nn.norm import rms_norm, rms_norm_init
from repro.nn.rope import apply_rope


# ----------------------------------------------------------------------
# layer plan: per-layer (window, theta)
# ----------------------------------------------------------------------

def layer_windows(cfg: LMConfig, max_seq: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(windows (L,), thetas (L,)) for the uniform layout.

    Pattern models: layer i is global iff (i % (p+1)) == p.
    Uniform SWA models (mixtral): every layer windowed.
    """
    n = cfg.num_layers
    if cfg.is_pattern:
        p = cfg.local_global_pattern
        is_global = (jnp.arange(n) % (p + 1)) == p
        win = jnp.where(is_global, attn.FULL_WINDOW,
                        jnp.int32(cfg.sliding_window))
        theta = jnp.where(is_global, cfg.rope_theta_global, cfg.rope_theta)
        return win.astype(jnp.int32), theta.astype(jnp.float32)
    if cfg.sliding_window is not None:
        win = jnp.full((n,), cfg.sliding_window, jnp.int32)
    else:
        win = jnp.full((n,), attn.FULL_WINDOW, jnp.int32)
    theta = jnp.full((n,), cfg.rope_theta, jnp.float32)
    return win, theta


def cache_len_for_layer(cfg: LMConfig, window: int, max_seq: int) -> int:
    """Slots a layer's decode cache needs (static python int)."""
    if window >= max_seq:
        return max_seq
    return window


# ----------------------------------------------------------------------
# single layer
# ----------------------------------------------------------------------

def _layer_init(key, cfg: LMConfig, dtype=jnp.float32) -> dict:
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko, kf, l1, l2 = jax.random.split(key, 7)
    s = cfg.d_model ** -0.5
    p = {
        "wq": init.normal(kq, (cfg.d_model, cfg.num_heads * hd), s, dtype),
        "wk": init.normal(kk, (cfg.d_model, cfg.num_kv_heads * hd), s, dtype),
        "wv": init.normal(kv, (cfg.d_model, cfg.num_kv_heads * hd), s, dtype),
        "wo": init.normal(ko, (cfg.num_heads * hd, cfg.d_model),
                          (cfg.num_heads * hd) ** -0.5, dtype),
        "ln1": rms_norm_init(cfg.d_model, dtype),
        "ln2": rms_norm_init(cfg.d_model, dtype),
    }
    if cfg.is_moe:
        p["moe"] = moe_lib.moe_init(kf, cfg.d_model, cfg.d_ff,
                                    cfg.num_experts, dtype)
    else:
        p["ffn"] = glu_ffn_init(kf, cfg.d_model, cfg.d_ff, dtype)
    return p


def _qkv(p, x, cfg: LMConfig):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, cfg.num_heads, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, cfg.num_kv_heads, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, cfg.num_kv_heads, hd)
    return q, k, v


def _ffn_block(p, x, cfg: LMConfig):
    if cfg.is_moe:
        # grouped shard_map dispatch for full sequences (train/prefill);
        # decode (S == 1) keeps the mesh-agnostic global formulation
        if cfg.moe_shard_map and x.shape[1] > 1:
            return moe_lib.moe_ffn_sharded(
                p["moe"], x, top_k=cfg.num_experts_per_tok,
                capacity_factor=cfg.moe_capacity_factor)
        return moe_lib.moe_ffn(p["moe"], x, top_k=cfg.num_experts_per_tok,
                               capacity_factor=cfg.moe_capacity_factor)
    return glu_ffn(p["ffn"], x, act=cfg.act), jnp.float32(0.0)


def layer_forward(p: dict, x: jax.Array, positions: jax.Array,
                  window, theta, cfg: LMConfig,
                  collect_kv: bool = False):
    """Full-sequence layer (train / prefill).

    Returns (y, aux) or (y, aux, (k, v)) when collect_kv.
    """
    h = rms_norm(p["ln1"], x)
    q, k, v = _qkv(p, h, cfg)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    if cfg.attn_kv_repeat and cfg.num_kv_heads < cfg.num_heads:
        g = cfg.num_heads // cfg.num_kv_heads
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    s = x.shape[1]
    impl = cfg.attention_impl
    if impl == "auto":
        # dense materializes (Sq, Skv) f32 scores per head — only safe
        # for short sequences; chunked streams KV blocks (online softmax)
        impl = "dense" if s <= 1024 else "chunked"
    if impl == "dense":
        o = attn.dense_attention(q, k, v, positions, positions, window)
    else:
        o = attn.chunked_attention(q, k, v, positions, positions, window,
                                   block=cfg.attention_block)
    x = x + (o.reshape(x.shape[0], s, -1) @ p["wo"].astype(x.dtype))
    h2 = rms_norm(p["ln2"], x)
    f, aux = _ffn_block(p, h2, cfg)
    y = x + f
    if collect_kv:
        return y, aux, (k, v)
    return y, aux


def layer_decode(p: dict, x: jax.Array, pos, window, theta,
                 k_cache, v_cache, kpos_cache, cfg: LMConfig):
    """One-token layer step.  x: (B, 1, d).  Returns (y, new caches)."""
    h = rms_norm(p["ln1"], x)
    q, k, v = _qkv(p, h, cfg)
    pos_arr = jnp.reshape(pos, (1,))
    q = apply_rope(q, pos_arr, theta)
    k = apply_rope(k, pos_arr, theta)     # rotate BEFORE caching
    k_cache, v_cache, kpos_cache = attn.cache_update(
        k_cache, v_cache, kpos_cache, k, v, pos)
    o = attn.decode_attention(q, k_cache, v_cache, kpos_cache, window)
    x = x + (o.reshape(x.shape[0], 1, -1) @ p["wo"].astype(x.dtype))
    h2 = rms_norm(p["ln2"], x)
    f, _ = _ffn_block(p, h2, cfg)
    return x + f, k_cache, v_cache, kpos_cache


# ----------------------------------------------------------------------
# model init
# ----------------------------------------------------------------------

def _stack_init(key, cfg: LMConfig, n: int, dtype) -> dict:
    keys = jax.random.split(key, max(n, 1))
    return jax.vmap(lambda k: _layer_init(k, cfg, dtype))(keys[:n]) if n \
        else None


def model_init(key, cfg: LMConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    k_emb, k_layers, k_head, k_norm = jax.random.split(key, 4)
    emb = Embedding(cfg.embedding)
    params = {
        "embed": emb.init(k_emb, dtype=dtype),
        "final_norm": rms_norm_init(cfg.d_model, dtype),
        "lm_head": init.normal(k_head, (cfg.d_model, cfg.vocab_size),
                               cfg.d_model ** -0.5, dtype),
    }
    if cfg.is_pattern:
        p = cfg.local_global_pattern
        g = cfg.num_layers // (p + 1)
        r = cfg.num_layers % (p + 1)
        kl, kg, kr = jax.random.split(k_layers, 3)
        loc = _stack_init(kl, cfg, g * p, dtype)
        params["loc"] = jax.tree.map(
            lambda a: a.reshape((g, p) + a.shape[1:]), loc)
        params["glob"] = _stack_init(kg, cfg, g, dtype)
        if r:
            params["rem"] = _stack_init(kr, cfg, r, dtype)
    else:
        params["layers"] = _stack_init(k_layers, cfg, cfg.num_layers, dtype)
    return params


# ----------------------------------------------------------------------
# forward trunk (train / prefill)
# ----------------------------------------------------------------------

def forward(params: dict, tokens: jax.Array, cfg: LMConfig,
            collect_kv: bool = False,
            embed_artifact: Optional[dict] = None):
    """tokens (B, S) -> (hidden (B, S, d), aux, kv_stacks | None).

    kv_stacks (when collect_kv): dict of per-stack (k, v) arrays in the
    same layout as the decode cache, used by prefill.

    embed_artifact: serving-time quantized embedding (codes+centroids);
    when given, the full table in params is never touched (paper Fig 1).
    """
    dtype = jnp.dtype(cfg.dtype)
    emb = Embedding(cfg.embedding)
    if embed_artifact is not None:
        x = emb.serve(embed_artifact, tokens)
        aux_emb = jnp.float32(0.0)
    else:
        x, aux_emb = emb.apply(params["embed"], tokens)
    x = x.astype(dtype) * jnp.asarray(cfg.d_model ** 0.5, dtype)
    s = tokens.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    aux = jnp.float32(0.0) + aux_emb

    group_remat = (cfg.remat and cfg.remat_granularity == "group"
                   and not collect_kv)

    def make_body(collect):
        def body(carry, xs):
            x, aux = carry
            p, window, theta = xs
            if collect:
                y, a, kv = layer_forward(p, x, positions, window, theta, cfg,
                                         collect_kv=True)
                return (y, aux + a), kv
            y, a = layer_forward(p, x, positions, window, theta, cfg)
            return (y, aux + a), None
        if cfg.remat and not group_remat:
            return jax.checkpoint(body)
        return body

    kv_out = {}
    if cfg.is_pattern:
        p_ = cfg.local_global_pattern
        r = cfg.num_layers % (p_ + 1)
        w_loc = jnp.int32(cfg.sliding_window)
        w_glob = attn.FULL_WINDOW
        th_loc = jnp.float32(cfg.rope_theta)
        th_glob = jnp.float32(cfg.rope_theta_global)

        def group_body(carry, xs):
            loc_p, glob_p = xs
            n_loc = p_
            carry, loc_kv = jax.lax.scan(make_body(collect_kv), carry,
                                         (loc_p,
                                          jnp.full((n_loc,), w_loc),
                                          jnp.full((n_loc,), th_loc)))
            carry, glob_kv = make_body(collect_kv)(carry,
                                                   (glob_p, w_glob, th_glob))
            return carry, (loc_kv, glob_kv)

        if group_remat:
            # checkpoint at group granularity: only G group-boundary
            # activations are saved; each group (p locals + 1 global)
            # recomputes during its backward
            group_body = jax.checkpoint(group_body)
        (x, aux), kvs = jax.lax.scan(group_body, (x, aux),
                                     (params["loc"], params["glob"]))
        if collect_kv:
            kv_out["loc"] = kvs[0]      # (G, p, B, S, kv, hd) k & v
            kv_out["glob"] = kvs[1]     # (G, B, S, kv, hd)
        if r:
            (x, aux), rem_kv = jax.lax.scan(
                make_body(collect_kv), (x, aux),
                (params["rem"], jnp.full((r,), w_loc),
                 jnp.full((r,), th_loc)))
            if collect_kv:
                kv_out["rem"] = rem_kv
    else:
        windows, thetas = layer_windows(cfg, s)
        if group_remat:
            blk = cfg.remat_block or max(
                1, int(round(cfg.num_layers ** 0.5)))
            while cfg.num_layers % blk:
                blk -= 1
            n_grp = cfg.num_layers // blk
            stacked = jax.tree.map(
                lambda a: a.reshape((n_grp, blk) + a.shape[1:]),
                params["layers"])
            w2 = windows.reshape(n_grp, blk)
            t2 = thetas.reshape(n_grp, blk)

            @jax.checkpoint
            def blk_body(carry, xs):
                p_grp, w_grp, th_grp = xs
                carry, _ = jax.lax.scan(make_body(False), carry,
                                        (p_grp, w_grp, th_grp))
                return carry, None

            (x, aux), _ = jax.lax.scan(blk_body, (x, aux),
                                       (stacked, w2, t2))
            kvs = None
        else:
            (x, aux), kvs = jax.lax.scan(make_body(collect_kv), (x, aux),
                                         (params["layers"], windows, thetas))
        if collect_kv:
            kv_out["layers"] = kvs

    x = rms_norm(params["final_norm"], x)
    return x, aux, (kv_out if collect_kv else None)


# ----------------------------------------------------------------------
# loss (chunked vocab softmax with remat)
# ----------------------------------------------------------------------

def chunked_xent(h: jax.Array, labels: jax.Array, w_head: jax.Array,
                 chunk: int) -> jax.Array:
    b, s, d = h.shape
    chunk = min(chunk, s)
    n_chunks = s // chunk
    if s % chunk:
        raise ValueError(f"seq len {s} not a multiple of chunk {chunk}")
    h_c = h.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    y_c = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(args):
        h_i, y_i = args
        logits = jnp.einsum("bcd,dv->bcv", h_i, w_head.astype(h_i.dtype),
                            preferred_element_type=jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via a row gather of W^T — take_along_axis on the
        # vocab-sharded logits would all-gather the full (b, c, V) tensor
        w_y = jnp.take(w_head.T, y_i, axis=0)           # (b, c, d)
        gold = jnp.sum(h_i * w_y.astype(h_i.dtype),
                       axis=-1).astype(jnp.float32)
        return jnp.sum(logz - gold)

    losses = jax.lax.map(one, (h_c, y_c))
    return jnp.sum(losses) / (b * s)


def loss_fn(params: dict, batch: dict, cfg: LMConfig) -> Tuple[jax.Array, dict]:
    h, aux, _ = forward(params, batch["tokens"], cfg)
    xent = chunked_xent(h, batch["labels"], params["lm_head"], cfg.xent_chunk)
    loss = xent + 0.01 * aux
    return loss, {"loss": loss, "xent": xent, "aux": aux}


# ----------------------------------------------------------------------
# serving: prefill + decode
# ----------------------------------------------------------------------

def _empty_like_cache(k: jax.Array):
    return jnp.full(k.shape[:-2] + (k.shape[-2],), -1, jnp.int32)


def prefill(params: dict, tokens: jax.Array, cfg: LMConfig,
            max_seq: Optional[int] = None,
            embed_artifact: Optional[dict] = None):
    """Returns (cache pytree, last-token logits).

    max_seq: decode context budget the cache must hold (>= prompt
    length).  Defaults to the prompt length, i.e. a cache with no
    headroom — callers that decode further must size it explicitly.
    """
    h, _, kvs = forward(params, tokens, cfg, collect_kv=True,
                        embed_artifact=embed_artifact)
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    max_seq = max_seq or s

    def to_cache(k, v, cache_len):
        # vmap cache_from_prefill over leading stack dims
        fn = functools.partial(attn.cache_from_prefill, kpos=positions,
                               cache_len=cache_len)
        for _ in range(k.ndim - 4):
            fn = jax.vmap(fn)
        return fn(k, v)

    cache = {"pos": jnp.int32(s)}
    if cfg.is_pattern and cfg.split_local_global_cache:
        w = cfg.sliding_window
        for name, clen in (("loc", w), ("glob", max_seq), ("rem", w)):
            if name in kvs:
                k, v = kvs[name]
                cache[name] = to_cache(k, v, min(clen, max_seq))
    elif cfg.is_pattern:
        clen = max_seq
        for name in ("loc", "glob", "rem"):
            if name in kvs:
                k, v = kvs[name]
                cache[name] = to_cache(k, v, clen)
    else:
        k, v = kvs["layers"]
        clen = cache_len_for_layer(
            cfg, cfg.sliding_window or (1 << 30), max_seq)
        cache["layers"] = to_cache(k, v, clen)

    logits = (h[:, -1] @ params["lm_head"].astype(h.dtype)
              ).astype(jnp.float32)
    return cache, logits


def make_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None) -> dict:
    """Allocate an empty decode cache (also used as a ShapeDtypeStruct
    template by the dry-run)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads

    def zeros(lead, clen):
        k = jnp.zeros(lead + (batch, clen, kv, hd), dtype)
        v = jnp.zeros(lead + (batch, clen, kv, hd), dtype)
        kp = jnp.full(lead + (batch, clen), -1, jnp.int32)
        return k, v, kp

    cache = {"pos": jnp.int32(0)}
    if cfg.is_pattern:
        p = cfg.local_global_pattern
        g = cfg.num_layers // (p + 1)
        r = cfg.num_layers % (p + 1)
        if cfg.split_local_global_cache:
            w = min(cfg.sliding_window, max_seq)
            cache["loc"] = zeros((g, p), w)
            cache["glob"] = zeros((g,), max_seq)
            if r:
                cache["rem"] = zeros((r,), w)
        else:
            cache["loc"] = zeros((g, p), max_seq)
            cache["glob"] = zeros((g,), max_seq)
            if r:
                cache["rem"] = zeros((r,), max_seq)
    else:
        clen = cache_len_for_layer(
            cfg, cfg.sliding_window or (1 << 30), max_seq)
        cache["layers"] = zeros((cfg.num_layers,), clen)
    return cache


def decode_step(params: dict, cache: dict, token: jax.Array, cfg: LMConfig,
                embed_artifact: Optional[dict] = None):
    """One decode step.  token (B,) int32 -> (new_cache, logits (B, V)).

    embed_artifact: serving-time embedding (codes + centroids for
    DPQ/MGQE) — the paper's Figure-1 serving path.  Falls back to the
    training table when None.
    """
    dtype = jnp.dtype(cfg.dtype)
    emb = Embedding(cfg.embedding)
    if embed_artifact is not None:
        x = emb.serve(embed_artifact, token)
    else:
        x, _ = emb.apply(params["embed"], token)
    x = (x[:, None, :] * cfg.d_model ** 0.5).astype(dtype)   # (B, 1, d)
    pos = cache["pos"]
    new_cache = {"pos": pos + 1}

    def scan_decode(x, stack, caches, window, theta):
        k, v, kp = caches

        def body(carry, xs):
            xx = carry
            p, k_l, v_l, kp_l, w_l, th_l = xs
            y, k_l, v_l, kp_l = layer_decode(p, xx, pos, w_l, th_l,
                                             k_l, v_l, kp_l, cfg)
            return y, (k_l, v_l, kp_l)

        n = k.shape[0]
        w_arr = jnp.broadcast_to(window, (n,)).astype(jnp.int32)
        th_arr = jnp.broadcast_to(theta, (n,)).astype(jnp.float32)
        x, new = jax.lax.scan(body, x, (stack, k, v, kp, w_arr, th_arr))
        return x, new

    if cfg.is_pattern:
        w_loc = jnp.int32(cfg.sliding_window)
        th_loc = jnp.float32(cfg.rope_theta)
        th_glob = jnp.float32(cfg.rope_theta_global)

        def group_body(x, xs):
            loc_p, (lk, lv, lkp), glob_p, (gk, gv, gkp) = xs
            x, new_loc = scan_decode(x, loc_p, (lk, lv, lkp), w_loc, th_loc)
            x, gk, gv, gkp = layer_decode(glob_p, x, pos, attn.FULL_WINDOW,
                                          th_glob, gk, gv, gkp, cfg)
            return x, (new_loc, (gk, gv, gkp))

        x, news = jax.lax.scan(
            group_body, x,
            (params["loc"], cache["loc"], params["glob"], cache["glob"]))
        new_cache["loc"], new_cache["glob"] = news
        if "rem" in params:
            x, new_cache["rem"] = scan_decode(x, params["rem"], cache["rem"],
                                              w_loc, th_loc)
    else:
        windows, thetas = layer_windows(cfg, 1 << 30)
        # clamp windows to this cache's actual length
        clen = cache["layers"][0].shape[2]
        windows = jnp.minimum(windows, clen)
        k, v, kp = cache["layers"]

        def body(carry, xs):
            xx = carry
            p, k_l, v_l, kp_l, w_l, th_l = xs
            y, k_l, v_l, kp_l = layer_decode(p, xx, pos, w_l, th_l,
                                             k_l, v_l, kp_l, cfg)
            return y, (k_l, v_l, kp_l)

        x, new = jax.lax.scan(body, x, (params["layers"], k, v, kp,
                                        windows, thetas))
        new_cache["layers"] = new

    x = rms_norm(params["final_norm"], x)
    logits = (x[:, 0] @ params["lm_head"].astype(x.dtype)).astype(jnp.float32)
    return new_cache, logits
