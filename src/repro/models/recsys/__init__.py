"""RecSys model family: CTR rankers (AutoInt, DeepFM, BST), two-tower
retrieval, and the paper's backbone recommenders (GMF, NeuMF, SASRec).
All of them consume embeddings through repro.core — full, DPQ or MGQE
is a config switch."""
