"""The paper's three backbone recommenders (§3.2): GMF, NeuMF, SASRec.

Embedding tables (user + item) go through repro.core so every
compression scheme in §3.4 (FE / LRF / SQ / DPQ / MGQE) is a config
switch — these are the models the reproduction experiments train.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import Embedding, EmbeddingConfig
from repro.core.partition import frequency_boundaries
from repro.nn import initializers as init
from repro.nn.mlp import mlp, mlp_init
from repro.nn.norm import layer_norm, layer_norm_init


@dataclasses.dataclass(frozen=True)
class BackboneConfig:
    model: str                  # gmf | neumf | sasrec
    n_users: int
    n_items: int
    dim: int = 64               # paper: d=64 for all methods
    embed_kind: str = "full"    # fe | lrf | sq | dpq | mgqe ...
    num_subspaces: int = 8      # D (varied for the size sweep)
    num_centroids: int = 256    # K=256 (paper default)
    tier_head_fraction: float = 0.1
    tier_tail_centroids: int = 64
    lrf_rank: int = 16
    sq_bits: int = 8
    # neumf
    mlp_dims: Tuple[int, ...] = (128, 64, 32)
    # sasrec
    maxlen: int = 50
    n_blocks: int = 2
    n_heads: int = 1

    def emb_config(self, vocab: int) -> EmbeddingConfig:
        k = self.embed_kind
        base = dict(vocab_size=vocab, dim=self.dim)
        if k == "full":
            return EmbeddingConfig(**base)
        if k == "lrf":
            return EmbeddingConfig(kind="lrf", rank=self.lrf_rank, **base)
        if k == "sq":
            return EmbeddingConfig(kind="sq", sq_bits=self.sq_bits, **base)
        if k == "hash":
            return EmbeddingConfig(kind="hash", hash_buckets=max(16, vocab // 5),
                                   **base)
        if k == "dpq":
            return EmbeddingConfig(kind="dpq", num_subspaces=self.num_subspaces,
                                   num_centroids=self.num_centroids, **base)
        if k == "mgqe":
            bounds = frequency_boundaries(vocab, (self.tier_head_fraction,))
            return EmbeddingConfig(
                kind="mgqe", num_subspaces=self.num_subspaces,
                num_centroids=self.num_centroids, tier_boundaries=bounds,
                tier_num_centroids=(self.num_centroids,
                                    self.tier_tail_centroids), **base)
        if k == "rq":
            # residual-quantization plugin (core/schemes/rq.py):
            # num_subspaces doubles as the stage count M
            return EmbeddingConfig(
                kind="rq", num_levels=self.num_subspaces,
                num_centroids=self.num_centroids, **base)
        raise ValueError(k)


# ----------------------------------------------------------------------
# GMF (He et al. 2017): weighted elementwise product of user/item vecs.
# ----------------------------------------------------------------------

class GMF:
    def __init__(self, cfg: BackboneConfig):
        self.cfg = cfg
        self.user_emb = Embedding(cfg.emb_config(cfg.n_users))
        self.item_emb = Embedding(cfg.emb_config(cfg.n_items))

    def init(self, key) -> Dict:
        ku, ki, kw = jax.random.split(key, 3)
        return {
            "user_emb": self.user_emb.init(ku),
            "item_emb": self.item_emb.init(ki),
            "w": init.normal(kw, (self.cfg.dim,), self.cfg.dim ** -0.5),
            "b": jnp.zeros(()),
        }

    def score(self, params, user_ids, item_ids) -> Tuple[jax.Array, jax.Array]:
        u, au = self.user_emb.apply(params["user_emb"], user_ids)
        v, ai = self.item_emb.apply(params["item_emb"], item_ids)
        return (u * v) @ params["w"] + params["b"], au + ai

    def loss(self, params, batch) -> Tuple[jax.Array, Dict]:
        logits, aux = self.score(params, batch["user_ids"],
                                 batch["item_ids"])
        bce = _bce(logits, batch["label"])
        loss = bce + aux
        return loss, {"loss": loss, "bce": bce, "aux": aux}

    def mse_loss(self, params, batch) -> Tuple[jax.Array, Dict]:
        """Regression form for the AAR-like relevance task."""
        pred, aux = self.score(params, batch["user_ids"],
                               batch["item_ids"])
        mse = jnp.mean(jnp.square(pred - batch["label"]))
        loss = mse + aux
        return loss, {"loss": loss, "mse": mse, "aux": aux}

    def serving_size_bits(self) -> int:
        return (self.user_emb.serving_size_bits()
                + self.item_emb.serving_size_bits())


# ----------------------------------------------------------------------
# NeuMF: GMF branch + MLP branch with separate embeddings.
# ----------------------------------------------------------------------

class NeuMF:
    def __init__(self, cfg: BackboneConfig):
        self.cfg = cfg
        self.user_emb_g = Embedding(cfg.emb_config(cfg.n_users))
        self.item_emb_g = Embedding(cfg.emb_config(cfg.n_items))
        self.user_emb_m = Embedding(cfg.emb_config(cfg.n_users))
        self.item_emb_m = Embedding(cfg.emb_config(cfg.n_items))

    def init(self, key) -> Dict:
        kug, kig, kum, kim, km, ko = jax.random.split(key, 6)
        cfg = self.cfg
        return {
            "user_emb_g": self.user_emb_g.init(kug),
            "item_emb_g": self.item_emb_g.init(kig),
            "user_emb_m": self.user_emb_m.init(kum),
            "item_emb_m": self.item_emb_m.init(kim),
            "mlp": mlp_init(km, (2 * cfg.dim,) + tuple(cfg.mlp_dims)),
            "w_out": init.dense_init(ko, cfg.dim + cfg.mlp_dims[-1], 1),
        }

    def score(self, params, user_ids, item_ids) -> Tuple[jax.Array, jax.Array]:
        ug, a1 = self.user_emb_g.apply(params["user_emb_g"], user_ids)
        ig, a2 = self.item_emb_g.apply(params["item_emb_g"], item_ids)
        um, a3 = self.user_emb_m.apply(params["user_emb_m"], user_ids)
        im, a4 = self.item_emb_m.apply(params["item_emb_m"], item_ids)
        gmf = ug * ig
        deep = mlp(params["mlp"], jnp.concatenate([um, im], -1), act="relu",
                   final_act=True)
        out = init.dense(params["w_out"], jnp.concatenate([gmf, deep], -1))
        return out[:, 0], a1 + a2 + a3 + a4

    def loss(self, params, batch) -> Tuple[jax.Array, Dict]:
        logits, aux = self.score(params, batch["user_ids"],
                                 batch["item_ids"])
        bce = _bce(logits, batch["label"])
        loss = bce + aux
        return loss, {"loss": loss, "bce": bce, "aux": aux}

    def serving_size_bits(self) -> int:
        return sum(e.serving_size_bits() for e in
                   (self.user_emb_g, self.item_emb_g,
                    self.user_emb_m, self.item_emb_m))


# ----------------------------------------------------------------------
# SASRec (Kang & McAuley 2018): causal self-attention next-item model.
# ----------------------------------------------------------------------

class SASRec:
    def __init__(self, cfg: BackboneConfig):
        self.cfg = cfg
        # +1 row: id 0 is the padding item; real items are 1..n_items
        self.item_emb = Embedding(cfg.emb_config(cfg.n_items + 1))

    def init(self, key) -> Dict:
        ke, kp, kb = jax.random.split(key, 3)
        cfg = self.cfg
        blocks = []
        for k in jax.random.split(kb, cfg.n_blocks):
            ka, kf, k1, k2 = jax.random.split(k, 4)
            d = cfg.dim
            blocks.append({
                "wq": init.normal(ka, (d, d), d ** -0.5),
                "wk": init.normal(kf, (d, d), d ** -0.5),
                "wv": init.normal(k1, (d, d), d ** -0.5),
                "ln1": layer_norm_init(d),
                "ln2": layer_norm_init(d),
                "ffn": mlp_init(k2, (d, d, d)),
            })
        return {
            "item_emb": self.item_emb.init(ke),
            "pos_emb": init.normal(kp, (cfg.maxlen, cfg.dim), 0.02),
            "blocks": blocks,
            "final_ln": layer_norm_init(cfg.dim),
        }

    def trunk(self, params, seq_ids) -> Tuple[jax.Array, jax.Array]:
        """seq_ids (B, L) with 0 = pad -> hidden (B, L, d)."""
        cfg = self.cfg
        e, aux = self.item_emb.apply(params["item_emb"], seq_ids)
        x = e * (cfg.dim ** 0.5) + params["pos_emb"][None]
        pad = (seq_ids == 0)
        l = seq_ids.shape[1]
        causal = jnp.tril(jnp.ones((l, l), bool))
        mask = causal[None] & (~pad)[:, None, :]
        for p in params["blocks"]:
            h = layer_norm(p["ln1"], x)
            q, k, v = h @ p["wq"], h @ p["wk"], h @ p["wv"]
            scores = jnp.einsum("bqd,bkd->bqk", q, k) * (cfg.dim ** -0.5)
            scores = jnp.where(mask, scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1)
            x = x + jnp.einsum("bqk,bkd->bqd", probs, v)
            x = x + mlp(p["ffn"], layer_norm(p["ln2"], x), act="relu")
        x = layer_norm(params["final_ln"], x)
        x = x * (~pad)[..., None]
        return x, aux

    def score_items(self, params, hidden, item_ids) -> jax.Array:
        """Dot-product scores of hidden states against given items.
        hidden (..., d), item_ids (...,) aligned."""
        e, _ = self.item_emb.apply(params["item_emb"], item_ids)
        return jnp.sum(hidden * e, axis=-1)

    def loss(self, params, batch) -> Tuple[jax.Array, Dict]:
        """batch: seq (B, L), pos (B, L), neg (B, L); 0 = pad.

        SASRec's BCE over (positive, sampled-negative) at every valid
        position (Kang & McAuley 2018, eq. 6)."""
        hidden, aux = self.trunk(params, batch["seq"])
        s_pos = self.score_items(params, hidden, batch["pos"])
        s_neg = self.score_items(params, hidden, batch["neg"])
        valid = (batch["pos"] != 0).astype(jnp.float32)
        bce = (jnp.maximum(s_pos, 0) - s_pos
               + jnp.log1p(jnp.exp(-jnp.abs(s_pos)))
               + jnp.maximum(s_neg, 0)
               + jnp.log1p(jnp.exp(-jnp.abs(s_neg))))
        bce = jnp.sum(bce * valid) / jnp.maximum(jnp.sum(valid), 1.0)
        loss = bce + aux
        return loss, {"loss": loss, "bce": bce, "aux": aux}

    def serving_size_bits(self) -> int:
        return self.item_emb.serving_size_bits()


def _bce(logits, y):
    y = y.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def make_backbone(cfg: BackboneConfig):
    return {"gmf": GMF, "neumf": NeuMF, "sasrec": SASRec}[cfg.model](cfg)
