"""DeepFM [arXiv:1703.04247]: FM interaction branch + deep MLP sharing
the same field embeddings.  n_sparse=39, embed_dim=10, MLP 400-400-400.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.core import Embedding, EmbeddingConfig
from repro.models.recsys.fields import FieldEmbeddings
from repro.nn.mlp import mlp, mlp_init


class DeepFM:
    def __init__(self, cfg: RecsysConfig):
        self.cfg = cfg
        self.fields = FieldEmbeddings(cfg)
        # first-order weights: one scalar per categorical value — these
        # stay full (dim-1 tables are already minimal).
        self.first_order = [
            Embedding(EmbeddingConfig(vocab_size=v, dim=1))
            for v in cfg.field_vocab_sizes]

    def init(self, key, dtype=jnp.float32) -> Dict:
        cfg = self.cfg
        k_emb, k_fo, k_mlp = jax.random.split(key, 3)
        fo_keys = jax.random.split(k_fo, len(self.first_order))
        d_in = cfg.n_sparse * cfg.embed_dim
        return {
            "fields": self.fields.init(k_emb, dtype),
            "first_order": {f"f{i}": e.init(k, dtype=dtype)
                            for i, (e, k) in
                            enumerate(zip(self.first_order, fo_keys))},
            "mlp": mlp_init(k_mlp, (d_in,) + tuple(cfg.mlp_dims) + (1,),
                            dtype=dtype),
            "bias": jnp.zeros((), dtype),
        }

    @staticmethod
    def _fm(x: jax.Array) -> jax.Array:
        """Second-order FM term via the sum-square trick.
        x: (B, F, d) -> (B,)   0.5 * ((Σv)² − Σv²) summed over d."""
        s = jnp.sum(x, axis=1)
        sq = jnp.sum(jnp.square(x), axis=1)
        return 0.5 * jnp.sum(jnp.square(s) - sq, axis=-1)

    def _logit(self, params: Dict, x: jax.Array, fo: jax.Array) -> jax.Array:
        b = x.shape[0]
        fm = self._fm(x)
        deep = mlp(params["mlp"], x.reshape(b, -1), act="relu")[:, 0]
        return fm + deep + fo + params["bias"]

    def _first_order(self, params: Dict, ids: jax.Array) -> jax.Array:
        total = jnp.zeros((ids.shape[0],), jnp.float32)
        for i, e in enumerate(self.first_order):
            o, _ = e.apply(params["first_order"][f"f{i}"], ids[:, i])
            total = total + o[:, 0]
        return total

    def apply(self, params: Dict, batch: Dict) -> Tuple[jax.Array, jax.Array]:
        ids = batch["sparse_ids"]
        x, aux = self.fields.apply(params["fields"], ids)
        fo = self._first_order(params, ids)
        return self._logit(params, x, fo), aux

    def serve(self, params: Dict, artifacts: Dict, batch: Dict) -> jax.Array:
        ids = batch["sparse_ids"]
        x = self.fields.serve(artifacts, ids)
        fo = self._first_order(params, ids)
        return self._logit(params, x, fo)

    def loss(self, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        logits, aux = self.apply(params, batch)
        y = batch["label"].astype(jnp.float32)
        bce = jnp.mean(jnp.maximum(logits, 0) - logits * y
                       + jnp.log1p(jnp.exp(-jnp.abs(logits))))
        loss = bce + aux
        return loss, {"loss": loss, "bce": bce, "aux": aux}
