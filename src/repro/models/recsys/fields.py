"""Multi-field categorical embedding collection + EmbeddingBag.

JAX has no native EmbeddingBag or CSR sparse; sum/mean CSR pooling
routes through the fused ``embedding_bag`` Pallas kernel via the
backend dispatch layer (each table row read once, each bag written
once — the FBGEMM-TBE pattern), with the take+segment_sum jnp path as
the XLA fallback and for max mode.  Large-vocab fields are compressed
with the paper's MGQE; small fields stay full (quantizing a 100-row
table is pure overhead — same reasoning as DESIGN.md §4 MACE note).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.core import Embedding, EmbeddingConfig
from repro.core.partition import frequency_boundaries


def field_embedding_config(cfg: RecsysConfig, vocab: int) -> EmbeddingConfig:
    """Per-field embedding spec: MGQE/DPQ for big fields, full for small."""
    kind = cfg.embed_kind
    sharded = cfg.sharded_embedding and vocab >= cfg.mgqe_min_vocab
    kb = cfg.kernel_backend
    if vocab < cfg.mgqe_min_vocab or kind == "full":
        return EmbeddingConfig(vocab_size=vocab, dim=cfg.embed_dim,
                               sharded_rows=sharded, kernel_backend=kb)
    if kind == "dpq":
        return EmbeddingConfig(
            vocab_size=vocab, dim=cfg.embed_dim, kind="dpq",
            num_subspaces=cfg.num_subspaces, num_centroids=cfg.num_centroids,
            sharded_rows=sharded, kernel_backend=kb)
    if kind == "mgqe":
        bounds = frequency_boundaries(vocab, (cfg.tier_head_fraction,))
        return EmbeddingConfig(
            vocab_size=vocab, dim=cfg.embed_dim, kind="mgqe",
            num_subspaces=cfg.num_subspaces, num_centroids=cfg.num_centroids,
            tier_boundaries=bounds,
            tier_num_centroids=(cfg.num_centroids, cfg.tier_tail_centroids),
            sharded_rows=sharded, kernel_backend=kb)
    if kind == "rq":
        # residual-quantization plugin: num_subspaces doubles as the
        # stage count M (same code-bytes-per-row knob as PQ's D)
        return EmbeddingConfig(
            vocab_size=vocab, dim=cfg.embed_dim, kind="rq",
            num_levels=cfg.num_subspaces, num_centroids=cfg.num_centroids,
            sharded_rows=sharded, kernel_backend=kb)
    # baselines for the comparison sweeps
    if kind == "lrf":
        return EmbeddingConfig(vocab_size=vocab, dim=cfg.embed_dim,
                               kind="lrf", rank=max(2, cfg.embed_dim // 4))
    if kind == "sq":
        return EmbeddingConfig(vocab_size=vocab, dim=cfg.embed_dim,
                               kind="sq", sq_bits=8)
    if kind == "hash":
        return EmbeddingConfig(vocab_size=vocab, dim=cfg.embed_dim,
                               kind="hash", hash_buckets=max(64, vocab // 4))
    raise ValueError(kind)


class FieldEmbeddings:
    """One embedding table per sparse field."""

    def __init__(self, cfg: RecsysConfig):
        self.cfg = cfg
        if len(cfg.field_vocab_sizes) != cfg.n_sparse:
            raise ValueError(
                f"{len(cfg.field_vocab_sizes)} field vocab sizes for "
                f"n_sparse={cfg.n_sparse} fields")
        self.embs: List[Embedding] = [
            Embedding(field_embedding_config(cfg, v))
            for v in cfg.field_vocab_sizes]

    def init(self, key, dtype=jnp.float32) -> Dict:
        keys = jax.random.split(key, len(self.embs))
        return {f"f{i}": e.init(k, dtype=dtype)
                for i, (e, k) in enumerate(zip(self.embs, keys))}

    def apply(self, params: Dict, ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """ids (B, F) -> ((B, F, d), aux_loss)."""
        outs, aux = [], jnp.float32(0.0)
        for i, e in enumerate(self.embs):
            o, a = e.apply(params[f"f{i}"], ids[:, i])
            outs.append(o)
            aux = aux + a
        return jnp.stack(outs, axis=1), aux

    def export(self, params: Dict) -> Dict:
        return {f"f{i}": e.export(params[f"f{i}"])
                for i, e in enumerate(self.embs)}

    def serve(self, artifacts: Dict, ids: jax.Array) -> jax.Array:
        outs = [e.serve(artifacts[f"f{i}"], ids[:, i])
                for i, e in enumerate(self.embs)]
        return jnp.stack(outs, axis=1)

    def artifact_struct(self) -> Dict:
        """ShapeDtypeStruct pytree of the serving artifacts (dry-run)."""
        return {f"f{i}": e.serving_artifact_struct()
                for i, e in enumerate(self.embs)}

    def serving_size_bits(self) -> int:
        return sum(e.serving_size_bits() for e in self.embs)

    def full_size_bits(self) -> int:
        return sum(v * self.cfg.embed_dim * 32
                   for v in self.cfg.field_vocab_sizes)


# ----------------------------------------------------------------------
# EmbeddingBag: ragged multi-hot pooled lookup.
# ----------------------------------------------------------------------

def embedding_bag(table: jax.Array, ids: jax.Array, segment_ids: jax.Array,
                  num_bags: int, weights: Optional[jax.Array] = None,
                  mode: str = "sum",
                  backend: Optional[str] = None) -> jax.Array:
    """CSR-style bag: ids (nnz,), segment_ids (nnz,) sorted ascending,
    -> pooled (num_bags, d).  mode: sum | mean | max.

    sum/mean run through the dispatched fused kernel (gather +
    segment-sum in one pass); max has no fused kernel and stays on the
    jnp path.
    """
    if mode == "max":
        rows = jnp.take(table, ids, axis=0)               # (nnz, d)
        if weights is not None:
            rows = rows * weights[:, None]
        return jax.ops.segment_max(rows, segment_ids, num_segments=num_bags)
    from repro.kernels.embedding_bag import bag
    pooled = bag(table, ids, segment_ids, num_bags, weights, backend=backend)
    if mode == "mean":
        counts = jax.ops.segment_sum(
            jnp.ones_like(ids, dtype=pooled.dtype), segment_ids,
            num_segments=num_bags)
        pooled = pooled / jnp.maximum(counts, 1.0)[:, None]
    return pooled


def embedding_bag_padded(table: jax.Array, ids: jax.Array,
                         mode: str = "mean") -> jax.Array:
    """Dense padded bag: ids (B, L) with -1 padding -> (B, d)."""
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    rows = jnp.take(table, safe, axis=0)                  # (B, L, d)
    rows = rows * valid[..., None].astype(rows.dtype)
    pooled = jnp.sum(rows, axis=1)
    if mode == "mean":
        n = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1)
        pooled = pooled / n.astype(pooled.dtype)
    return pooled
