"""AutoInt [arXiv:1810.11921]: multi-head self-attention over field
embeddings.  n_sparse=39, embed_dim=16, 3 attn layers, 2 heads, d_attn=32.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.recsys.fields import FieldEmbeddings
from repro.nn import initializers as init


def _interact_layer_init(key, d_in: int, n_heads: int, d_attn: int,
                         dtype=jnp.float32) -> dict:
    kq, kk, kv, kr = jax.random.split(key, 4)
    s = d_in ** -0.5
    return {
        "wq": init.normal(kq, (d_in, n_heads * d_attn), s, dtype),
        "wk": init.normal(kk, (d_in, n_heads * d_attn), s, dtype),
        "wv": init.normal(kv, (d_in, n_heads * d_attn), s, dtype),
        "wres": init.normal(kr, (d_in, n_heads * d_attn), s, dtype),
    }


def _interact_layer(p: dict, x: jax.Array, n_heads: int,
                    d_attn: int) -> jax.Array:
    """x (B, F, d_in) -> (B, F, n_heads*d_attn); full bidirectional attn
    over the (tiny) field axis."""
    b, f, _ = x.shape
    q = (x @ p["wq"]).reshape(b, f, n_heads, d_attn)
    k = (x @ p["wk"]).reshape(b, f, n_heads, d_attn)
    v = (x @ p["wv"]).reshape(b, f, n_heads, d_attn)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (d_attn ** -0.5)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, f, -1)
    return jax.nn.relu(o + x @ p["wres"])


class AutoInt:
    def __init__(self, cfg: RecsysConfig):
        self.cfg = cfg
        self.fields = FieldEmbeddings(cfg)

    def init(self, key, dtype=jnp.float32) -> Dict:
        cfg = self.cfg
        k_emb, k_layers, k_out = jax.random.split(key, 3)
        d_attn_out = cfg.n_attn_heads * cfg.d_attn
        layer_keys = jax.random.split(k_layers, cfg.n_attn_layers)
        layers = []
        d_in = cfg.embed_dim
        for lk in layer_keys:
            layers.append(_interact_layer_init(lk, d_in, cfg.n_attn_heads,
                                               cfg.d_attn, dtype))
            d_in = d_attn_out
        return {
            "fields": self.fields.init(k_emb, dtype),
            "layers": layers,
            "w_out": init.dense_init(k_out, cfg.n_sparse * d_attn_out, 1,
                                     dtype=dtype),
        }

    def _interact(self, params: Dict, x: jax.Array) -> jax.Array:
        for p in params["layers"]:
            x = _interact_layer(p, x, self.cfg.n_attn_heads, self.cfg.d_attn)
        b = x.shape[0]
        return init.dense(params["w_out"], x.reshape(b, -1))[:, 0]

    def apply(self, params: Dict, batch: Dict) -> Tuple[jax.Array, jax.Array]:
        """batch["sparse_ids"] (B, F) -> (logits (B,), aux)."""
        x, aux = self.fields.apply(params["fields"], batch["sparse_ids"])
        return self._interact(params, x), aux

    def serve(self, params: Dict, artifacts: Dict, batch: Dict) -> jax.Array:
        x = self.fields.serve(artifacts, batch["sparse_ids"])
        return self._interact(params, x)

    def loss(self, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        logits, aux = self.apply(params, batch)
        y = batch["label"].astype(jnp.float32)
        bce = jnp.mean(jnp.maximum(logits, 0) - logits * y
                       + jnp.log1p(jnp.exp(-jnp.abs(logits))))
        loss = bce + aux
        return loss, {"loss": loss, "bce": bce, "aux": aux}
