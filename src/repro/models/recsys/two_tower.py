"""Two-tower retrieval [Yi et al. RecSys'19]: user tower + item tower ->
dot product; trained with in-batch sampled softmax + logQ correction.

This is where MGQE's serving story peaks: the item corpus (10M rows)
is stored as codes, and ``retrieval_topk`` scores a BATCH of users
against 1M candidates without ever materializing their embeddings
(ADC through the retrieval index registry — flat or IVF-probed,
DESIGN.md §3/§8).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.core import Embedding
from repro.models.recsys.fields import field_embedding_config
from repro.nn.mlp import mlp, mlp_init


class TwoTower:
    def __init__(self, cfg: RecsysConfig):
        self.cfg = cfg
        self.user_emb = Embedding(field_embedding_config(cfg, cfg.n_users))
        self.item_emb = Embedding(field_embedding_config(cfg, cfg.n_items))

    def init(self, key, dtype=jnp.float32) -> Dict:
        cfg = self.cfg
        ku, ki, kmu, kmi = jax.random.split(key, 4)
        dims = (cfg.embed_dim,) + tuple(cfg.tower_mlp)
        return {
            "user_emb": self.user_emb.init(ku, dtype),
            "item_emb": self.item_emb.init(ki, dtype),
            "user_mlp": mlp_init(kmu, dims, dtype=dtype),
            "item_mlp": mlp_init(kmi, dims, dtype=dtype),
        }

    # ------------------------------------------------------------ towers
    def user_vec(self, params, user_ids) -> Tuple[jax.Array, jax.Array]:
        e, aux = self.user_emb.apply(params["user_emb"], user_ids)
        v = mlp(params["user_mlp"], e, act="relu")
        return _l2norm(v), aux

    def item_vec(self, params, item_ids) -> Tuple[jax.Array, jax.Array]:
        e, aux = self.item_emb.apply(params["item_emb"], item_ids)
        v = mlp(params["item_mlp"], e, act="relu")
        return _l2norm(v), aux

    # ------------------------------------------------------------- train
    def loss(self, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        """In-batch sampled softmax with logQ correction.

        batch: user_ids (B,), item_ids (B,), item_logq (B,) — log of
        each item's sampling probability (its empirical frequency).
        """
        u, aux_u = self.user_vec(params, batch["user_ids"])
        v, aux_v = self.item_vec(params, batch["item_ids"])
        logits = (u @ v.T) * INV_TEMPERATURE - batch["item_logq"][None, :]
        labels = jnp.arange(u.shape[0])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
        sm = jnp.mean(logz - gold)
        loss = sm + aux_u + aux_v
        return loss, {"loss": loss, "softmax": sm, "aux": aux_u + aux_v}

    # ------------------------------------------------------------- serve
    def retrieval_scores(self, params: Dict, user_id: jax.Array,
                         cand_vectors: jax.Array) -> jax.Array:
        """Baseline: query (1,) against precomputed candidate tower
        outputs (N, dim_out) — a dense matvec reading the full matrix."""
        u, _ = self.user_vec(params, user_id)
        return cand_vectors @ u[0]

    def encode_items(self, params: Dict, item_ids: jax.Array) -> jax.Array:
        v, _ = self.item_vec(params, item_ids)
        return v

    def build_index(self, key, params: Dict, item_ids: jax.Array,
                    index_cfg=None) -> Tuple:
        """Offline: run the item tower over the corpus and build a
        retrieval index over the *tower outputs* through the index
        registry (DESIGN.md §8) — ``flat_pq`` (exact ADC) or
        ``ivf_pq`` (nprobe-probed).  Returns ``(index, artifact)``."""
        from repro.retrieval import IndexConfig, get_index
        index = get_index(index_cfg or IndexConfig())
        vecs = self.encode_items(params, item_ids)
        return index, index.build(key, vecs)

    def retrieval_topk(self, params: Dict, index, artifact: Dict,
                       user_ids: jax.Array, k: int
                       ) -> Tuple[jax.Array, jax.Array]:
        """Batched top-k retrieval: user_ids (B,) ->
        (scores (B, k), item ids (B, k)) through the index's fused
        batched search — one user-tower pass + one pass over the code
        stream for the whole batch.  Under an ambient mesh with a
        sharded artifact the per-shard top-k merge kicks in
        (retrieval/sharded.py) — call sites never branch."""
        from repro.retrieval import sharded_topk
        u, _ = self.user_vec(params, user_ids)
        return sharded_topk(index, artifact, u, k)

    # -------- single-query ADC compat layer (pre-registry callers) ----
    def build_adc_corpus(self, key, params: Dict, item_ids: jax.Array,
                         num_subspaces: int = 8,
                         num_centroids: int = 256) -> Dict:
        """Offline: PQ-code the corpus tower outputs (exact flat ADC,
        DESIGN.md §3).  Kept as a thin wrapper over ``build_index``
        with a ``flat_pq`` config."""
        from repro.retrieval import IndexConfig
        _, artifact = self.build_index(
            key, params, item_ids,
            IndexConfig(kind="flat_pq", num_subspaces=num_subspaces,
                        num_centroids=num_centroids))
        return artifact

    def retrieval_scores_adc(self, params: Dict, corpus_artifact: Dict,
                             user_id: jax.Array) -> jax.Array:
        """Score one user against the PQ-coded corpus via the pq_score
        kernel: reads N*D bytes of codes instead of N*dim*4 bytes of
        vectors.  user_id (1,) -> scores (N,)."""
        from repro.retrieval.flat_pq import adc_scores
        u, _ = self.user_vec(params, user_id)
        return adc_scores(corpus_artifact, u[0])


INV_TEMPERATURE = 20.0  # softmax temperature 0.05


def _l2norm(x, eps=1e-6):
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)
