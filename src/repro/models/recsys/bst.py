"""Behavior Sequence Transformer (Alibaba) [arXiv:1905.06874]:
transformer block over the user's last-N item sequence + target item,
then MLP.  embed_dim=32, seq_len=20, 1 block, 8 heads, MLP 1024-512-256.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.core import Embedding
from repro.models.recsys.fields import field_embedding_config
from repro.nn import initializers as init
from repro.nn.mlp import mlp, mlp_init
from repro.nn.norm import layer_norm, layer_norm_init


def _block_init(key, d: int, n_heads: int, dtype=jnp.float32) -> dict:
    kq, kk, kv, ko, k1, k2 = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wq": init.normal(kq, (d, d), s, dtype),
        "wk": init.normal(kk, (d, d), s, dtype),
        "wv": init.normal(kv, (d, d), s, dtype),
        "wo": init.normal(ko, (d, d), s, dtype),
        "ln1": layer_norm_init(d, dtype),
        "ln2": layer_norm_init(d, dtype),
        "ffn": mlp_init(k1, (d, 4 * d, d), dtype=dtype),
    }


def _block(p: dict, x: jax.Array, n_heads: int) -> jax.Array:
    b, s, d = x.shape
    hd = d // n_heads
    h = layer_norm(p["ln1"], x)
    q = (h @ p["wq"]).reshape(b, s, n_heads, hd)
    k = (h @ p["wk"]).reshape(b, s, n_heads, hd)
    v = (h @ p["wv"]).reshape(b, s, n_heads, hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (hd ** -0.5)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, d)
    x = x + o @ p["wo"]
    h2 = layer_norm(p["ln2"], x)
    return x + mlp(p["ffn"], h2, act="relu")


class BST:
    def __init__(self, cfg: RecsysConfig):
        self.cfg = cfg
        self.item_emb = Embedding(field_embedding_config(cfg, cfg.n_items))

    def init(self, key, dtype=jnp.float32) -> Dict:
        cfg = self.cfg
        ke, kp, kb, km = jax.random.split(key, 4)
        s = cfg.seq_len + 1   # history + target
        blocks = [
            _block_init(k, cfg.embed_dim, cfg.bst_heads, dtype)
            for k in jax.random.split(kb, cfg.n_blocks)]
        return {
            "item_emb": self.item_emb.init(ke, dtype),
            "pos_emb": init.normal(kp, (s, cfg.embed_dim), 0.02, dtype),
            "blocks": blocks,
            "mlp": mlp_init(km, (s * cfg.embed_dim,) + tuple(cfg.tower_mlp)
                            + (1,), dtype=dtype),
        }

    def _trunk(self, params: Dict, seq_e: jax.Array) -> jax.Array:
        x = seq_e + params["pos_emb"][None]
        for p in params["blocks"]:
            x = _block(p, x, self.cfg.bst_heads)
        b = x.shape[0]
        return mlp(params["mlp"], x.reshape(b, -1), act="relu")[:, 0]

    def apply(self, params: Dict, batch: Dict) -> Tuple[jax.Array, jax.Array]:
        """batch: hist_ids (B, L), target_id (B,) -> (logits, aux)."""
        ids = jnp.concatenate(
            [batch["hist_ids"], batch["target_id"][:, None]], axis=1)
        e, aux = self.item_emb.apply(params["item_emb"], ids)
        return self._trunk(params, e), aux

    def serve(self, params: Dict, artifact: Dict, batch: Dict) -> jax.Array:
        ids = jnp.concatenate(
            [batch["hist_ids"], batch["target_id"][:, None]], axis=1)
        e = self.item_emb.serve(artifact, ids)
        return self._trunk(params, e)

    def loss(self, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        logits, aux = self.apply(params, batch)
        y = batch["label"].astype(jnp.float32)
        bce = jnp.mean(jnp.maximum(logits, 0) - logits * y
                       + jnp.log1p(jnp.exp(-jnp.abs(logits))))
        loss = bce + aux
        return loss, {"loss": loss, "bce": bce, "aux": aux}
