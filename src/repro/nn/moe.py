"""Top-k routed mixture-of-experts FFN (GShard-style capacity dispatch).

Two implementations:

* ``moe_ffn`` — mesh-agnostic single-program formulation: static-shape
  scatter into a GLOBAL (E, C, d) dispatch buffer, batched expert
  GEMMs, gather-combine.  Correct everywhere, but under pjit the global
  buffer forces XLA to all-reduce (E, C, d)-sized partial sums every
  layer — the §Perf baseline shows ~10 TB/device/step of collectives
  for mixtral train_4k.

* ``moe_ffn_sharded`` — shard_map grouped dispatch (the real GShard
  scheme): every data shard dispatches its OWN tokens into a local
  (E, C_local, d) buffer (group-wise capacity), then
    - "expert" strategy (E % model_n == 0): all_to_all over the model
      axis routes expert rows to their owning shard; expert GEMMs are
      fully local; reverse all_to_all returns outputs.  Wire cost per
      layer = 2 x local dispatch buffer.
    - "ffn" strategy (E < model_n, e.g. mixtral's 8 experts on a
      16-way axis): experts replicated, d_ff sharded; the only
      collective is one psum of the (E, C_local, d) output buffer.

Router aux loss: load-balancing loss from Switch Transformer
(mean(fraction_tokens_e * mean_router_prob_e) * E).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.compat import shard_map

from repro.nn import initializers as init


def ambient_mesh():
    """The physical mesh installed by ``with mesh:`` (trace-time)."""
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty:
        raise RuntimeError("moe_ffn_sharded needs an ambient mesh "
                           "(wrap the jit call in `with mesh:`)")
    return mesh


def moe_init(key, d_model: int, d_ff: int, num_experts: int,
             dtype=jnp.float32) -> dict:
    k_r, k1, k2, k3 = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5
    return {
        "router": init.normal(k_r, (d_model, num_experts), s_in, dtype),
        "w_gate": init.normal(k1, (num_experts, d_model, d_ff), s_in, dtype),
        "w_up": init.normal(k2, (num_experts, d_model, d_ff), s_in, dtype),
        "w_down": init.normal(k3, (num_experts, d_ff, d_model), s_ff, dtype),
    }


def capacity(num_tokens: int, num_experts: int, top_k: int,
             factor: float) -> int:
    c = int(math.ceil(num_tokens * top_k * factor / num_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _dispatch_combine(xt: jax.Array, router: jax.Array, top_k: int,
                      cap: int, expert_fn):
    """Shared routing math: route xt (T, d), scatter into (E, cap, d),
    run ``expert_fn(buf) -> (E, cap, d)``, gather-combine.

    Returns (out (T, d), aux_loss)."""
    t, d = xt.shape
    num_experts = router.shape[-1]

    logits = (xt.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate_w, gate_i = jax.lax.top_k(probs, top_k)                  # (T, k)
    gate_w = gate_w / jnp.maximum(
        jnp.sum(gate_w, axis=-1, keepdims=True), 1e-9)

    # Switch load-balance loss.
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_i, num_experts, dtype=jnp.float32),
                axis=1), axis=0)                                  # (E,)
    aux = jnp.sum(me * ce) * num_experts

    # Position of each (token, choice) within its expert's capacity.
    flat_e = gate_i.reshape(-1)                                   # (T*k,)
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)              # exclusive
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                     # (T*k,)
    keep = pos < cap                                              # drop overflow
    slot = jnp.where(keep, pos, cap - 1)

    # Dispatch: (E, C, d) buffer.  Dropped tokens scatter with weight 0.
    xt_rep = jnp.repeat(xt, top_k, axis=0)                        # (T*k, d)
    w_scatter = keep.astype(xt.dtype)[:, None]
    buf = jnp.zeros((num_experts, cap, d), xt.dtype)
    buf = buf.at[flat_e, slot].add(xt_rep * w_scatter)

    out_buf = expert_fn(buf)                                      # (E, C, d)

    # Combine: gather each (token, choice)'s output, weight, sum over k.
    gathered = out_buf[flat_e, slot]                              # (T*k, d)
    gathered = gathered * (gate_w.reshape(-1)[:, None].astype(gathered.dtype)
                           * w_scatter)
    out = jnp.sum(gathered.reshape(t, top_k, d), axis=1)
    return out, aux


def _expert_swiglu(buf, w_gate, w_up, w_down):
    gate = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    hidden = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", hidden, w_down.astype(buf.dtype))


def moe_ffn(params: dict, x: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    num_experts = params["router"].shape[-1]
    cap = capacity(t, num_experts, top_k, capacity_factor)
    out, aux = _dispatch_combine(
        xt, params["router"], top_k, cap,
        lambda buf: _expert_swiglu(buf, params["w_gate"], params["w_up"],
                                   params["w_down"]))
    return out.reshape(b, s, d), aux


def moe_ffn_sharded(params: dict, x: jax.Array, *, top_k: int,
                    capacity_factor: float = 1.25,
                    model_axis: str = "model"
                    ) -> Tuple[jax.Array, jax.Array]:
    """shard_map grouped dispatch (docstring at module top).

    Requires an ambient mesh (``with mesh:``) whose axis names include
    ``model_axis``; tokens are sharded over every other axis.  Inside
    jit, operands are resharded to the declared in_specs as needed.
    """
    mesh = ambient_mesh()
    axis_names = tuple(mesh.axis_names)
    data_axes = tuple(a for a in axis_names if a != model_axis)
    model_n = mesh.shape[model_axis]
    num_experts = params["router"].shape[-1]
    expert_par = num_experts % model_n == 0 and num_experts >= model_n

    b, s, d = x.shape

    if expert_par:
        w_spec = P(model_axis, None, None)          # E over model
    else:
        if params["w_gate"].shape[-1] % model_n:
            raise ValueError(
                f"ffn strategy needs d_ff divisible by the model axis: "
                f"w_gate {params['w_gate'].shape} over {model_n}")
        w_spec = P(None, None, model_axis)          # d_ff over model
    wd_spec = (P(model_axis, None, None) if expert_par
               else P(None, model_axis, None))

    def body(router, wg, wu, wd, x_loc):
        bl, sl, _ = x_loc.shape
        t_loc = bl * sl
        xt = x_loc.reshape(t_loc, d)
        cap = capacity(t_loc, num_experts, top_k, capacity_factor)

        if expert_par:
            def expert_fn(buf):                      # (E, cap, d) local grp
                # route expert rows to their owning model shard
                buf = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                         concat_axis=1, tiled=True)
                # -> (E/model_n, cap*model_n, d); wg is the local slice
                out = _expert_swiglu(buf, wg, wu, wd)
                return jax.lax.all_to_all(out, model_axis, split_axis=1,
                                          concat_axis=0, tiled=True)
        else:
            def expert_fn(buf):                      # experts replicated
                out = _expert_swiglu(buf, wg, wu, wd)   # partial over f
                return jax.lax.psum(out, model_axis)

        out, aux = _dispatch_combine(xt, router, top_k, cap, expert_fn)
        aux = jax.lax.pmean(aux, data_axes + (model_axis,))
        return out.reshape(bl, sl, d), aux

    if expert_par:
        # tokens split over data axes (batch) AND the model axis
        # (sequence): every (data, model) shard group-dispatches its own
        # token slice; all_to_all routes expert rows
        x_spec = P(data_axes, model_axis, None)
    else:
        # ffn strategy: every model shard must see the SAME tokens (the
        # psum sums f-slice partials of one token set), so tokens are
        # replicated over model; routing work duplicates (cheap), the
        # expert GEMMs split over d_ff
        x_spec = P(data_axes, None, None)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), w_spec, w_spec, wd_spec, x_spec),
        out_specs=(x_spec, P()),
        check=False)
    return fn(params["router"], params["w_gate"], params["w_up"],
              params["w_down"], x)
