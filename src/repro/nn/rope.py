"""Rotary position embeddings with a *traced* base frequency.

Gemma-3 interleaves local layers (theta=10k) with global layers
(theta=1M); keeping theta a traced scalar lets a single ``lax.scan``
body serve both layer types (DESIGN.md §5 — small-HLO layer stacking).
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies; theta may be traced."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta) -> jnp.ndarray:
    """Rotate x (..., seq, heads, head_dim) at integer positions (seq,)
    or (..., seq).  fp32 math, cast back to x.dtype."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                    # (half,)
    # explicit rank match (sanitizer lane runs rank_promotion='raise')
    pos = positions.astype(jnp.float32)[..., None]            # (..., seq, 1)
    angles = pos * freqs.reshape((1,) * (pos.ndim - 1) + (-1,))  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    if cos.ndim < x.ndim:        # unbatched positions, batched activations
        lead = (1,) * (x.ndim - cos.ndim)
        cos = cos.reshape(lead + cos.shape)
        sin = sin.reshape(lead + sin.shape)
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
