"""Grouped-query attention: dense, KV-chunked (online softmax), and
cached-decode paths; sliding windows expressed as *traced* scalars so
local and global layers share one scan body.

Shapes (per device, before sharding annotations):
    q:     (B, Sq, n_q, hd)
    k, v:  (B, Skv, n_kv, hd)      n_q = n_kv * group
    out:   (B, Sq, n_q, hd)

Masking model: every query/key carries an integer position.  A key is
visible iff ``0 <= qpos - kpos < window`` (causal + window in one
predicate; window = BIG for global layers) and ``kpos >= 0`` (ring-
buffer slots that haven't been written yet carry kpos = -1).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

# "infinite" window sentinel — bigger than any sequence we lower.
# Plain python int, NOT a jnp constant: a module-level jnp value would
# initialize the jax backend at import time, breaking tools that must
# set XLA_FLAGS first (launch/dryrun.py, launch/serve.py --mesh).
FULL_WINDOW = 2 ** 30

_NEG_INF = -1e30


def _split_heads(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, S, n_q, hd) -> (B, S, n_kv, g, hd)."""
    b, s, n_q, hd = q.shape
    return q.reshape(b, s, n_kv, n_q // n_kv, hd)


def _mask(qpos: jax.Array, kpos: jax.Array, window) -> jax.Array:
    """Boolean (…, Sq, Skv) visibility mask."""
    delta = qpos[..., :, None] - kpos[..., None, :]
    return (delta >= 0) & (delta < window) & (kpos[..., None, :] >= 0)


# ----------------------------------------------------------------------
# Dense path: materializes (Sq, Skv) scores.  Fine for short sequences.
# ----------------------------------------------------------------------

def dense_attention(q, k, v, qpos, kpos, window=FULL_WINDOW) -> jax.Array:
    n_kv = k.shape[2]
    qg = _split_heads(q, n_kv)                          # (B,Sq,kv,g,hd)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    mask = _mask(qpos, kpos, window)                    # (Sq,Skv)
    scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(q.shape)


# ----------------------------------------------------------------------
# Chunked path: lax.scan over KV blocks with an online softmax — the
# pure-XLA flash-attention analogue used for 32k prefill / 4k train.
# ----------------------------------------------------------------------

def chunked_attention(q, k, v, qpos, kpos, window=FULL_WINDOW,
                      block: int = 1024) -> jax.Array:
    b, skv, n_kv, hd = k.shape
    if skv % block != 0:
        # pad keys/values to a block multiple with invisible slots
        pad = block - skv % block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=-1)
        skv += pad
    n_blocks = skv // block
    qg = _split_heads(q, n_kv)
    scale = q.shape[-1] ** -0.5
    sq = q.shape[1]
    g = qg.shape[3]

    kb = k.reshape(b, n_blocks, block, n_kv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_blocks, block, n_kv, hd).transpose(1, 0, 2, 3, 4)
    kposb = kpos.reshape(n_blocks, block)

    # checkpointed body: the (Sq, block) probability tensor is recomputed
    # in the backward instead of being saved once per KV block — without
    # this, grad-of-scan stores n_blocks copies of the largest tensor.
    @jax.checkpoint
    def body(carry, blk):
        m, l, acc = carry
        k_i, v_i, kpos_i = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_i).astype(jnp.float32) * scale
        mask = _mask(qpos, kpos_i, window)              # (Sq, block)
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v_i.dtype), v_i).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, n_kv, g, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, n_kv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, kposb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4)                  # (B,Sq,kv,g,hd)
    return out.reshape(q.shape).astype(q.dtype)


# ----------------------------------------------------------------------
# Decode path: single query token against a cache.
# ----------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, kpos, window=FULL_WINDOW
                     ) -> jax.Array:
    """q: (B, 1, n_q, hd); caches (B, S, n_kv, hd); kpos (B, S) or (S,)."""
    n_kv = k_cache.shape[2]
    qg = _split_heads(q, n_kv)[:, 0]                    # (B,kv,g,hd)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32) * scale
    if kpos.ndim == 1:
        kpos = kpos[None]
    qpos = jnp.max(kpos, axis=-1)                       # newest written token
    delta = qpos[:, None] - kpos                        # (B, S)
    mask = (delta >= 0) & (delta < window) & (kpos >= 0)
    s = jnp.where(mask[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(q.shape).astype(q.dtype)


# ----------------------------------------------------------------------
# KV cache helpers (ring buffer for windowed layers, linear for global).
# ----------------------------------------------------------------------

def cache_update(k_cache, v_cache, kpos_cache, k_new, v_new, pos):
    """Write one decode step's K/V at ring slot ``pos % cache_len``.

    k_cache:(B,S,kv,hd)  k_new:(B,1,kv,hd)  pos: scalar int32 (global
    token position).  Works for both layer kinds: global layers size
    the cache at max-seq so the ring never wraps; local layers size it
    at the window.  kpos_cache (B,S) tracks which token occupies each
    slot (-1 = empty).
    """
    cache_len = k_cache.shape[1]
    slot = (pos % cache_len).astype(jnp.int32)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    kpos_cache = jax.lax.dynamic_update_slice_in_dim(
        kpos_cache,
        jnp.broadcast_to(pos.astype(jnp.int32),
                         (kpos_cache.shape[0], 1)), slot, axis=1)
    return k_cache, v_cache, kpos_cache


def cache_from_prefill(k, v, kpos, cache_len: int):
    """Convert prefill K/V (B,S,kv,hd) + positions (S,) into a ring
    cache of ``cache_len`` slots laid out by ``token % cache_len``."""
    b, s = k.shape[0], k.shape[1]
    if s <= cache_len:
        pad = cache_len - s
        k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(kpos, (0, pad), constant_values=-1)
        # slot of token t is t % cache_len == t while s <= cache_len
        return k_c, v_c, jnp.broadcast_to(kp[None], (b, cache_len))
    last_k = k[:, s - cache_len:]
    last_v = v[:, s - cache_len:]
    last_p = kpos[s - cache_len:]
    shift = s % cache_len
    k_c = jnp.roll(last_k, shift, axis=1)
    v_c = jnp.roll(last_v, shift, axis=1)
    p_c = jnp.roll(last_p, shift, axis=0)
    return k_c, v_c, jnp.broadcast_to(p_c[None], (b, cache_len))
