"""Pure-JAX neural-net substrate: params are plain pytrees (nested
dicts), every layer is an ``init``/``apply`` function pair.  No flax —
the container ships bare jax and the framework owns its full stack.
"""
