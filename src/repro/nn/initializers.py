"""Weight initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normal(key, shape, stddev: float, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=dtype) * stddev


def lecun_normal(key, shape, fan_in: int, dtype=jnp.float32):
    return normal(key, shape, fan_in ** -0.5, dtype=dtype)


def glorot_uniform(key, shape, fan_in: int, fan_out: int, dtype=jnp.float32):
    limit = (6.0 / (fan_in + fan_out)) ** 0.5
    return jax.random.uniform(key, shape, dtype=dtype, minval=-limit,
                              maxval=limit)


def dense_init(key, d_in: int, d_out: int, *, bias: bool = True,
               dtype=jnp.float32) -> dict:
    p = {"w": lecun_normal(key, (d_in, d_out), d_in, dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        # explicit rank match (sanitizer lane: rank_promotion='raise')
        b = params["b"].astype(x.dtype)
        y = y + b.reshape((1,) * (y.ndim - 1) + (-1,))
    return y
