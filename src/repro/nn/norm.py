"""Normalization layers (statistics always computed in fp32)."""
from __future__ import annotations

import jax.numpy as jnp


def rms_norm_init(dim: int, dtype=jnp.float32) -> dict:
    # scale stored as a zero-centered offset: effective gain = 1 + scale
    return {"scale": jnp.zeros((dim,), dtype=dtype)}


def rms_norm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMS statistics accumulate in fp32 via the einsum accumulator; the
    (B, S, d) tensors stay in the input dtype.  The f32-materialized
    variant cost ~200 GB/step of extra HBM traffic on the 4k-train
    cells (per-device, §Perf A2) for no accuracy benefit at bf16."""
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / x.shape[-1]
    inv = ((var + eps) ** -0.5)[..., None].astype(x.dtype)
    # explicit rank match (sanitizer lane runs rank_promotion='raise')
    gain = (1.0 + params["scale"].astype(x.dtype)).reshape(
        (1,) * (x.ndim - 1) + (-1,))
    return x * inv * gain


def layer_norm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype=dtype),
            "bias": jnp.zeros((dim,), dtype=dtype)}


def layer_norm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * (var + eps) ** -0.5
    # explicit rank match (sanitizer lane runs rank_promotion='raise')
    lead = (1,) * (y.ndim - 1)
    y = (y * params["scale"].astype(jnp.float32).reshape(lead + (-1,))
         + params["bias"].astype(jnp.float32).reshape(lead + (-1,)))
    return y.astype(x.dtype)
