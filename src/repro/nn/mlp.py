"""Dense FFN blocks: GeGLU/SwiGLU (LM) and plain MLP stacks (recsys)."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.nn import initializers as init

_ACTS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


def glu_ffn_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_ff = d_model ** -0.5, d_ff ** -0.5
    return {
        "w_gate": init.normal(k1, (d_model, d_ff), s_in, dtype),
        "w_up": init.normal(k2, (d_model, d_ff), s_in, dtype),
        "w_down": init.normal(k3, (d_ff, d_model), s_ff, dtype),
    }


def glu_ffn(params: dict, x: jax.Array, act: str = "gelu") -> jax.Array:
    fn = _ACTS[act]
    gate = fn(x @ params["w_gate"].astype(x.dtype))
    up = x @ params["w_up"].astype(x.dtype)
    return (gate * up) @ params["w_down"].astype(x.dtype)


def mlp_init(key, dims: Sequence[int], *, bias: bool = True,
             dtype=jnp.float32) -> list:
    """dims = (in, h1, ..., out)."""
    keys = jax.random.split(key, len(dims) - 1)
    return [init.dense_init(k, dims[i], dims[i + 1], bias=bias, dtype=dtype)
            for i, k in enumerate(keys)]


def mlp(params: list, x: jax.Array, act: str = "relu",
        final_act: bool = False) -> jax.Array:
    for i, layer in enumerate(params):
        x = init.dense(layer, x)
        if i < len(params) - 1 or final_act:
            x = _ACTS[act](x)
    return x
