import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Profile proxy: compile one cell and attribute loop-weighted bytes and
collective bytes to jax source regions (metadata op_name prefixes).

    PYTHONPATH=src python tools/attribute_cell.py <arch> <shape> [depth]
"""
import sys

import jax

from repro.configs.registry import shapes_for
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.roofline import analyze
from repro.roofline.model import HBM_BW, ICI_LINK_BW

arch, shape_name = sys.argv[1], sys.argv[2]
depth = int(sys.argv[3]) if len(sys.argv) > 3 else 5
opts = tuple(sys.argv[4].split(",")) if len(sys.argv) > 4 else ()

mesh = make_production_mesh()
shape = [s for s in shapes_for(arch) if s.name == shape_name][0]
cell = build_cell(arch, shape, mesh, False, opts=opts)
jit_fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                 out_shardings=cell.out_shardings,
                 donate_argnums=cell.donate)
with mesh:
    compiled = jit_fn.lower(*cell.args).compile()

import repro.roofline.hlo as H
H_depth = depth


def patched_source_key(line_rest, depth=depth):
    return H._source_key.__wrapped__(line_rest, depth) \
        if hasattr(H._source_key, "__wrapped__") else None


# use analyze with attribution at the requested depth
orig = H._source_key
H._source_key = lambda rest, d=depth: orig(rest, d)
hc = analyze(compiled.as_text(), attribute=True)
H._source_key = orig

mem = compiled.memory_analysis()
print(f"=== {arch} x {shape_name} | temps "
      f"{mem.temp_size_in_bytes/1e9:.1f} GB ===")
print(f"total: bytes {hc.bytes/1e12:.2f} TB "
      f"({hc.bytes/HBM_BW*1e3:.0f} ms) | collective "
      f"{hc.collective_bytes/1e9:.1f} GB "
      f"({hc.collective_bytes/ICI_LINK_BW*1e3:.0f} ms)")

print("\n-- top bytes by source --")
for k, v in sorted(hc.bytes_by_source.items(), key=lambda kv: -kv[1])[:18]:
    print(f"  {v/1e9:10.1f} GB  {k}")
print("\n-- top collective bytes by source --")
for k, v in sorted(hc.collective_by_source.items(),
                   key=lambda kv: -kv[1])[:18]:
    print(f"  {v/1e9:10.1f} GB  {k}")
print("\n-- collective kinds --", hc.collective_by_kind)
