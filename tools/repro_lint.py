#!/usr/bin/env python
"""Thin wrapper so the linter runs without installing the package:

    python tools/repro_lint.py [src tools ...] [--json report.json]

Equivalent to ``python -m repro.analysis`` (see that module / DESIGN.md
§15 for rules, suppressions, and the baseline policy).  Stdlib-only —
safe to run before heavyweight deps are installed.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
