import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Debug probe: compile one cell, print top-N largest op outputs in the
entry computation (proxy for what dominates temp memory) + roofline."""
import re
import sys

import jax

from repro.configs.registry import shapes_for
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh

arch = sys.argv[1]
shape_name = sys.argv[2]
multi = len(sys.argv) > 3 and sys.argv[3] == "multi"

mesh = make_production_mesh(multi_pod=multi)
shape = [s for s in shapes_for(arch) if s.name == shape_name][0]
cell = build_cell(arch, shape, mesh, multi)
jit_fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                 out_shardings=cell.out_shardings,
                 donate_argnums=cell.donate)
with mesh:
    compiled = jit_fn.lower(*cell.args).compile()
txt = compiled.as_text()

_DT = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
       "f32": 4, "s64": 8, "f64": 8}
sizes = {}
in_entry = False
for line in txt.splitlines():
    if line.startswith("ENTRY"):
        in_entry = True
        continue
    if in_entry and line.strip() == "}":
        break
    if not in_entry:
        continue
    m = re.match(r"\s*(?:ROOT )?%([\w.\-]+) = ([a-z0-9]+)\[([\d,]*)\]", line)
    if m:
        dt = _DT.get(m.group(2), 0)
        n = 1
        for d in (m.group(3).split(",") if m.group(3) else []):
            n *= int(d)
        opname = line.split("=")[1].strip().split("(")[0].split()[-1]
        sizes[m.group(1) + " :: " + opname] = n * dt

print("top-15 entry-computation op outputs (per-device bytes):")
for k, v in sorted(sizes.items(), key=lambda kv: -kv[1])[:15]:
    print(f"  {v/1e9:8.3f} GB  {k}")
mem = compiled.memory_analysis()
print("temps", mem.temp_size_in_bytes / 1e9, "GB; args",
      mem.argument_size_in_bytes / 1e9, "GB")
