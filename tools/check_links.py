"""Markdown link check: every relative link must resolve to a file,
and every anchored link (``file.md#slug`` or ``#slug``) must resolve
to a heading in the target file.

    python tools/check_links.py [file.md ...]

With no arguments, checks every tracked *.md in the repo.  External
(http/mailto) links are skipped — this is a does-it-resolve check,
not a crawler; it catches the common docs rot (renamed/deleted files
or retitled sections leaving dangling ``[x](path#anchor)``
references).  Anchors are matched against GitHub-style heading slugs
(lowercase, punctuation stripped, spaces → hyphens, duplicate
headings deduped with ``-1``/``-2`` suffixes).  Exit code 1 when any
link is broken (the CI docs job gate).
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

# [text](target) — target runs to the first whitespace or ')'
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)[^)]*\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*?)\s*$", re.MULTILINE)
_SKIP_PREFIXES = ("http://", "https://", "mailto:")


def slugify(title: str) -> str:
    """GitHub's anchor slug: lowercase, drop everything that is not a
    word char / hyphen / space, then spaces -> hyphens (consecutive
    spaces keep consecutive hyphens, matching github.com rendering)."""
    t = title.strip().lower()
    t = re.sub(r"[^\w\- ]", "", t)
    return t.replace(" ", "-")


def _strip_fences(text: str) -> str:
    # fenced code blocks contain example paths and '#' comments,
    # not links or headings — drop them
    return re.sub(r"```.*?```", "", text, flags=re.DOTALL)


def heading_anchors(path: str) -> set:
    """All anchor slugs a markdown file exposes, duplicates deduped
    the way GitHub does (second 'Foo' heading becomes foo-1)."""
    text = _strip_fences(open(path, encoding="utf-8").read())
    anchors, seen = set(), {}
    for m in _HEADING.finditer(text):
        slug = slugify(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def check_file(path: str, anchor_cache: dict) -> list:
    text = _strip_fences(open(path, encoding="utf-8").read())
    bad = []
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP_PREFIXES):
            continue
        file_part, _, anchor = target.partition("#")
        full = path if not file_part else os.path.normpath(
            os.path.join(os.path.dirname(path) or ".", file_part))
        if not os.path.exists(full):
            bad.append((path, target, "missing file"))
            continue
        if anchor and full.endswith(".md"):
            if full not in anchor_cache:
                anchor_cache[full] = heading_anchors(full)
            if anchor not in anchor_cache[full]:
                bad.append((path, target, "missing anchor"))
    return bad


def tracked_markdown() -> list:
    out = subprocess.run(["git", "ls-files", "*.md"],
                         capture_output=True, text=True, check=True)
    return out.stdout.split()


def main(argv: list) -> int:
    files = argv or tracked_markdown()
    bad, anchor_cache = [], {}
    for f in files:
        bad += check_file(f, anchor_cache)
    for path, target, why in bad:
        print(f"BROKEN {path}: ({target}) [{why}]")
    print(f"checked {len(files)} file(s): "
          f"{'all links resolve' if not bad else f'{len(bad)} broken'}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
