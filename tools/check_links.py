"""Markdown link check: every relative link must resolve to a file.

    python tools/check_links.py [file.md ...]

With no arguments, checks every tracked *.md in the repo.  External
(http/mailto) links and pure-anchor links are skipped — this is a
does-the-file-exist check, not a crawler; it catches the common docs
rot (renamed/deleted files leaving dangling `[x](path)` references).
Exit code 1 when any link is broken (the CI docs job gate).
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

# [text](target) — target up to the first ')' or '#appendix'
_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)[^)]*\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:")


def check_file(path: str) -> list:
    text = open(path, encoding="utf-8").read()
    # fenced code blocks contain example paths, not links — drop them
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    bad = []
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP_PREFIXES):
            continue
        full = os.path.normpath(
            os.path.join(os.path.dirname(path) or ".", target))
        if not os.path.exists(full):
            bad.append((path, target))
    return bad


def tracked_markdown() -> list:
    out = subprocess.run(["git", "ls-files", "*.md"],
                         capture_output=True, text=True, check=True)
    return out.stdout.split()


def main(argv: list) -> int:
    files = argv or tracked_markdown()
    bad = []
    for f in files:
        bad += check_file(f)
    for path, target in bad:
        print(f"BROKEN {path}: ({target})")
    print(f"checked {len(files)} file(s): "
          f"{'all links resolve' if not bad else f'{len(bad)} broken'}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
