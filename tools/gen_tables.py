"""Regenerate EXPERIMENTS.md tables from results/*.json."""
import json
import sys


def fmt(r):
    uf = r.get("useful_frac")
    rf = r.get("roofline_frac")
    opts = r.get("opts", "")
    return ("| {a} | {s} | {o} | {c:.1f} | {m:.1f} | {k:.1f} | {dom} | "
            "{uf} | {rf} | {p:.1f} |").format(
        a=r["arch"], s=r["shape"], o=opts or "—",
        c=r["compute_ms"], m=r["memory_ms"], k=r["collective_ms"],
        dom=r["dominant"],
        uf="—" if uf is None else f"{uf:.3f}",
        rf="—" if rf is None else f"{rf:.3f}", p=r["peak_gb"])


HDR = ("| arch | shape | opts | compute ms | memory ms | collective ms | "
       "bound | useful | roofline | peak GB/dev |\n"
       "|---|---|---|---|---|---|---|---|---|---|")


def main(paths):
    for p in paths:
        rows = json.load(open(p))
        print(f"\n### {p} ({len(rows)} rows)\n")
        print(HDR)
        key = lambda r: -(max(r["compute_ms"], r["memory_ms"],
                              r["collective_ms"]))
        for r in sorted(rows, key=key):
            print(fmt(r))


if __name__ == "__main__":
    main(sys.argv[1:] or ["results/dryrun_single.json",
                          "results/dryrun_multi.json",
                          "results/hillclimb.json"])
