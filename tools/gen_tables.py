"""Regenerate doc tables: EXPERIMENTS.md rows from results/*.json, and
the README backend/variant support matrix (``--support-matrix``).

The support matrix is *introspected*, not hand-written: variants come
from ``repro.core.types``, backends from the kernel dispatch registry,
and sharded-serving support from ``repro.sharding.quantized`` — so the
table in README.md cannot drift from the code.  Regenerate with:

    python tools/gen_tables.py --support-matrix
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def fmt(r):
    uf = r.get("useful_frac")
    rf = r.get("roofline_frac")
    opts = r.get("opts", "")
    return ("| {a} | {s} | {o} | {c:.1f} | {m:.1f} | {k:.1f} | {dom} | "
            "{uf} | {rf} | {p:.1f} |").format(
        a=r["arch"], s=r["shape"], o=opts or "—",
        c=r["compute_ms"], m=r["memory_ms"], k=r["collective_ms"],
        dom=r["dominant"],
        uf="—" if uf is None else f"{uf:.3f}",
        rf="—" if rf is None else f"{rf:.3f}", p=r["peak_gb"])


HDR = ("| arch | shape | opts | compute ms | memory ms | collective ms | "
       "bound | useful | roofline | peak GB/dev |\n"
       "|---|---|---|---|---|---|---|---|---|---|")


def support_matrix():
    """Markdown matrix: table scheme x decode backend x placement.

    Every cell is PROBED, not hardcoded: rows are enumerated from the
    scheme plugin registry (every registered quantized scheme and its
    variants — a new plugin shows up with zero edits here), backend
    columns come from the kernel dispatch registry, the single-device
    cell from an actual init -> export -> serve round trip, and the
    sharded cell from the sharding layer's own capability check plus
    its artifact placement specs — so the README table cannot drift
    from the code (CI gates on the output matching).
    """
    import jax
    from repro.core.api import Embedding
    from repro.core.schemes import registered_kinds, scheme_class
    from repro.kernels import dispatch
    from repro.sharding.quantized import supports_sharding
    from repro.sharding.rules import quantized_artifact_specs

    backends = sorted(dispatch.registered_ops()["mgqe_decode"])
    schemes = []
    for kind in registered_kinds():
        cls = scheme_class(kind)
        if not cls.supports_sharded_codes:
            continue  # the matrix covers quantized-table schemes
        for var in cls.variants():
            label = f"`{kind}`" + (f" ({var})" if var != "-" else "")
            schemes.append((label, kind, var))

    def probe(fn):
        try:
            fn()
            return "✓"
        except Exception:
            return "—"

    def probe_hot_rows(cfg):
        """End-to-end check of the hot-row decode-ahead hook
        (Scheme.precompute_hot_rows, DESIGN.md §9): export with
        hot_rows must attach a spec-shaped dense block."""
        import dataclasses
        hcfg = dataclasses.replace(cfg, hot_rows=8)
        e = Embedding(hcfg)
        hot = e.export(e.init(jax.random.PRNGKey(0)))["hot"]
        assert tuple(hot.shape) == (8, hcfg.dim), hot.shape

    def probe_async(emb, art):
        """End-to-end check of the async front-end (DESIGN.md §10):
        wrap the engine, submit through the deadline-batched flush
        thread, and get host result rows back."""
        import numpy as np
        from repro.launch.async_engine import AsyncServingEngine
        from repro.launch.engine import ServingEngine
        with AsyncServingEngine(ServingEngine(emb, art),
                                max_wait_us=100.0) as a:
            out = a.lookup(np.arange(4), timeout=60)
        assert out.shape == (4, emb.cfg.dim), out.shape

    notes = {"pallas": "TPU hw", "xla": "any", "interpret": "any, slow"}
    lines = ["| scheme | " + " | ".join(
        f"`{b}` ({notes.get(b, 'any')})" for b in backends)
        + " | single-device | sharded codes | hot rows | async engine |",
        "|---" * (len(backends) + 5) + "|"]
    for label, kind, var in schemes:
        cfg = scheme_class(kind).probe_config(var)
        emb = Embedding(cfg)
        art = emb.export(emb.init(jax.random.PRNGKey(0)))
        ids = jax.numpy.arange(8)
        cells = [probe(lambda b=b: dispatch.get_impl("mgqe_decode", b))
                 for b in backends]
        cells.append(probe(lambda: emb.serve(art, ids)))
        cells.append("✓" if supports_sharding(kind, var)
                     and probe(lambda: quantized_artifact_specs(cfg)) == "✓"
                     else "—")
        cells.append(probe(lambda: probe_hot_rows(cfg)))
        cells.append(probe(lambda: probe_async(emb, art)))
        lines.append(f"| {label} | " + " | ".join(cells) + " |")

    # retrieval index kinds (src/repro/retrieval/, DESIGN.md §8):
    # rows from the index registry, backend columns from the fused
    # pq_topk dispatch entry, search/sharded cells probed end-to-end
    from repro.retrieval import get_index, index_class as idx_class, \
        registered_index_kinds
    r_backends = sorted(dispatch.registered_ops()["pq_topk"])
    lines.append("")
    lines.append("Retrieval index kinds (`repro.retrieval`, batched "
                 "top-k through the fused `pq_topk` dispatch):")
    lines.append("")
    lines.append("| index | " + " | ".join(
        f"`{b}` ({notes.get(b, 'any')})" for b in r_backends)
        + " | batched top-k | sharded rows |")
    lines.append("|---" * (len(r_backends) + 3) + "|")
    vecs = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
    for kind in registered_index_kinds():
        index = get_index(idx_class(kind).probe_config())
        art = index.build(jax.random.PRNGKey(1), vecs)
        cells = [probe(lambda b=b: dispatch.get_impl("pq_topk", b))
                 for b in r_backends]
        cells.append(probe(lambda: index.search(art, vecs[:4], 5)))
        cells.append("✓" if index.supports_sharded
                     and probe(lambda: index.artifact_shard_specs(art))
                     == "✓" else "—")
        lines.append(f"| `{kind}` | " + " | ".join(cells) + " |")
    return "\n".join(lines)


def main(paths):
    for p in paths:
        rows = json.load(open(p))
        print(f"\n### {p} ({len(rows)} rows)\n")
        print(HDR)
        key = lambda r: -(max(r["compute_ms"], r["memory_ms"],
                              r["collective_ms"]))
        for r in sorted(rows, key=key):
            print(fmt(r))


if __name__ == "__main__":
    if "--support-matrix" in sys.argv:
        print(support_matrix())
    else:
        main(sys.argv[1:] or ["results/dryrun_single.json",
                              "results/dryrun_multi.json",
                              "results/hillclimb.json"])
