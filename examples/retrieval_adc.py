"""Beyond-paper serving: ADC retrieval over a PQ-coded corpus.

Trains a small two-tower retrieval model with in-batch sampled softmax,
PQ-codes the *item-tower outputs* offline, and scores a user against
the whole corpus via LUT summation (pq_score kernel on TPU) — reading
N*D code bytes instead of N*d*4 vector bytes.

    PYTHONPATH=src python examples/retrieval_adc.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.models.recsys.two_tower import TwoTower
from repro.train import optimizer as opt_lib
from repro.train.optimizer import TrainState


def main():
    _, cfg = get_arch("two-tower-retrieval", smoke=True)
    model = TwoTower(cfg)
    ocfg = opt_lib.OptimizerConfig(kind="adam", lr=1e-3)
    state = TrainState.create(ocfg, model.init(jax.random.PRNGKey(0)))
    step = jax.jit(opt_lib.make_step_fn(ocfg, model.loss))

    rng = np.random.default_rng(0)
    logq = float(np.log(1.0 / cfg.n_items))
    print("training two-tower retrieval (in-batch sampled softmax)...")
    for i in range(150):
        # planted structure: user u prefers items congruent mod 1000
        u = rng.integers(0, cfg.n_users, 256)
        it = (u + rng.integers(0, 5, 256) * 1000) % cfg.n_items
        batch = {"user_ids": jnp.asarray(u), "item_ids": jnp.asarray(it),
                 "item_logq": jnp.full((256,), logq, jnp.float32)}
        state, metrics = step(state, batch)
        if (i + 1) % 50 == 0:
            print(f"  step {i+1}: loss={float(metrics['loss']):.3f}")

    n_corpus = 20_000
    item_ids = jnp.arange(n_corpus, dtype=jnp.int32)
    t0 = time.time()
    corpus = model.build_adc_corpus(jax.random.PRNGKey(1), state.params,
                                    item_ids, num_subspaces=16,
                                    num_centroids=256)
    d_out = cfg.tower_mlp[-1]
    n_sub = corpus["codes"].shape[1]
    print(f"corpus PQ-coded in {time.time()-t0:.1f}s: "
          f"{corpus['codes'].nbytes/1e3:.0f} KB codes vs "
          f"{n_corpus*d_out*4/1e3:.0f} KB dense vectors "
          f"({d_out*4/n_sub:.0f}x stream cut)")

    user = jnp.asarray([123], jnp.int32)
    s_adc = np.asarray(model.retrieval_scores_adc(state.params, corpus,
                                                  user))
    vecs = model.encode_items(state.params, item_ids)
    s_exact = np.asarray(model.retrieval_scores(state.params, user, vecs))

    k = 50
    top_adc = set(np.argsort(-s_adc)[:k].tolist())
    top_exact = set(np.argsort(-s_exact)[:k].tolist())
    print(f"score corr = {np.corrcoef(s_adc, s_exact)[0, 1]:.4f}; "
          f"recall@{k} vs exact = {len(top_adc & top_exact)/k:.2f}")

    # batched top-k through the retrieval index registry (DESIGN.md §8):
    # one fused pass over the code stream for a whole user batch, and an
    # IVF index that probes nprobe/nlist of the corpus per query
    from repro.retrieval import IndexConfig
    users = jnp.asarray([123, 7, 4242, 9001], jnp.int32)
    for icfg in (IndexConfig(kind="flat_pq", num_subspaces=16),
                 IndexConfig(kind="ivf_pq", num_subspaces=16,
                             nlist=64, nprobe=8)):
        index, artifact = model.build_index(jax.random.PRNGKey(2),
                                            state.params, item_ids, icfg)
        scores, ids = model.retrieval_topk(state.params, index, artifact,
                                           users, k)
        u_vecs, _ = model.user_vec(state.params, users)
        ex = np.argsort(-np.asarray(u_vecs @ vecs.T), axis=1)[:, :k]
        rec = np.mean([len(set(np.asarray(ids)[b].tolist())
                           & set(ex[b].tolist())) / k
                       for b in range(users.shape[0])])
        print(f"{icfg.kind}: batched top-{k} for B={users.shape[0]} "
              f"users, recall vs exact = {rec:.2f}")


if __name__ == "__main__":
    main()
