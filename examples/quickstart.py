"""Quickstart: the paper's technique in 60 lines.

Train a GMF recommender on a synthetic MovieLens-like dataset with an
MGQE-compressed item/user embedding, export the serving artifact
(codes + centroids — the full table is discarded, paper Fig. 1), and
compare quality + serving size against full embeddings.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EmbeddingConfig
from repro.core.partition import frequency_boundaries
from repro.data.sampler import PointwiseSampler
from repro.data.synthetic import movielens_like
from repro.models.recsys.backbones import BackboneConfig, GMF
from repro.train import optimizer as opt_lib
from repro.train.optimizer import TrainState


def train_gmf(embed_kind: str, data, steps: int = 300):
    cfg = BackboneConfig(model="gmf", n_users=data.n_users,
                         n_items=data.n_items, dim=64,
                         embed_kind=embed_kind)
    model = GMF(cfg)
    ocfg = opt_lib.OptimizerConfig(kind="adam", lr=2e-3, grad_clip=None)
    state = TrainState.create(ocfg, model.init(jax.random.PRNGKey(0)))
    step = jax.jit(opt_lib.make_step_fn(ocfg, model.loss))
    it = iter(PointwiseSampler(data, batch_pos=512, n_neg=4))
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step(state, batch)
        if (i + 1) % 100 == 0:
            print(f"  [{embed_kind}] step {i+1}: "
                  f"loss={float(metrics['loss']):.4f}")
    return model, state


def hr_at_10(model, params, data, n_eval=300, seed=7):
    rng = np.random.default_rng(seed)
    users = rng.choice(data.n_users, n_eval, replace=False)
    cand = np.concatenate([data.test_item[users][:, None],
                           rng.integers(0, data.n_items, (n_eval, 100))], 1)
    scores, _ = jax.jit(model.score)(
        params, jnp.asarray(np.repeat(users, 101)),
        jnp.asarray(cand.reshape(-1)))
    s = np.asarray(scores).reshape(n_eval, 101)
    return float(((s[:, 1:] >= s[:, :1]).sum(1) < 10).mean())


def main():
    print("generating MovieLens-like data (1200 users x 800 items)...")
    data = movielens_like(n_users=1200, n_items=800, seed=0)

    results = {}
    for kind in ("full", "mgqe"):
        print(f"training GMF with {kind} embeddings...")
        model, state = train_gmf(kind, data)
        hr = hr_at_10(model, state.params, data)
        bits = model.serving_size_bits()
        results[kind] = (hr, bits)
        print(f"  HR@10 = {hr:.3f}; serving size = {bits/8/1e3:.0f} KB")

    full_hr, full_bits = results["full"]
    mg_hr, mg_bits = results["mgqe"]
    print(f"\nMGQE vs full: HR@10 {mg_hr:.3f} vs {full_hr:.3f} at "
          f"{100*mg_bits/full_bits:.0f}% of the serving size")

    # the serving artifact (Fig. 1): codes + centroids only
    cfg = EmbeddingConfig(
        vocab_size=100_000, dim=64, kind="mgqe", num_subspaces=8,
        num_centroids=256,
        tier_boundaries=frequency_boundaries(100_000, (0.1,)),
        tier_num_centroids=(256, 64))
    print(f"\nat production vocab (100k): MGQE = "
          f"{100*cfg.serving_size_bits()/(100_000*64*32):.1f}% of full")

    # any registered scheme is a one-line swap — e.g. the rq plugin
    # (residual quantization, core/schemes/rq.py), same code budget
    # per row as MGQE's D=8 but M=8 full-width codebooks:
    rq = EmbeddingConfig(vocab_size=100_000, dim=64, kind="rq",
                         num_levels=8, num_centroids=256)
    print(f"rq (registry plugin)      = "
          f"{100*rq.serving_size_bits()/(100_000*64*32):.1f}% of full")


if __name__ == "__main__":
    main()
