"""MGQE on an LM token embedding: quantized serving path end to end.

Loads the gemma3-4b *smoke* config (CPU-sized; the full config is
exercised by the 512-device dry-run), exports the MGQE artifact for the
token embedding, and decodes with the full table discarded.

    PYTHONPATH=src python examples/lm_mgqe_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import Embedding
from repro.models import lm


def main():
    _, cfg = get_arch("gemma3-4b", smoke=True)
    print(f"model: {cfg.name} ({cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}, embedding={cfg.embedding.kind})")
    params = lm.model_init(jax.random.PRNGKey(0), cfg)

    emb = Embedding(cfg.embedding)
    artifact = emb.export(params["embed"])
    full_bits = cfg.vocab_size * cfg.d_model * 32
    print(f"embedding artifact: {emb.serving_size_bits()/8/1e3:.1f} KB "
          f"({100*emb.serving_size_bits()/full_bits:.1f}% of the full "
          f"table) — codes {artifact['codes'].shape} "
          f"{artifact['codes'].dtype}, centroids "
          f"{artifact['centroids'].shape}")

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                          jnp.int32)
    cache, logits = jax.jit(
        lambda p, a, t: lm.prefill(p, t, cfg, max_seq=32,
                                   embed_artifact=a))(params, artifact,
                                                      prompts)
    decode = jax.jit(
        lambda p, a, c, t: lm.decode_step(p, c, t, cfg, embed_artifact=a))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks = [tok]
    for _ in range(12):
        cache, logits = decode(params, artifact, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(tok)
    out = np.asarray(jnp.stack(toks, 1))
    print(f"decoded (greedy, quantized embeddings): {out[0]}")
    assert np.isfinite(np.asarray(logits)).all()
    print("serving path OK — full table never touched after export")


if __name__ == "__main__":
    main()
