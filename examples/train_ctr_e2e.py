"""End-to-end CTR training driver with the full production substrate:
MGQE-compressed embedding tables, Adagrad, checkpointing + auto-resume,
failure injection, straggler monitoring, and serving-artifact export.

    PYTHONPATH=src python examples/train_ctr_e2e.py
    PYTHONPATH=src python examples/train_ctr_e2e.py --fail-at 120
    # relaunch after the injected crash: resumes from the checkpoint
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.data.synthetic import CTRStream
from repro.models.recsys.autoint import AutoInt
from repro.train import optimizer as opt_lib
from repro.train.loop import LoopConfig, fit
from repro.train.optimizer import TrainState
from repro.train.resilience import FailureInjector, SimulatedFailure


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=0)
    ap.add_argument("--ckpt-dir",
                    default=os.path.join(tempfile.gettempdir(),
                                         "repro_ctr_ckpt"))
    args = ap.parse_args()

    _, cfg = get_arch("autoint", smoke=True)
    model = AutoInt(cfg)
    ocfg = opt_lib.OptimizerConfig(kind="adagrad", lr=2e-2)
    state = TrainState.create(ocfg, model.init(jax.random.PRNGKey(0)))
    step_fn = opt_lib.make_step_fn(ocfg, model.loss)

    stream = CTRStream(cfg.field_vocab_sizes, batch=512, seed=0)

    def data():
        for b in stream:
            yield {"sparse_ids": jnp.asarray(b["sparse_ids"]),
                   "label": jnp.asarray(b["label"])}

    lcfg = LoopConfig(
        total_steps=args.steps, log_every=25,
        ckpt_every=50, ckpt_dir=args.ckpt_dir,
        metrics_hook=lambda s, m: print(
            f"step {s}: loss={m['loss']:.4f} bce={m['bce']:.4f}"))
    inj = (FailureInjector(fail_at_steps=[args.fail_at])
           if args.fail_at else None)

    try:
        state, hist = fit(state, step_fn, data(), lcfg, injector=inj)
    except SimulatedFailure as e:
        print(f"\n!! {e} — relaunch this script to auto-resume from "
              f"{args.ckpt_dir}")
        return 1

    # serving export: every big field table becomes codes + centroids
    artifacts = model.fields.export(state.params["fields"])
    full = model.fields.full_size_bits()
    quant = model.fields.serving_size_bits()
    print(f"\ntrained {args.steps} steps; exported serving artifacts: "
          f"{quant/8/1e6:.2f} MB vs {full/8/1e6:.2f} MB full "
          f"({100*quant/full:.1f}%)")
    # sanity: the artifact serves identically to the training forward
    batch = next(data())
    s_train, _ = model.apply(state.params, batch)
    s_serve = model.serve(state.params, artifacts, batch)
    err = float(jnp.max(jnp.abs(s_train - s_serve)))
    print(f"serve-vs-train max|Δlogit| = {err:.2e} (Fig.1 equivalence)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
