"""Bit-packed codes + fused unpack-and-decode kernel (DESIGN.md §13).

Properties held:

  * pack/unpack round-trip at every bitwidth (2/4/8), odd row counts,
    and non-divisor code widths (hypothesis property + pinned cases);
  * the fused kernel is BIT-identical to the unpack-then-decode
    reference for any block geometry, including block sizes that do
    not divide the batch and block_d values that fall back to full
    width (the candidates' value-interchangeability contract);
  * the PACKED words — not an unpacked copy — are what cross the
    dispatch boundary into the kernel impl (spy test): the whole point
    of the kernel is that no (B, D) unpacked table exists outside it;
  * malformed inputs (wrong packed width, unsupported bitwidth) raise.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.kernels import dispatch
from repro.kernels.packed_decode import (PACK_BITS, decode, pack_codes,
                                         packed_decode, packed_decode_ref,
                                         packed_width, unpack_codes)

BITS = PACK_BITS


def _codes(rng, shape, bits):
    return jnp.asarray(rng.integers(0, 2 ** bits, size=shape,
                                    dtype=np.uint8))


# ------------------------------------------------------ pack round-trip

@settings(max_examples=40, deadline=None)
@given(bits=st.sampled_from(BITS), b=st.integers(1, 33),
       d=st.integers(1, 12), seed=st.integers(0, 999))
def test_pack_unpack_round_trip_property(bits, b, d, seed):
    codes = _codes(np.random.default_rng(seed), (b, d), bits)
    packed = pack_codes(codes, bits)
    assert packed.shape == (b, packed_width(d, bits))
    assert packed.dtype == jnp.uint8
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(packed, bits, d)), np.asarray(codes))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("shape", [(1, 1), (7, 5), (33, 9), (3, 5, 7)])
def test_pack_unpack_round_trip_pinned(bits, shape):
    """Odd row counts, non-divisor widths, and >2d leading dims."""
    codes = _codes(np.random.default_rng(0), shape, bits)
    packed = pack_codes(codes, bits)
    assert packed.shape == shape[:-1] + (packed_width(shape[-1], bits),)
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(packed, bits, shape[-1])),
        np.asarray(codes))


@pytest.mark.parametrize("bits,d,w", [(2, 8, 2), (4, 8, 4), (8, 8, 8),
                                      (2, 7, 2), (4, 5, 3), (2, 1, 1)])
def test_packed_width(bits, d, w):
    assert packed_width(d, bits) == w


# -------------------------------------------------------- kernel parity

@settings(max_examples=15, deadline=None)
@given(bits=st.sampled_from(BITS), b=st.integers(1, 50),
       block_b=st.sampled_from((3, 4, 7, 16)),
       block_d=st.sampled_from((None, 1, 2, 3, 4, 8)),
       seed=st.integers(0, 99))
def test_fused_kernel_parity_any_block_geometry(bits, b, block_b,
                                                block_d, seed):
    """Interpret mode runs the real kernel body; every block geometry —
    divisor or not — must reproduce the reference bits exactly."""
    rng = np.random.default_rng(seed)
    d_sub, s = 8, 2
    codes = _codes(rng, (b, d_sub), bits)
    cent = jnp.asarray(rng.normal(size=(d_sub, 2 ** bits, s)),
                       jnp.float32)
    packed = pack_codes(codes, bits)
    ref = packed_decode_ref(packed, cent, bits)
    out = packed_decode(packed, cent, bits, block_b=block_b,
                        block_d=block_d, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("bits", BITS)
def test_dispatch_backends_bit_identical(bits):
    """xla and interpret resolve to different impls; same bits out."""
    rng = np.random.default_rng(3)
    codes = _codes(rng, (37, 8), bits)
    cent = jnp.asarray(rng.normal(size=(8, 2 ** bits, 4)), jnp.float32)
    packed = pack_codes(codes, bits)
    ref = np.asarray(decode(packed, cent, bits, backend="xla"))
    out = np.asarray(decode(packed, cent, bits, block_b=16,
                            backend="interpret"))
    np.testing.assert_array_equal(out, ref)
    assert ref.shape == (37, 32)


# ------------------------------------------------------------- spy test

def test_packed_words_reach_the_kernel_impl(monkeypatch):
    """The mpe serve path must hand the kernel impl the PACKED (B, W_i)
    uint8 words — an O(n) or even O(B) unpacked copy crossing the
    dispatch boundary would forfeit the HBM byte cut the packed layout
    exists for."""
    from repro.core.api import Embedding
    from repro.core.schemes import scheme_class
    cfg = dataclasses.replace(scheme_class("mpe").probe_config(),
                              kernel_backend="xla")
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    real = dispatch._REGISTRY["packed_decode"]["xla"]
    seen = []

    def spy(packed, cent, bits, **kw):
        seen.append((tuple(packed.shape), str(packed.dtype), bits))
        return real(packed, cent, bits, **kw)
    monkeypatch.setitem(dispatch._REGISTRY["packed_decode"], "xla", spy)
    ids = jnp.arange(9)
    out = emb.serve(art, ids)
    assert out.shape == (9, cfg.dim)
    D = cfg.num_subspaces
    assert seen == [((9, packed_width(D, b)), "uint8", b)
                    for b in cfg.tier_bits]
    # sub-byte tiers cross the boundary NARROWER than the code count —
    # the unpack really happens inside the kernel
    assert all(w < D for (_, w), _, b in seen if b < 8)


# ----------------------------------------------------------- bad inputs

def test_wrong_packed_width_raises():
    packed = jnp.zeros((4, 3), jnp.uint8)
    cent = jnp.zeros((8, 4, 2), jnp.float32)
    with pytest.raises(ValueError, match="packed width"):
        unpack_codes(packed, 2, 8)
    with pytest.raises(ValueError, match="packed width"):
        packed_decode(packed, cent, 2, interpret=True)


def test_unsupported_bitwidth_raises():
    with pytest.raises(ValueError, match="bits"):
        packed_width(8, 3)
    with pytest.raises(ValueError, match="bits"):
        packed_decode(jnp.zeros((4, 8), jnp.uint8),
                      jnp.zeros((8, 4, 2), jnp.float32), 16,
                      interpret=True)
