"""Core DPQ/MGQE correctness + the paper's serving-size accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import Embedding, EmbeddingConfig
from repro.core import dpq, mgqe
from repro.core.partition import (frequency_boundaries, rank_by_frequency,
                                  tier_of_ids, validate_partition)


def _mk(kind="dpq", vocab=120, dim=16, D=4, K=8, **kw):
    if kind == "mgqe":
        kw.setdefault("tier_boundaries", (12,))
        kw.setdefault("tier_num_centroids", (K, max(2, K // 2)))
    return EmbeddingConfig(vocab_size=vocab, dim=dim, kind=kind,
                           num_subspaces=D, num_centroids=K, **kw)


# ----------------------------------------------------------------- DPQ

def test_dpq_forward_equals_decode_of_codes(key):
    cfg = _mk("dpq")
    emb = Embedding(cfg)
    p = emb.init(key)
    ids = jnp.arange(37)
    out, aux = emb.apply(p, ids)
    # forward value must equal the decoded nearest-centroid embedding
    e = jnp.take(p["emb"], ids, axis=0)
    e_sub = e.reshape(37, 4, 4)
    codes = dpq.assign_codes(e_sub, p["centroids"])
    dec = dpq.decode_codes(codes, p["centroids"]).reshape(37, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dec), atol=1e-6)
    assert float(aux) >= 0.0


def test_dpq_serving_matches_training_forward(key):
    cfg = _mk("dpq")
    emb = Embedding(cfg)
    p = emb.init(key)
    ids = jnp.asarray([0, 5, 5, 119])
    out, _ = emb.apply(p, ids)
    art = emb.export(p)
    assert art["codes"].dtype == jnp.uint8
    sv = emb.serve(art, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(sv), atol=1e-5)


def test_dpq_straight_through_gradients(key):
    cfg = _mk("dpq")
    emb = Embedding(cfg)
    p = emb.init(key)
    ids = jnp.arange(16)

    def loss(p):
        out, aux = emb.apply(p, ids)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    # STE: gradient reaches the full table rows that were looked up
    g_emb = np.asarray(g["emb"])
    assert np.abs(g_emb[:16]).sum() > 0
    assert np.abs(g_emb[16:]).sum() == 0          # untouched rows: no grad
    assert np.abs(np.asarray(g["centroids"])).sum() > 0


def test_dpq_multi_dim_ids(key):
    cfg = _mk("dpq")
    emb = Embedding(cfg)
    p = emb.init(key)
    ids = jnp.zeros((3, 5), jnp.int32)
    out, _ = emb.apply(p, ids)
    assert out.shape == (3, 5, 16)


# ---------------------------------------------------------------- MGQE

def test_mgqe_tier_budget_respected(key):
    """Tail items may only use the first K_i centroids (paper §2.2)."""
    cfg = _mk("mgqe", K=8)
    emb = Embedding(cfg)
    p = emb.init(key)
    art = emb.export(p)
    codes = np.asarray(art["codes"])
    # head tier: ids < 12 can use all 8; tail: only first 4
    assert codes[:12].max() <= 7
    assert codes[12:].max() <= 3


def test_mgqe_serving_matches_training(key):
    cfg = _mk("mgqe")
    emb = Embedding(cfg)
    p = emb.init(key)
    ids = jnp.asarray([0, 11, 12, 119, 63])
    out, _ = emb.apply(p, ids)
    sv = emb.serve(emb.export(p), ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(sv), atol=1e-5)


@pytest.mark.parametrize("variant", ["private_k", "private_d"])
def test_mgqe_private_variants(key, variant):
    kw = dict(mgqe_variant=variant, tier_boundaries=(12,))
    if variant == "private_k":
        kw["tier_num_centroids"] = (8, 4)
    else:
        kw["tier_num_subspaces"] = (4, 2)
    cfg = EmbeddingConfig(vocab_size=120, dim=16, kind="mgqe",
                          num_subspaces=4, num_centroids=8, **kw)
    emb = Embedding(cfg)
    p = emb.init(key)
    ids = jnp.asarray([0, 50, 119])
    out, aux = emb.apply(p, ids)
    assert out.shape == (3, 16)
    assert np.isfinite(float(aux))
    sv = emb.serve(emb.export(p), ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(sv), atol=1e-5)


def test_mgqe_head_equals_dpq_when_single_tier(key):
    """One tier with K_1 = K must reduce exactly to DPQ."""
    c_dpq = _mk("dpq")
    c_mgqe = EmbeddingConfig(vocab_size=120, dim=16, kind="mgqe",
                             num_subspaces=4, num_centroids=8,
                             tier_boundaries=(), tier_num_centroids=(8,))
    e1, e2 = Embedding(c_dpq), Embedding(c_mgqe)
    p = e1.init(key)            # identical param structure
    ids = jnp.arange(120)
    o1, a1 = e1.apply(p, ids)
    o2, a2 = e2.apply(p, ids)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


# ------------------------------------------------------------ baselines

@pytest.mark.parametrize("kind,kw", [
    ("full", {}),
    ("lrf", {"rank": 4}),
    ("sq", {"sq_bits": 8}),
    ("hash", {"hash_buckets": 32}),
])
def test_baselines_roundtrip(key, kind, kw):
    cfg = EmbeddingConfig(vocab_size=120, dim=16, kind=kind, **kw)
    emb = Embedding(cfg)
    p = emb.init(key)
    ids = jnp.asarray([0, 3, 119])
    out, aux = emb.apply(p, ids)
    assert out.shape == (3, 16) and float(aux) == 0.0
    sv = emb.serve(emb.export(p), ids)
    tol = 0.05 if kind == "sq" else 1e-6     # sq is lossy by design
    np.testing.assert_allclose(np.asarray(out), np.asarray(sv), atol=tol)


def test_sq_export_quantization_error_bounded(key):
    cfg = EmbeddingConfig(vocab_size=200, dim=8, kind="sq", sq_bits=8)
    emb = Embedding(cfg)
    p = emb.init(key)
    ids = jnp.arange(200)
    out, _ = emb.apply(p, ids)
    sv = emb.serve(emb.export(p), ids)
    rng = np.asarray(out).max(0) - np.asarray(out).min(0)
    err = np.abs(np.asarray(out) - np.asarray(sv))
    assert (err <= rng / 255 + 1e-6).all()


# ------------------------------------------------------- size accounting

def test_serving_sizes_match_paper_formulas():
    n, d, D, K = 100_000, 64, 8, 256
    full = EmbeddingConfig(vocab_size=n, dim=d)
    assert full.serving_size_bits() == n * d * 32
    dq = EmbeddingConfig(vocab_size=n, dim=d, kind="dpq",
                         num_subspaces=D, num_centroids=K)
    assert dq.serving_size_bits() == n * D * 8 + 32 * K * d  # §1.1 exactly
    mg = EmbeddingConfig(vocab_size=n, dim=d, kind="mgqe",
                         num_subspaces=D, num_centroids=K,
                         tier_boundaries=(n // 10,),
                         tier_num_centroids=(256, 64))
    head, tail = n // 10, n - n // 10
    expected = head * D * 8 + tail * D * 6 + 32 * K * d
    assert mg.serving_size_bits() == expected
    # the paper's headline: MGQE ~20% of full at these settings
    assert mg.serving_size_bits() / full.serving_size_bits() < 0.25
    assert mg.serving_size_bits() < dq.serving_size_bits()


def test_paper_default_compression_ratio():
    """d=64, D=8, K=256/64 two-tier 10/90 — the §3.4 configuration."""
    for n in (10_000, 100_000, 1_000_000):
        mg = EmbeddingConfig(
            vocab_size=n, dim=64, kind="mgqe", num_subspaces=8,
            num_centroids=256, tier_boundaries=(n // 10,),
            tier_num_centroids=(256, 64))
        ratio = mg.serving_size_bits() / (n * 64 * 32)
        assert ratio < 0.30, (n, ratio)


# ------------------------------------------------------------ partition

def test_rank_by_frequency():
    counts = np.asarray([5, 100, 7, 100, 1])
    remap, inverse = rank_by_frequency(counts)
    assert list(inverse[:2]) == [1, 3]            # ties stable by old id
    assert counts[inverse[0]] >= counts[inverse[-1]]
    assert (remap[inverse] == np.arange(5)).all()


def test_frequency_boundaries_and_validation():
    b = frequency_boundaries(1000, (0.1,))
    assert b == (100,)
    validate_partition(1000, b)
    b3 = frequency_boundaries(1000, (0.05, 0.25))
    assert b3 == (50, 250)
    validate_partition(1000, b3)


@given(st.integers(10, 10_000), st.lists(
    st.floats(0.01, 0.9), min_size=1, max_size=4))
@settings(max_examples=50, deadline=None)
def test_tier_of_ids_matches_searchsorted(vocab, fracs):
    fracs = sorted(set(round(f, 3) for f in fracs))
    bounds = frequency_boundaries(vocab, fracs)
    validate_partition(vocab, bounds)
    ids = np.arange(vocab)
    tiers = tier_of_ids(ids, bounds)
    expected = np.searchsorted(np.asarray(bounds), ids, side="right")
    np.testing.assert_array_equal(np.asarray(tiers), expected)


# ------------------------------------------------- hypothesis invariants

@given(
    vocab=st.integers(20, 300),
    dim_d=st.sampled_from([(8, 2), (16, 4), (32, 8), (24, 3)]),
    k=st.sampled_from([4, 8, 16]),
)
@settings(max_examples=20, deadline=None)
def test_dpq_roundtrip_property(vocab, dim_d, k):
    dim, D = dim_d
    cfg = EmbeddingConfig(vocab_size=vocab, dim=dim, kind="dpq",
                          num_subspaces=D, num_centroids=k)
    emb = Embedding(cfg)
    p = emb.init(jax.random.PRNGKey(vocab))
    ids = jnp.arange(min(vocab, 50))
    out, _ = emb.apply(p, ids)
    sv = emb.serve(emb.export(p), ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(sv), atol=1e-5)
    # every code within range
    art = emb.export(p)
    assert int(np.asarray(art["codes"]).max()) < k


@given(
    vocab=st.integers(40, 400),
    head_frac=st.floats(0.05, 0.5),
    k_pair=st.sampled_from([(16, 4), (8, 8), (16, 2), (32, 8)]),
)
@settings(max_examples=20, deadline=None)
def test_mgqe_size_never_exceeds_dpq_property(vocab, head_frac, k_pair):
    """shared-K MGQE is never bigger than same-K DPQ (paper's point)."""
    k1, k2 = k_pair
    bounds = frequency_boundaries(vocab, (head_frac,))
    mg = EmbeddingConfig(vocab_size=vocab, dim=16, kind="mgqe",
                         num_subspaces=4, num_centroids=k1,
                         tier_boundaries=bounds,
                         tier_num_centroids=(k1, k2))
    dq = EmbeddingConfig(vocab_size=vocab, dim=16, kind="dpq",
                         num_subspaces=4, num_centroids=k1)
    assert mg.serving_size_bits() <= dq.serving_size_bits()


def test_k_limit_monotone_distance(key):
    """Masked assign with smaller budget can't find a closer centroid."""
    e = jax.random.normal(key, (20, 4, 4))
    cent = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 4))
    for klim in (2, 4, 8):
        codes = dpq.assign_codes(e, cent, jnp.full((20,), klim))
        assert int(np.asarray(codes).max()) < klim
