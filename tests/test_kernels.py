"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes and
dtypes, in interpret mode (kernel bodies execute on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dpq_assign import dpq_assign, dpq_assign_ref
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_ref
from repro.kernels.flash_attention import (attend, flash_attention,
                                           flash_attention_ref)
from repro.kernels.mgqe_decode import (decode_stages, mgqe_decode,
                                       mgqe_decode_ref, rq_decode_stages,
                                       rq_decode_stages_ref)
from repro.kernels.pq_score import build_lut_ref, pq_score, pq_score_ref


# ----------------------------------------------------------- mgqe_decode

@pytest.mark.parametrize("b,d,k,s", [
    (1, 4, 8, 4), (100, 8, 256, 8), (257, 16, 64, 4), (64, 4, 16, 32),
])
@pytest.mark.parametrize("cdtype", [jnp.uint8, jnp.int32])
def test_mgqe_decode_matches_ref(b, d, k, s, cdtype):
    if k > 256 and cdtype == jnp.uint8:
        pytest.skip("uint8 can't hold K>256")
    kk = jax.random.PRNGKey(b * 7 + d)
    codes = jax.random.randint(kk, (b, d), 0, k).astype(cdtype)
    cent = jax.random.normal(kk, (d, k, s))
    out = mgqe_decode(codes, cent, block_b=64, interpret=True)
    ref = mgqe_decode_ref(codes, cent)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mgqe_decode_dtypes(dtype):
    kk = jax.random.PRNGKey(0)
    codes = jax.random.randint(kk, (33, 4), 0, 16).astype(jnp.uint8)
    cent = jax.random.normal(kk, (4, 16, 8)).astype(dtype)
    out = mgqe_decode(codes, cent, block_b=16, interpret=True)
    ref = mgqe_decode_ref(codes, cent)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=1e-2)


# ------------------------------------------------------ rq_decode_stages

@pytest.mark.parametrize("b,m,k,d", [
    (1, 1, 8, 4),          # M=1 degenerate: single stage, no summing
    (37, 3, 16, 8),        # odd batch (block padding path)
    (256, 4, 256, 64),     # exact block, full uint8 code range
    (100, 2, 8, 16),
])
def test_rq_decode_stages_matches_ref(b, m, k, d):
    kk = jax.random.PRNGKey(b * 13 + m)
    codes = jax.random.randint(kk, (b, m), 0, k).astype(jnp.uint8)
    cbs = jax.random.normal(kk, (m, k, d))
    out = rq_decode_stages(codes, cbs, block_b=64, interpret=True)
    ref = rq_decode_stages_ref(codes, cbs)
    assert out.shape == (b, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("block_d", [None, 8, 16, 7])   # 7: non-divisor
def test_rq_decode_stages_block_d_tiling(block_d):
    kk = jax.random.PRNGKey(5)
    codes = jax.random.randint(kk, (70, 3), 0, 8).astype(jnp.uint8)
    cbs = jax.random.normal(kk, (3, 8, 16))
    out = rq_decode_stages(codes, cbs, block_b=32, block_d=block_d,
                           interpret=True)
    ref = rq_decode_stages_ref(codes, cbs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("b", [1, 37, 64, 257])
def test_decode_stages_backend_parity(b):
    """Fused (dispatched) vs unfused-per-stage at 1e-5 on all three
    backends — off-TPU pallas resolves to xla, so the triple covers
    every resolvable path."""
    kk = jax.random.PRNGKey(b)
    codes = jax.random.randint(kk, (b, 3), 0, 16).astype(jnp.uint8)
    cbs = jax.random.normal(kk, (3, 16, 8))
    unfused = sum(np.asarray(jnp.take(cbs[i], codes[:, i].astype(jnp.int32),
                                      axis=0))
                  for i in range(3))
    for backend in ("pallas", "xla", "interpret"):
        out = decode_stages(codes, cbs, block_b=64, backend=backend)
        assert out.shape == (b, 8)
        np.testing.assert_allclose(np.asarray(out), unfused, atol=1e-5,
                                   err_msg=backend)


def test_decode_stages_uint8_codes_end_to_end():
    """Codes must keep their stored dtype through the wrapper — the
    widening happens per block inside the backends, never as an eager
    O(B·M) int32 copy at the call site."""
    kk = jax.random.PRNGKey(1)
    codes = jax.random.randint(kk, (64, 2), 0, 8).astype(jnp.uint8)
    cbs = jax.random.normal(kk, (2, 8, 4))
    assert codes.dtype == jnp.uint8
    seen = {}
    from repro.kernels import dispatch as dp
    orig = dp.get_impl

    def spy(name, backend=None):
        impl = orig(name, backend)
        if name != "rq_decode_stages":
            return impl

        def wrapped(c, cb, **kw):
            seen["dtype"] = c.dtype
            return impl(c, cb, **kw)
        return wrapped
    dp_get_impl = dp.get_impl
    dp.get_impl = spy
    try:
        out_i = decode_stages(codes, cbs, backend="interpret")
        assert seen["dtype"] == jnp.uint8
        out_x = decode_stages(codes, cbs, backend="xla")
        assert seen["dtype"] == jnp.uint8
    finally:
        dp.get_impl = dp_get_impl
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_x),
                               atol=1e-5)


def test_rq_decode_stages_m1_equals_plain_gather():
    """M=1 is exactly one codebook row-gather."""
    kk = jax.random.PRNGKey(2)
    codes = jax.random.randint(kk, (33, 1), 0, 8).astype(jnp.uint8)
    cbs = jax.random.normal(kk, (1, 8, 4))
    out = rq_decode_stages(codes, cbs, block_b=16, interpret=True)
    ref = jnp.take(cbs[0], codes[:, 0].astype(jnp.int32), axis=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


# ------------------------------------------------------------ dpq_assign

@pytest.mark.parametrize("b,d,k,s", [
    (1, 4, 8, 4), (100, 8, 256, 8), (513, 4, 64, 16),
])
def test_dpq_assign_matches_ref(b, d, k, s):
    kk = jax.random.PRNGKey(b + d)
    e = jax.random.normal(kk, (b, d, s))
    cent = jax.random.normal(jax.random.PRNGKey(1), (d, k, s))
    out = dpq_assign(e, cent, None, block_b=128, interpret=True)
    ref = dpq_assign_ref(e, cent, None)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_dpq_assign_k_limit():
    kk = jax.random.PRNGKey(3)
    e = jax.random.normal(kk, (50, 4, 8))
    cent = jax.random.normal(jax.random.PRNGKey(4), (4, 32, 8))
    klim = jax.random.randint(jax.random.PRNGKey(5), (50,), 1, 33)
    out = dpq_assign(e, cent, klim, block_b=32, interpret=True)
    ref = dpq_assign_ref(e, cent, klim)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert (np.asarray(out) < np.asarray(klim)[:, None]).all()


# -------------------------------------------------------------- pq_score

@pytest.mark.parametrize("n,d,k", [(10, 4, 8), (1000, 8, 256), (2049, 16, 64)])
def test_pq_score_matches_ref(n, d, k):
    kk = jax.random.PRNGKey(n)
    codes = jax.random.randint(kk, (n, d), 0, k)
    lut = jax.random.normal(kk, (d, k))
    out = pq_score(lut, codes, block_n=512, interpret=True)
    ref = pq_score_ref(lut, codes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_adc_identity_property():
    """score via LUT == <q, decode(codes)> exactly (ADC correctness)."""
    kk = jax.random.PRNGKey(0)
    d, k, s, n = 8, 32, 8, 200
    codes = jax.random.randint(kk, (n, d), 0, k)
    cent = jax.random.normal(kk, (d, k, s))
    q = jax.random.normal(jax.random.PRNGKey(1), (d * s,))
    lut = build_lut_ref(q, cent)
    scores = pq_score_ref(lut, codes)
    decoded = mgqe_decode_ref(codes, cent)
    np.testing.assert_allclose(np.asarray(scores),
                               np.asarray(decoded @ q), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- embedding_bag

def test_embedding_bag_matches_ref():
    kk = jax.random.PRNGKey(0)
    table = jax.random.normal(kk, (40, 8))
    ids = jnp.asarray([1, 2, 2, 7, 39, 0, 5], jnp.int32)
    seg = jnp.asarray([0, 0, 2, 2, 2, 4, 4], jnp.int32)
    out = embedding_bag(table, ids, seg, 6, interpret=True)
    ref = embedding_bag_ref(table, ids, seg, 6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_embedding_bag_weighted_and_empty_bags():
    kk = jax.random.PRNGKey(1)
    table = jax.random.normal(kk, (20, 4))
    ids = jnp.asarray([3, 3, 3], jnp.int32)
    seg = jnp.asarray([1, 1, 3], jnp.int32)
    w = jnp.asarray([0.5, 1.5, 2.0])
    out = embedding_bag(table, ids, seg, 5, w, interpret=True)
    ref = embedding_bag_ref(table, ids, seg, 5, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    assert np.abs(np.asarray(out)[[0, 2, 4]]).sum() == 0


@pytest.mark.parametrize("nnz,bags,vocab,dim", [(50, 10, 100, 16),
                                                (200, 7, 30, 32)])
def test_embedding_bag_random_sweep(nnz, bags, vocab, dim):
    rng = np.random.default_rng(nnz)
    table = jnp.asarray(rng.normal(size=(vocab, dim)), jnp.float32)
    seg = jnp.asarray(np.sort(rng.integers(0, bags, nnz)), jnp.int32)
    ids = jnp.asarray(rng.integers(0, vocab, nnz), jnp.int32)
    out = embedding_bag(table, ids, seg, bags, interpret=True)
    ref = embedding_bag_ref(table, ids, seg, bags)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- flash_attention

@pytest.mark.parametrize("b,sq,skv,h,hkv,hd,win", [
    (2, 256, 256, 4, 2, 64, 1 << 30),     # GQA, causal
    (1, 128, 128, 4, 4, 32, 64),          # MHA, sliding window
    (2, 128, 384, 8, 2, 64, 1 << 30),     # cross-length
    (1, 256, 256, 2, 1, 128, 300),        # window > block
])
def test_flash_attention_matches_ref(b, sq, skv, h, hkv, hd, win):
    ks = jax.random.split(jax.random.PRNGKey(sq + h), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd)) * 0.3
    k = jax.random.normal(ks[1], (b, skv, hkv, hd)) * 0.3
    v = jax.random.normal(ks[2], (b, skv, hkv, hd))
    out = flash_attention(q, k, v, window=win, block_q=128, block_k=128,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_grad_matches_ref_grad():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32)) * 0.3
    k = jax.random.normal(ks[1], (1, 128, 2, 32)) * 0.3
    v = jax.random.normal(ks[2], (1, 128, 2, 32))

    def f_kernel(q, k, v):
        return jnp.sum(attend(q, k, v, 1 << 30) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(flash_attention_ref(q, k, v, 1 << 30) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = (jax.random.normal(ks[0], (1, 128, 2, 32)) * 0.3).astype(jnp.bfloat16)
    k = (jax.random.normal(ks[1], (1, 128, 2, 32)) * 0.3).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 32)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, interpret=True)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)
