"""Retrieval at scale (DESIGN.md §12): streamed-vs-one-shot build
bit-parity, the bounded chained list layout, host-staged serving, and
the √N nlist heuristic."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.engine import RetrievalEngine
from repro.retrieval import (INVALID_ID, IndexConfig, build_flat_artifact,
                             build_ivf_artifact, get_index, suggest_nlist)
from repro.retrieval.ivf_pq import bounded_list_layout
from tests._hypothesis_compat import given, settings, st

_N, _D = 403, 16            # deliberately not a multiple of any block


def _vectors(n=_N, d=_D, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, d)) * 2.0
    return (centers[rng.integers(0, 8, n)]
            + 0.2 * rng.normal(size=(n, d))).astype(np.float32)


_VECS = _vectors()


def _cfg(kind, **kw):
    base = dict(num_subspaces=4, num_centroids=16, iters=3)
    if kind == "ivf_pq":
        base |= dict(nlist=8, nprobe=8, coarse_iters=3)
    return IndexConfig(kind=kind, **(base | kw))


_BUILDERS = {"flat_pq": build_flat_artifact, "ivf_pq": build_ivf_artifact}


def _build(cfg, vecs=_VECS):
    return _BUILDERS[cfg.kind](jax.random.PRNGKey(7), vecs, cfg)


# one-shot references, cached per (kind, sample) so the hypothesis
# property does not refit codebooks on every drawn block size
_ONE_SHOT = {}


def _one_shot(kind, sample):
    if (kind, sample) not in _ONE_SHOT:
        art, _ = _build(_cfg(kind, train_sample=sample))
        _ONE_SHOT[(kind, sample)] = art
    return _ONE_SHOT[(kind, sample)]


# --------------------------------------------- satellite: nlist heuristic

def test_suggest_nlist_tracks_sqrt_n():
    # the old serve.py heuristic min(64, n // 64) hard-capped at 64,
    # leaving a 10M corpus with 156k-row lists
    assert suggest_nlist(10_000_000) == 3162
    assert suggest_nlist(1_000_000) == 1000
    assert suggest_nlist(100) == 10
    # clamps: never below nprobe (config validity), never above n
    assert suggest_nlist(100, nprobe=32) == 32
    assert suggest_nlist(10, nprobe=8) == 8
    assert suggest_nlist(4, nprobe=8) == 4
    assert suggest_nlist(0) == 1
    # the suggestion always yields a valid config
    IndexConfig(kind="ivf_pq", nlist=suggest_nlist(5000, 8), nprobe=8)


def test_index_config_rejects_bad_scale_knobs():
    for bad in (dict(train_sample=-1), dict(encode_block=-8),
                dict(list_cap_quantile=0.0), dict(list_cap_quantile=1.5)):
        with pytest.raises(ValueError):
            _cfg("ivf_pq", **bad)


# ------------------------------------------ streamed == one-shot parity

@pytest.mark.parametrize("kind", ["flat_pq", "ivf_pq"])
def test_streamed_build_matches_one_shot(kind):
    """Blocked encode + sampled fit are bit-identical to the one-shot
    build at equal sample settings, for any block size — including
    block=1, non-dividing blocks, and blocks larger than the corpus."""
    for sample in (0, 64):
        ref = _one_shot(kind, sample)
        for block in (1, 3, 64, 100, _N, 5 * _N):
            art, stats = _build(_cfg(kind, train_sample=sample,
                                     encode_block=block))
            assert sorted(art) == sorted(ref)
            for name in ref:
                np.testing.assert_array_equal(
                    np.asarray(art[name]), np.asarray(ref[name]),
                    err_msg=f"{kind}/{name} block={block} sample={sample}")
            assert stats.blocks == -(-_N // min(block, _N))
            assert stats.sample_rows == (sample or _N)


@settings(deadline=None, max_examples=8)
@given(st.integers(min_value=1, max_value=_N + 50),
       st.sampled_from([0, 97]))
def test_streamed_build_parity_property(block, sample):
    ref = _one_shot("ivf_pq", sample)
    art, _ = _build(_cfg("ivf_pq", train_sample=sample,
                         encode_block=block))
    for name in ref:
        np.testing.assert_array_equal(np.asarray(art[name]),
                                      np.asarray(ref[name]))


def test_build_stats_peak_is_block_bounded():
    vecs = _vectors(8192, 16, seed=1)
    cfg = _cfg("ivf_pq", nlist=16, train_sample=1024, encode_block=512)
    art, stats = build_ivf_artifact(jax.random.PRNGKey(0), vecs, cfg)
    assert stats.blocks == 16 and stats.block_rows == 512
    assert stats.sample_rows == 1024
    assert stats.peak_device_ok
    # staged bytes stay below the corpus — the point of streaming
    assert stats.peak_device_bytes < vecs.nbytes
    # the bound is corpus-independent: 4x the rows, same bound
    vecs4 = _vectors(32768, 16, seed=2)
    _, stats4 = build_ivf_artifact(jax.random.PRNGKey(0), vecs4, cfg)
    assert stats4.device_bound_bytes == stats.device_bound_bytes
    # list tables come back host-resident; placement is the caller's
    assert isinstance(art["list_codes"], np.ndarray)
    assert isinstance(art["list_ids"], np.ndarray)


def test_build_rejects_undersized_corpus_or_sample():
    vecs = _vectors(32)
    with pytest.raises(ValueError, match="nlist"):
        build_ivf_artifact(jax.random.PRNGKey(0), vecs,
                           _cfg("ivf_pq", nlist=64, nprobe=8))
    with pytest.raises(ValueError, match="train_sample"):
        build_ivf_artifact(jax.random.PRNGKey(0), vecs,
                           _cfg("ivf_pq", nlist=16, train_sample=8))


# ------------------------------------------------- bounded list layout

def test_bounded_layout_bytes_on_skewed_assignment():
    """On a Zipf-skewed assignment the quantile-capped chained layout
    stays within a constant factor of the ideal bytes; the old
    pad-to-longest layout blows up by the max/mean list ratio."""
    rng = np.random.default_rng(0)
    nlist, n, D = 64, 20_000, 8
    w = 1.0 / np.arange(1, nlist + 1) ** 1.1
    assign = rng.choice(nlist, size=n, p=w / w.sum()).astype(np.int64)
    codes = rng.integers(0, 256, size=(n, D)).astype(np.uint8)
    lay = bounded_list_layout(assign, codes, nlist, 0.9)
    counts = np.bincount(assign, minlength=nlist)
    ideal = n * D
    padded = nlist * int(counts.max()) * D     # the old layout's bytes
    assert padded >= 8 * ideal                 # skew really blows it up
    assert lay["list_codes"].nbytes <= 4 * ideal
    assert lay["list_codes"].nbytes * 2 < padded
    # the layout is a faithful inverse: every corpus row appears exactly
    # once, carrying its own codes
    ids = lay["list_ids"]
    valid = ids != INVALID_ID
    np.testing.assert_array_equal(np.sort(ids[valid]), np.arange(n))
    np.testing.assert_array_equal(lay["list_codes"][valid],
                                  codes[ids[valid]])
    # each base list's chain holds exactly its members
    chain = lay["list_chain"]
    for l in range(nlist):
        rows = chain[l][chain[l] >= 0]
        members = ids[rows][ids[rows] != INVALID_ID]
        assert members.size == counts[l]
        assert (assign[members] == l).all()
    # spill padding keeps the row-sharding divisibility invariant
    assert lay["list_codes"].shape[0] % nlist == 0


def test_quantile_one_reproduces_pad_to_max():
    rng = np.random.default_rng(1)
    nlist, n, D = 8, 500, 4
    assign = rng.integers(0, nlist, n)
    codes = rng.integers(0, 256, size=(n, D)).astype(np.uint8)
    lay = bounded_list_layout(assign, codes, nlist, 1.0)
    counts = np.bincount(assign, minlength=nlist)
    assert lay["list_chain"].shape == (nlist, 1)
    assert lay["list_codes"].shape == (nlist, counts.max(), D)
    np.testing.assert_array_equal(lay["list_chain"][:, 0],
                                  np.arange(nlist))


def test_spilled_layout_search_matches_padded_layout():
    """Tight caps force spill chains; search results must be EXACTLY
    the pad-to-max layout's (same scores, same ids, same order)."""
    vecs = jnp.asarray(_vectors(2048, 16, seed=3))
    q = jnp.asarray(np.random.default_rng(9).normal(
        size=(6, 16)).astype(np.float32))
    outs = {}
    for quant in (1.0, 0.5):
        cfg = _cfg("ivf_pq", nlist=16, nprobe=16,
                   list_cap_quantile=quant)
        idx = get_index(cfg)
        art = idx.build(jax.random.PRNGKey(5), vecs)
        if quant < 1.0:
            assert art["list_chain"].shape[1] > 1   # chains really spill
        outs[quant] = idx.search(art, q, 50)
    np.testing.assert_array_equal(np.asarray(outs[1.0][0]),
                                  np.asarray(outs[0.5][0]))
    np.testing.assert_array_equal(np.asarray(outs[1.0][1]),
                                  np.asarray(outs[0.5][1]))


# ---------------------------------------------------- host-staged serving

def test_host_staged_search_matches_device_search():
    vecs = _vectors(1024, 16, seed=4)
    cfg = _cfg("ivf_pq", nlist=16, nprobe=4)
    idx = get_index(cfg)
    art_host, _ = build_ivf_artifact(jax.random.PRNGKey(1), vecs, cfg)
    art_dev = {name: jnp.asarray(v) for name, v in art_host.items()}
    q = jnp.asarray(np.random.default_rng(2).normal(
        size=(5, 16)).astype(np.float32))
    ref_s, ref_i = idx.search(art_dev, q, 20)
    s, i = idx.search_host_staged(art_host, q, 20)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))
    assert idx.staged_bytes > 0


def test_host_staged_engine_bit_identical_and_bounded_upload():
    vecs = _vectors(8192, 16, seed=5)
    cfg = _cfg("ivf_pq", nlist=256, nprobe=2, host_staged=True)
    idx = get_index(cfg)
    art_host, _ = build_ivf_artifact(jax.random.PRNGKey(1), vecs, cfg)
    eng = RetrievalEngine(idx, art_host, k=20, block_q=4)
    assert eng.host_staged
    assert isinstance(eng.artifact["list_codes"], np.ndarray)
    ref_idx = get_index(dataclasses.replace(cfg, host_staged=False))
    ref_eng = RetrievalEngine(
        ref_idx, {name: jnp.asarray(v) for name, v in art_host.items()},
        k=20, block_q=4)
    rng = np.random.default_rng(2)
    reqs = [rng.normal(size=(b, 16)).astype(np.float32) for b in (5, 3)]
    hs = [eng.submit(r) for r in reqs]
    rhs = [ref_eng.submit(r) for r in reqs]
    outs, ref_outs = eng.flush(), ref_eng.flush()
    for h, rh in zip(hs, rhs):
        np.testing.assert_array_equal(np.asarray(outs[h][0]),
                                      np.asarray(ref_outs[rh][0]))
        np.testing.assert_array_equal(np.asarray(outs[h][1]),
                                      np.asarray(ref_outs[rh][1]))
    # the flush staged only probed lists — far below the full tables
    table_mb = (art_host["list_codes"].nbytes
                + art_host["list_ids"].nbytes) / 1e6
    assert 0 < eng.staged_mbytes < table_mb


def test_host_staged_engine_rejects_flat_and_mesh():
    vecs = jnp.asarray(_vectors(256, 16, seed=6))
    fidx = get_index(_cfg("flat_pq"))
    fart = fidx.build(jax.random.PRNGKey(0), vecs)
    with pytest.raises(ValueError, match="host-staged"):
        RetrievalEngine(fidx, fart, k=10, host_staged=True)
    iidx = get_index(_cfg("ivf_pq", host_staged=True))
    iart = iidx.build(jax.random.PRNGKey(0), vecs)
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="single-device"):
        RetrievalEngine(iidx, iart, k=10, mesh=mesh)


# --------------------------------------------------------- 1M-row recall

@pytest.mark.slow
def test_one_million_row_recall_and_peak():
    """End-to-end scale check (the bench gate's settings): streamed 1M
    build with bounded peak device bytes, recall@100 >= 0.95 at the
    largest swept nprobe."""
    from repro.data.synthetic import pq_clustered_corpus
    n = 1_000_000
    vecs, q = pq_clustered_corpus(n=n, n_clusters=1024,
                                  cluster_zipf_a=1.3)
    nlist = suggest_nlist(n, 128)
    cfg = IndexConfig(kind="ivf_pq", num_subspaces=8, num_centroids=128,
                      iters=10, coarse_iters=10, nlist=nlist, nprobe=128,
                      train_sample=131_072, encode_block=131_072,
                      list_cap_quantile=0.9)
    art, stats = build_ivf_artifact(jax.random.PRNGKey(42), vecs, cfg)
    assert stats.peak_device_ok
    assert stats.peak_device_bytes < vecs.nbytes // 2
    idx = get_index(cfg)
    art_dev = {name: jnp.asarray(v) for name, v in art.items()}
    _, ids = jax.jit(lambda a, qq: idx.search(a, qq, 100))(
        art_dev, jnp.asarray(q))
    ids = np.asarray(ids)
    exact = np.argsort(-(q @ vecs.T), axis=1)[:, :100]
    recall = float(np.mean([np.isin(ids[b], exact[b]).mean()
                            for b in range(q.shape[0])]))
    assert recall >= 0.95, f"recall@100 {recall:.3f} at nprobe=128"
