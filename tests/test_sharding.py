"""Multi-device sharding tests.

These need >1 XLA device, so they run in a subprocess with
``--xla_force_host_platform_device_count=8`` (the main test process
keeps the default single device — smoke tests must see 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest


_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=520)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_debug_mesh_and_param_specs():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_debug_mesh
        from repro.sharding import rules
        from repro.configs.registry import get_arch
        from repro.models import lm

        mesh = make_debug_mesh(2, 4)
        assert mesh.shape == {"data": 2, "model": 4}
        _, cfg = get_arch("stablelm-3b", smoke=True)
        params = lm.model_init(jax.random.PRNGKey(0), cfg)
        spec = rules.spec_tree(params, rules.lm_param_rules(cfg, mesh))
        # vocab rows sharded over model
        assert tuple(spec["embed"]["emb"])[-2:] == ("model", None)
        # lm head columns over model
        assert tuple(spec["lm_head"])[-1] == "model"
        print("OK")
    """)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """One train step under a (2, 4) mesh must match the unsharded step
    bit-for-bit (up to float tolerance) — the SPMD-correctness test."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_debug_mesh
        from repro.sharding import rules
        from repro.configs.registry import get_arch
        from repro.models import lm
        from repro.train import optimizer as opt_lib
        from repro.train.optimizer import TrainState

        _, cfg = get_arch("stablelm-3b", smoke=True)
        ocfg = opt_lib.OptimizerConfig(kind="adamw", lr=1e-3)
        params = lm.model_init(jax.random.PRNGKey(0), cfg)
        state = TrainState.create(ocfg, params)
        step = opt_lib.make_step_fn(
            ocfg, functools.partial(lm.loss_fn, cfg=cfg))
        k = jax.random.PRNGKey(1)
        toks = jax.random.randint(k, (8, 33), 0, cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        ref_state, ref_metrics = jax.jit(step)(state, batch)

        mesh = make_debug_mesh(2, 4)
        p_spec, o_spec = rules.lm_state_specs(
            cfg, mesh, state.params, state.opt_state)
        named = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s if isinstance(s, P) else P()),
            t, is_leaf=lambda x: isinstance(x, P) or x is None)
        st_shard = TrainState(named(p_spec), named(o_spec))
        b_shard = named({"tokens": P("data", None), "labels": P("data", None)})
        with mesh:
            sh_state, sh_metrics = jax.jit(
                step, in_shardings=(st_shard, b_shard),
                out_shardings=(st_shard, None))(state, batch)

        np.testing.assert_allclose(
            float(ref_metrics["loss"]), float(sh_metrics["loss"]),
            rtol=1e-4)
        for a, b in zip(jax.tree.leaves(ref_state.params),
                        jax.tree.leaves(sh_state.params)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=2e-3, atol=2e-3)
        print("OK")
    """)


def test_sharded_mgqe_embedding_lookup_matches():
    """Row-sharded MGQE table lookup == replicated lookup."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_debug_mesh
        from repro.core import Embedding, EmbeddingConfig

        cfg = EmbeddingConfig(vocab_size=128, dim=16, kind="mgqe",
                              num_subspaces=4, num_centroids=8,
                              tier_boundaries=(16,),
                              tier_num_centroids=(8, 4))
        emb = Embedding(cfg)
        p = emb.init(jax.random.PRNGKey(0))
        ids = jnp.arange(64)
        ref, _ = emb.apply(p, ids)

        mesh = make_debug_mesh(2, 4)
        shard = {"emb": NamedSharding(mesh, P("model", None)),
                 "centroids": NamedSharding(mesh, P())}
        p_sharded = jax.device_put(p, shard)
        with mesh:
            out, _ = jax.jit(emb.apply)(p_sharded, ids)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   atol=1e-5)
        print("OK")
    """)


def test_sharded_quantized_gather_matches_serve_all_variants():
    """Row-sharded codes + replicated codebooks on Mesh(data=2, model=2)
    must serve identically to the single-device fused decode, for DPQ,
    all three MGQE variants, and the rq and mpe plugins
    (DESIGN.md §6/§7)."""
    _run("""
        import warnings; warnings.filterwarnings('ignore')
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import Embedding, EmbeddingConfig
        from repro.sharding.rules import shard_quantized_artifact

        variants = [
            dict(kind="dpq", num_subspaces=4, num_centroids=8),
            dict(kind="mgqe", num_subspaces=4, num_centroids=8,
                 tier_boundaries=(16,), tier_num_centroids=(8, 4)),
            dict(kind="mgqe", mgqe_variant="private_k", num_subspaces=4,
                 num_centroids=8, tier_boundaries=(16,),
                 tier_num_centroids=(8, 4)),
            dict(kind="mgqe", mgqe_variant="private_d", num_subspaces=4,
                 num_centroids=8, tier_boundaries=(16,),
                 tier_num_subspaces=(4, 2)),
            dict(kind="rq", num_levels=3, num_centroids=8),
            dict(kind="mpe", num_subspaces=8, tier_boundaries=(16, 48),
                 tier_bits=(8, 4, 2)),
        ]
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        assert dict(mesh.shape) == {"data": 2, "model": 2}
        for kw in variants:
            cfg = EmbeddingConfig(vocab_size=128, dim=16, **kw)
            emb = Embedding(cfg)
            art = emb.export(emb.init(jax.random.PRNGKey(0)))
            ids = jax.random.randint(jax.random.PRNGKey(1), (8, 8), 0, 128)
            ref = emb.serve(art, ids)

            scfg = dataclasses.replace(cfg, sharded_codes=True)
            semb = Embedding(scfg)
            art_s = shard_quantized_artifact(art, scfg, mesh)
            with mesh:
                out = jax.jit(semb.serve)(art_s, ids)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=1e-5)
            # no ambient mesh -> single-device fallback, same result
            np.testing.assert_allclose(np.asarray(semb.serve(art, ids)),
                                       np.asarray(ref), atol=1e-5)
        print("OK")
    """)


def test_sharded_rq_single_pass_decode_bit_identical():
    """The rq scheme's single-pass ``rq_decode_stages`` serve path
    under Mesh(data=2, model=2) must be BIT-identical (array_equal,
    not a tolerance) to the single-device fused decode — the per-shard
    decode routes through the same dispatched op, summed via psum of
    disjoint shard partials, so not even the reduction order differs.
    Covers odd/ragged batch shapes and both kernel backends."""
    _run("""
        import warnings; warnings.filterwarnings('ignore')
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import Embedding, EmbeddingConfig
        from repro.sharding.rules import shard_quantized_artifact

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        for backend in ("xla", "interpret"):
            cfg = EmbeddingConfig(vocab_size=128, dim=16, kind="rq",
                                  num_levels=3, num_centroids=8,
                                  decode_block_b=32,
                                  kernel_backend=backend)
            emb = Embedding(cfg)
            art = emb.export(emb.init(jax.random.PRNGKey(0)))
            assert art["codes"].dtype == jnp.uint8
            scfg = dataclasses.replace(cfg, sharded_codes=True)
            semb = Embedding(scfg)
            art_s = shard_quantized_artifact(art, scfg, mesh)
            for shape in [(8, 8), (7,), (1,), (3, 5)]:
                ids = jax.random.randint(
                    jax.random.PRNGKey(sum(shape)), shape, 0, 128)
                ref = emb.serve(art, ids)
                assert ref.shape == shape + (16,)
                with mesh:
                    out = jax.jit(semb.serve)(art_s, ids)
                np.testing.assert_array_equal(np.asarray(out),
                                              np.asarray(ref))
        print("OK")
    """)


def test_sharded_mpe_packed_decode_bit_identical():
    """The mpe scheme's fused unpack-and-decode serve path under
    Mesh(data=2, model=2) must be BIT-identical to the single-device
    decode: each shard gathers PACKED rows from its local (n/2, W_i)
    code shards and unpacks inside the dispatched kernel, with tier
    blending keyed on the all-gathered GLOBAL ids.  Covers odd/ragged
    batch shapes and both kernel backends (DESIGN.md §13)."""
    _run("""
        import warnings; warnings.filterwarnings('ignore')
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import Embedding, EmbeddingConfig
        from repro.sharding.rules import shard_quantized_artifact

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        for backend in ("xla", "interpret"):
            cfg = EmbeddingConfig(vocab_size=128, dim=16, kind="mpe",
                                  num_subspaces=8,
                                  tier_boundaries=(16, 48),
                                  tier_bits=(8, 4, 2),
                                  decode_block_b=32,
                                  kernel_backend=backend)
            emb = Embedding(cfg)
            art = emb.export(emb.init(jax.random.PRNGKey(0)))
            # stored packed: W_i = ceil(D * bits / 8) bytes per row
            assert [c.shape[1] for c in art["codes"]] == [8, 4, 2]
            assert all(c.dtype == jnp.uint8 for c in art["codes"])
            scfg = dataclasses.replace(cfg, sharded_codes=True)
            semb = Embedding(scfg)
            art_s = shard_quantized_artifact(art, scfg, mesh)
            for shape in [(8, 8), (7,), (1,), (3, 5)]:
                ids = jax.random.randint(
                    jax.random.PRNGKey(sum(shape)), shape, 0, 128)
                ref = emb.serve(art, ids)
                assert ref.shape == shape + (16,)
                with mesh:
                    out = jax.jit(semb.serve)(art_s, ids)
                np.testing.assert_array_equal(np.asarray(out),
                                              np.asarray(ref))
        print("OK")
    """)


def test_sharded_engine_matches_single_device():
    """ServingEngine(mesh=...) — per-shard device-resident artifact,
    flushes padded to block_b x data shards — returns the same rows as
    the single-device engine."""
    _run("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import Embedding, EmbeddingConfig
        from repro.launch.engine import ServingEngine

        cfg = EmbeddingConfig(vocab_size=256, dim=16, kind="mgqe",
                              num_subspaces=4, num_centroids=8,
                              tier_boundaries=(32,),
                              tier_num_centroids=(8, 4),
                              decode_block_b=32)
        emb = Embedding(cfg)
        art = emb.export(emb.init(jax.random.PRNGKey(0)))
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        eng = ServingEngine(emb, art, mesh=mesh)
        ref_eng = ServingEngine(emb, art)
        assert eng.pad_multiple == 32 * 2 and eng.data_shards == 2

        rng = np.random.default_rng(0)
        reqs = [rng.integers(0, 256, n) for n in (5, 40, 1, 17)]
        handles = [eng.submit(r) for r in reqs]
        ref_handles = [ref_eng.submit(r) for r in reqs]
        outs, ref_outs = eng.flush(), ref_eng.flush()
        for h, rh in zip(handles, ref_handles):
            np.testing.assert_allclose(np.asarray(outs[h]),
                                       np.asarray(ref_outs[rh]), atol=1e-5)
        st = eng.stats()
        assert st.padded_lookups % eng.pad_multiple == 0
        print("OK")
    """)


def test_sharded_engine_hot_cache_bit_identical():
    """Hot-row cache under Mesh(data=2, model=2): the hot block is
    re-decoded through the engine's own sharded serve and replicated,
    so cached lookups are BIT-identical to the uncached sharded decode
    — for every quantized scheme (DESIGN.md §9)."""
    _run("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import Embedding, EmbeddingConfig
        from repro.launch.engine import ServingEngine

        variants = [
            dict(kind="dpq", num_subspaces=4, num_centroids=8),
            dict(kind="mgqe", mgqe_variant="private_k", num_subspaces=4,
                 num_centroids=8, tier_boundaries=(16,),
                 tier_num_centroids=(8, 4)),
            dict(kind="mgqe", mgqe_variant="private_d", num_subspaces=4,
                 num_centroids=8, tier_boundaries=(16,),
                 tier_num_subspaces=(4, 2)),
            dict(kind="rq", num_levels=3, num_centroids=8),
            dict(kind="mpe", num_subspaces=8, tier_boundaries=(16, 48),
                 tier_bits=(8, 4, 2)),
        ]
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        for kw in variants:
            cfg = EmbeddingConfig(vocab_size=128, dim=16,
                                  decode_block_b=32, hot_rows=32, **kw)
            emb = Embedding(cfg)
            art = emb.export(emb.init(jax.random.PRNGKey(0)))
            assert art["hot"].shape == (32, 16)
            hot_eng = ServingEngine(emb, art, mesh=mesh)
            assert hot_eng.hot_rows == 32
            cold_eng = ServingEngine(emb, art, mesh=mesh, hot_rows=0)
            # mixed hot/cold batch incl. duplicates + boundary ids
            ids = np.r_[np.arange(8), rng.integers(0, 128, 20), 31, 32]
            out = hot_eng.lookup(ids)
            ref = cold_eng.lookup(ids)
            np.testing.assert_array_equal(np.asarray(out),
                                          np.asarray(ref))
            st = hot_eng.stats()
            assert st.hot_hits > 0 and st.decoded_lookups > 0
            # fully-cached flush: zero fused decode on the whole mesh
            before = hot_eng.stats().decoded_lookups
            out2 = hot_eng.lookup(np.arange(16))
            np.testing.assert_array_equal(
                np.asarray(out2), np.asarray(cold_eng.lookup(np.arange(16))))
            assert hot_eng.stats().decoded_lookups == before
            # adaptive refresh keeps bit-parity under the mesh too
            hot_eng.refresh_hot_rows(np.arange(64, 96))
            np.testing.assert_array_equal(
                np.asarray(hot_eng.lookup(ids)), np.asarray(ref))
        print("OK")
    """)


def test_sharded_retrieval_topk_bit_identical_all_kinds():
    """Row-sharded corpus top-k on Mesh(data=2, model=2) must equal the
    single-device batched search EXACTLY (bit-identical scores AND
    ids, not a tolerance) for every registered index kind — the
    deterministic (score, tiebreak) merge contract of DESIGN.md §8 —
    including through the RetrievalEngine and the k > candidates
    padding edge."""
    _run("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.engine import RetrievalEngine
        from repro.retrieval import (IndexConfig, get_index,
                                     index_class, registered_index_kinds,
                                     sharded_topk)
        from repro.sharding.rules import shard_retrieval_artifact

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        vecs = jax.random.normal(jax.random.PRNGKey(0), (2048, 16))
        q = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
        for kind in registered_index_kinds():
            index = get_index(index_class(kind).probe_config())
            art = index.build(jax.random.PRNGKey(2), vecs)
            ref_s, ref_i = index.search(art, q, 50)
            art_s = shard_retrieval_artifact(art, index, mesh)
            with mesh:
                out_s, out_i = jax.jit(
                    lambda a, qq: sharded_topk(index, a, qq, 50))(
                        art_s, q)
            np.testing.assert_array_equal(np.asarray(out_s),
                                          np.asarray(ref_s))
            np.testing.assert_array_equal(np.asarray(out_i),
                                          np.asarray(ref_i))
            # no ambient mesh -> single-device fallback, same result
            fs, fi = sharded_topk(index, art, q, 50)
            np.testing.assert_array_equal(np.asarray(fs),
                                          np.asarray(ref_s))

            # through the engine: mesh vs single-device, odd batches
            eng = RetrievalEngine(index, art, k=13, block_q=4,
                                  mesh=mesh)
            ref_eng = RetrievalEngine(index, art, k=13, block_q=4)
            assert eng.pad_multiple == 4 * 2 and eng.data_shards == 2
            rng = np.random.default_rng(0)
            reqs = [rng.normal(size=(n, 16)).astype(np.float32)
                    for n in (5, 1, 3)]
            hs = [eng.submit(r) for r in reqs]
            ref_hs = [ref_eng.submit(r) for r in reqs]
            outs, ref_outs = eng.flush(), ref_eng.flush()
            for h, rh in zip(hs, ref_hs):
                np.testing.assert_array_equal(
                    np.asarray(outs[h][1]), np.asarray(ref_outs[rh][1]))
                np.testing.assert_array_equal(
                    np.asarray(outs[h][0]), np.asarray(ref_outs[rh][0]))

        # k > valid candidates: pads (-inf, INVALID_ID) identically
        index = get_index(IndexConfig(kind="ivf_pq", num_subspaces=4,
                                      num_centroids=16, iters=3,
                                      nlist=8, nprobe=2))
        art = index.build(jax.random.PRNGKey(2), vecs[:64])
        ref = index.search(art, q, 40)
        art_s = shard_retrieval_artifact(art, index, mesh)
        with mesh:
            out = jax.jit(lambda a, qq: sharded_topk(
                index, a, qq, 40))(art_s, q)
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      np.asarray(ref[0]))
        np.testing.assert_array_equal(np.asarray(out[1]),
                                      np.asarray(ref[1]))

        # spilled chained layout (DESIGN.md §12): a skewed corpus under
        # a tight list cap forces multi-chunk chains; the sharded merge
        # must stay bit-identical with spill lists scattered over shards
        cents = np.asarray(jax.random.normal(jax.random.PRNGKey(5),
                                             (8, 16)))
        g = np.repeat(np.arange(8),
                      [1200, 400, 200, 100, 60, 40, 28, 20])
        vs = jnp.asarray(
            cents[g] + 0.05 * np.random.default_rng(3).normal(
                size=(2048, 16)))
        index = get_index(IndexConfig(kind="ivf_pq", num_subspaces=4,
                                      num_centroids=16, iters=3,
                                      nlist=8, nprobe=8,
                                      list_cap_quantile=0.5))
        art = index.build(jax.random.PRNGKey(2), vs)
        assert art["list_chain"].shape[1] > 1    # chains really spill
        ref = index.search(art, q, 50)
        art_s = shard_retrieval_artifact(art, index, mesh)
        with mesh:
            out = jax.jit(lambda a, qq: sharded_topk(
                index, a, qq, 50))(art_s, q)
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      np.asarray(ref[0]))
        np.testing.assert_array_equal(np.asarray(out[1]),
                                      np.asarray(ref[1]))
        print("OK")
    """)


def test_sharded_rows_train_lookup_private_variants():
    """Training-path row gather (sharded_rows) parity for the private
    MGQE variants — the full table row-sharded over model."""
    _run("""
        import warnings; warnings.filterwarnings('ignore')
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import Embedding, EmbeddingConfig

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for variant, extra in [
                ("private_k", dict(tier_num_centroids=(8, 4))),
                ("private_d", dict(tier_num_subspaces=(4, 2)))]:
            cfg = EmbeddingConfig(vocab_size=128, dim=16, kind="mgqe",
                                  mgqe_variant=variant, num_subspaces=4,
                                  num_centroids=8, tier_boundaries=(16,),
                                  **extra)
            emb = Embedding(cfg)
            p = emb.init(jax.random.PRNGKey(0))
            ids = jax.random.randint(jax.random.PRNGKey(1), (64,), 0, 128)
            ref, ref_aux = emb.apply(p, ids)

            semb = Embedding(dataclasses.replace(cfg, sharded_rows=True))
            shard = {"emb": NamedSharding(mesh, P("model", None)),
                     "centroids": [NamedSharding(mesh, P())] * 2}
            p_sharded = jax.device_put(p, shard)
            with mesh:
                out, aux = jax.jit(semb.apply)(p_sharded, ids)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=1e-5)
            np.testing.assert_allclose(float(aux), float(ref_aux),
                                       rtol=1e-5)
        print("OK")
    """)


def test_multipod_mesh_shape():
    _run("""
        import jax
        import numpy as np
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(2, 2, multi_pod=True)
        assert mesh.shape == {"pod": 2, "data": 2, "model": 2}
        print("OK")
    """)


def test_moe_sharded_dispatch_matches_reference():
    """moe_ffn_sharded (both strategies) == moe_ffn at high capacity."""
    _run("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, jax.numpy as jnp
        from repro.nn import moe as moe_lib
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        for e in (8, 3):   # 8 -> expert strategy; 3 -> ffn strategy
            p = moe_lib.moe_init(key, d_model=32, d_ff=64, num_experts=e)
            ref, _ = moe_lib.moe_ffn(p, x, top_k=2, capacity_factor=64.0)
            with mesh:
                out, _ = jax.jit(lambda p, x: moe_lib.moe_ffn_sharded(
                    p, x, top_k=2, capacity_factor=64.0))(p, x)
            err = float(jnp.max(jnp.abs(out - ref)))
            assert err < 1e-5, (e, err)
        print("OK")
    """)


def test_sharded_row_gather_matches_take():
    _run("""
        import warnings; warnings.filterwarnings('ignore')
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding.gather import row_gather
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        table = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
        ids = jax.random.randint(jax.random.PRNGKey(1), (8,), 0, 64)
        ref = jnp.take(table, ids, axis=0)
        with mesh:
            out = jax.jit(lambda t, i: row_gather(t, i, sharded=True))(
                table, ids)
            g_s = jax.jit(jax.grad(lambda t: jnp.sum(
                row_gather(t, ids, sharded=True) ** 2)))(table)
        g_r = jax.grad(lambda t: jnp.sum(
            jnp.take(t, ids, axis=0) ** 2))(table)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref))
        np.testing.assert_allclose(np.asarray(g_s), np.asarray(g_r))
        print("OK")
    """)


def test_elastic_reshard_roundtrip():
    """Checkpoint saved under one mesh restores under a different DP
    width (elastic scaling)."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_debug_mesh
        from repro.train import checkpoint as ck
        from repro.train import optimizer as opt_lib
        from repro.train.optimizer import TrainState

        params = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
        ocfg = opt_lib.OptimizerConfig()
        state = TrainState.create(ocfg, params)

        mesh1 = make_debug_mesh(4, 2)
        sh1 = NamedSharding(mesh1, P("data", None))
        state1 = jax.tree.map(
            lambda x: jax.device_put(x, sh1) if x.ndim == 2 else x, state)
        with tempfile.TemporaryDirectory() as d:
            ck.save(d, 3, state1, keep=1)
            # restore onto a *different* mesh layout
            mesh2 = make_debug_mesh(2, 4)
            sh2 = NamedSharding(mesh2, P("data", None))
            template = jax.tree.map(lambda x: x, state)
            restored, step = ck.restore_latest(d, template)
            assert step == 3
            r2 = jax.tree.map(
                lambda x: jax.device_put(x, sh2) if x.ndim == 2 else x,
                restored)
            np.testing.assert_array_equal(
                np.asarray(r2.params["w"]), np.asarray(params["w"]))
        print("OK")
    """)
