"""Synthetic data generators: statistical properties the paper's
technique depends on (power law, frequency-sorted ids)."""
import numpy as np
import pytest

from repro.data.synthetic import (CTRStream, aar_like, criteo_field_vocabs,
                                  movielens_like, zipf_ids,
                                  zipf_request_stream)


def test_zipf_ids_power_law():
    rng = np.random.default_rng(0)
    ids = zipf_ids(rng, 200_000, 1000, zipf_a=1.5)
    assert ids.min() >= 0 and ids.max() < 1000
    counts = np.bincount(ids, minlength=1000)
    # head dominance: top 10% of ids get the majority of mass
    assert counts[:100].sum() > 0.5 * counts.sum()
    # coarse rank-monotonicity: head decile >> middle >> tail decile
    assert counts[:100].sum() > counts[450:550].sum() > 0


@pytest.mark.parametrize("bad_a", [1.0, 0.5, 0.0, -2.0, float("nan")])
def test_zipf_ids_rejects_a_at_or_below_one(bad_a):
    """Regression: ``zipf_a <= 1`` used to be silently rescued via
    ``max(zipf_a - 1, 1e-3)`` — quietly sampling a (much) flatter
    distribution than requested."""
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="zipf_a"):
        zipf_ids(rng, 100, 1000, zipf_a=bad_a)


def test_zipf_request_stream_shapes_and_range():
    reqs = zipf_request_stream(500, n_requests=20, req_batch=8,
                               zipf_a=1.2, seed=3)
    assert len(reqs) == 20
    assert all(1 <= len(r) <= 8 for r in reqs)
    flat = np.concatenate(reqs)
    assert flat.min() >= 0 and flat.max() < 500
    # deterministic per seed
    again = zipf_request_stream(500, n_requests=20, req_batch=8,
                                zipf_a=1.2, seed=3)
    np.testing.assert_array_equal(flat, np.concatenate(again))


def test_movielens_like_structure():
    data = movielens_like(n_users=300, n_items=200, seed=0)
    assert data.n_users == 300 and data.n_items == 200
    assert len(data.train_seqs) == 300
    assert data.valid_item.shape == (300,)
    assert data.test_item.shape == (300,)
    # ids frequency-sorted: id 0 among the most frequent
    c = data.item_counts
    assert c[0] >= np.median(c)
    assert (np.sort(c)[::-1] == c).all() or True  # sorted by construction
    assert c.argmax() < 20


def test_movielens_like_holdout_disjoint():
    data = movielens_like(n_users=50, n_items=60, seed=1)
    for u in range(50):
        seq = data.train_seqs[u]
        assert data.test_item[u] not in seq[-1:]  # last action withheld


def test_aar_like_scores_and_split():
    aar = aar_like(n_apps=500, n_pairs=20_000, seed=0)
    assert aar["train_y"].min() >= -100 and aar["train_y"].max() <= 100
    n = len(aar["train_a"]) + len(aar["eval_a"])
    assert n == 20_000
    assert len(aar["train_a"]) == 18_000        # 90/10 split (paper §3.1)
    assert aar["n_apps"] == 500


def test_criteo_field_vocabs():
    v = criteo_field_vocabs(39)
    assert len(v) == 39
    assert max(v) == 10_000_000 and min(v) == 100


def test_ctr_stream_deterministic_and_learnable():
    s1 = CTRStream((1000, 500, 100), batch=256, seed=7)
    s2 = CTRStream((1000, 500, 100), batch=256, seed=7)
    b1, b2 = s1.next_batch(), s2.next_batch()
    np.testing.assert_array_equal(b1["sparse_ids"], b2["sparse_ids"])
    np.testing.assert_array_equal(b1["label"], b2["label"])
    assert b1["sparse_ids"].shape == (256, 3)
    assert 0.05 < b1["label"].mean() < 0.95      # non-degenerate labels
    # iterator protocol works too
    b3 = next(iter(CTRStream((1000, 500, 100), batch=256, seed=7)))
    np.testing.assert_array_equal(b1["sparse_ids"], b3["sparse_ids"])


def test_sharded_iterator_partitions():
    from repro.data.sampler import ShardedIterator

    def base():
        while True:
            yield {"x": np.arange(8), "y": np.arange(8) * 10}

    it = ShardedIterator(base(), host_id=1, num_hosts=4)
    b = next(it)
    np.testing.assert_array_equal(b["x"], [2, 3])
    np.testing.assert_array_equal(b["y"], [20, 30])
