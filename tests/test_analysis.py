"""repro.analysis: the repo-invariant lint engine (DESIGN.md §15).

Coverage in four layers: every rule gets a paired bad fixture (fires)
and good fixture (stays quiet) driven through ``analyze_source`` with
virtual repo paths (rules are path-scoped); the suppression and
baseline machinery round-trips; the registry contract (duplicate ids,
kind-name superset of the live scheme/index registries) is pinned; and
the self-check — the linter parses every committed src/tools file and
reports ZERO diagnostics, the empty-committed-baseline invariant the
CI ``analysis`` job gates on.
"""
import json
import os
import textwrap

import pytest

from repro.analysis import (analyze_paths, analyze_source,
                            filter_baseline, lint_exclusions,
                            load_baseline, registered_rule_ids,
                            rule_class, write_baseline)
from repro.analysis.engine import PARSE_ERROR_RULE, Rule, register_rule
from repro.analysis.rules import SCHEME_KIND_NAMES
from repro.analysis.scope import find_repo_root

REPO = find_repo_root(os.path.dirname(__file__))


def _ids(path, src, rule=None):
    """Rule ids fired on dedented ``src`` under virtual ``path``."""
    diags = analyze_source(path, textwrap.dedent(src),
                           rule_ids=[rule] if rule else None)
    return [d.rule_id for d in diags]


# ----------------------------------------------------------------------
# rule fixtures: each bad snippet fires, its good twin does not
# ----------------------------------------------------------------------

def test_import_time_jax_fires_on_module_constant():
    bad = """\
        import jax.numpy as jnp
        SCALE = jnp.ones((4,))
    """
    assert _ids("src/repro/foo.py", bad) == ["import-time-jax"]


def test_import_time_jax_fires_on_eager_default_arg():
    bad = """\
        import jax.numpy as jnp
        def f(x=jnp.zeros(3)):
            return x
    """
    assert _ids("src/repro/foo.py", bad) == ["import-time-jax"]


def test_import_time_jax_quiet_on_lazy_and_meta_calls():
    good = """\
        import jax
        import jax.numpy as jnp
        MAX = jnp.iinfo(jnp.int32).max        # dtype meta: no backend
        def build():
            return jnp.ones((4,))             # runs at call time
        step = jax.jit(build)                 # wrapping is lazy
    """
    assert _ids("src/repro/foo.py", good) == []


def test_kind_dispatch_fires_outside_registries():
    bad = """\
        def f(cfg):
            if cfg.kind == "dpq":
                return 1
    """
    assert _ids("src/repro/launch/foo.py", bad) == ["kind-dispatch"]


def test_kind_dispatch_fires_on_membership():
    bad = """\
        def f(cfg):
            return cfg.kind in ("mgqe", "rq")
    """
    assert _ids("src/repro/core/foo.py", bad) == ["kind-dispatch"]


def test_kind_dispatch_quiet_in_registry_dirs_and_foreign_kinds():
    text = """\
        def f(cfg):
            if cfg.kind == "dpq":
                return 1
    """
    assert _ids("src/repro/core/schemes/foo.py", text) == []
    assert _ids("src/repro/retrieval/foo.py", text) == []
    # .kind comparisons against non-scheme strings are not dispatch
    good = """\
        def f(shape):
            if shape.kind == "graph_mini":
                return 1
    """
    assert _ids("src/repro/launch/foo.py", good) == []


def test_code_upcast_fires_outside_kernels():
    bad = """\
        import jax.numpy as jnp
        def f(codes_table, ids):
            return jnp.take(codes_table, ids, axis=0).astype(jnp.int32)
    """
    assert _ids("src/repro/core/foo.py", bad) == ["code-upcast"]


def test_code_upcast_quiet_in_kernels_and_on_non_codes():
    text = """\
        import jax.numpy as jnp
        def f(codes):
            return codes.astype(jnp.int32)
    """
    assert _ids("src/repro/kernels/foo/foo.py", text) == []
    good = """\
        import jax.numpy as jnp
        def f(rows, codes):
            return rows.astype(jnp.int32), codes.astype(jnp.float32)
    """
    assert _ids("src/repro/core/foo.py", good) == []


def test_block_literal_fires_on_signature_default():
    bad = """\
        def adc(artifact, q, block_n=1024):
            return None
    """
    assert _ids("src/repro/retrieval/foo.py", bad) == ["block-literal"]


def test_block_literal_fires_at_kernel_call_site():
    bad = """\
        from repro.kernels.mgqe_decode import decode
        def f(c, cent):
            return decode(c, cent, block_b=64)
    """
    assert _ids("src/repro/core/foo.py", bad) == ["block-literal"]
    bad2 = """\
        def f(dispatch, lut, codes):
            return dispatch.dispatch("pq_score", lut, codes, block_n=512)
    """
    assert _ids("src/repro/core/foo.py", bad2) == ["block-literal"]


def test_block_literal_quiet_on_none_pins_and_kernel_internals():
    good = """\
        from repro.kernels.mgqe_decode import decode
        def adc(artifact, q, block_n=None):
            return decode(q, artifact, block_b=None)
        def g(cfg, c, cent):
            return decode(c, cent, block_b=cfg.decode_block_b)
    """
    assert _ids("src/repro/core/foo.py", good) == []
    # kernels may default their own block geometry
    internal = """\
        def _impl(lut, codes, block_n=1024):
            return None
    """
    assert _ids("src/repro/kernels/pq/pq.py", internal) == []


def test_shard_map_in_jit_fires_on_decorated_and_lambda():
    bad = """\
        import jax
        from jax.experimental.shard_map import shard_map
        @jax.jit
        def f(x):
            return shard_map(g, mesh=m, in_specs=s, out_specs=o)(x)
    """
    assert _ids("src/repro/sharding/foo.py", bad) == ["shard-map-in-jit"]
    bad2 = """\
        import jax
        from jax.experimental.shard_map import shard_map
        h = jax.jit(lambda x: shard_map(g, mesh=m, in_specs=s,
                                        out_specs=o)(x))
    """
    assert _ids("src/repro/sharding/foo.py", bad2) == ["shard-map-in-jit"]


def test_shard_map_quiet_as_own_jit():
    good = """\
        import jax
        from jax.experimental.shard_map import shard_map
        def gather(art, ids):
            return shard_map(g, mesh=m, in_specs=s, out_specs=o)(art, ids)
        serve = jax.jit(lambda art, ids: postprocess(art, ids))
    """
    assert _ids("src/repro/sharding/foo.py", good) == []


def test_pad_in_flush_fires_only_in_launch():
    bad = """\
        import jax.numpy as jnp
        def flush(flat, widths):
            return jnp.pad(flat, widths)
    """
    assert _ids("src/repro/launch/foo.py", bad) == ["pad-in-flush"]
    assert _ids("src/repro/core/foo.py", bad) == []
    good = """\
        import numpy as np
        def flush(flat, widths):
            return np.pad(flat, widths)
    """
    assert _ids("src/repro/launch/foo.py", good) == []


def test_lock_discipline_fires_on_unlocked_write():
    bad = """\
        import threading
        class Engine:
            def __init__(self):
                self._lock = threading.Lock()
                self._pending = []
            def locked_reset(self):
                with self._lock:
                    self._pending = []
            def racy_reset(self):
                self._pending = [1]
    """
    diags = analyze_source("src/repro/launch/foo.py",
                           textwrap.dedent(bad))
    assert [d.rule_id for d in diags] == ["lock-discipline"]
    assert "racy_reset" not in diags[0].message  # message names the attr
    assert "_pending" in diags[0].message


def test_lock_discipline_quiet_when_all_writes_guarded():
    good = """\
        import threading
        class Engine:
            def __init__(self):
                self._lock = threading.Lock()
                self._pending = []     # __init__ is pre-thread: exempt
            def reset(self):
                with self._lock:
                    self._pending = []
            def grow(self):
                with self._lock:
                    self._pending += [1]
            def unrelated(self):
                self.stats = {}        # never lock-guarded anywhere
    """
    assert _ids("src/repro/launch/foo.py", good) == []


def test_bare_assert_scoped_to_src():
    bad = "def f(x):\n    assert x > 0\n"
    assert _ids("src/repro/foo.py", bad) == ["bare-assert"]
    assert _ids("tools/foo.py", bad) == []
    assert _ids("tests/foo.py", bad) == []
    good = "def f(x):\n    if x <= 0:\n        raise ValueError(x)\n"
    assert _ids("src/repro/foo.py", good) == []


# ----------------------------------------------------------------------
# suppressions
# ----------------------------------------------------------------------

BAD_ASSERT = "def f(x):\n    assert x > 0{tail}\n"


def test_suppression_same_line():
    src = BAD_ASSERT.format(tail="  # repro-lint: disable=bare-assert")
    assert _ids("src/repro/foo.py", src) == []


def test_suppression_comment_line_above():
    src = ("def f(x):\n"
           "    # repro-lint: disable=bare-assert (sanctioned: demo)\n"
           "    assert x > 0\n")
    assert _ids("src/repro/foo.py", src) == []


def test_suppression_disable_all_and_wrong_id():
    src = BAD_ASSERT.format(tail="  # repro-lint: disable=all")
    assert _ids("src/repro/foo.py", src) == []
    src = BAD_ASSERT.format(tail="  # repro-lint: disable=pad-in-flush")
    assert _ids("src/repro/foo.py", src) == ["bare-assert"]


# ----------------------------------------------------------------------
# baseline round-trip
# ----------------------------------------------------------------------

def test_baseline_round_trip(tmp_path):
    src = "def f(x):\n    assert x > 0\n"
    diags = analyze_source("src/repro/foo.py", src)
    assert len(diags) == 1
    bl_path = str(tmp_path / "baseline.json")
    write_baseline(bl_path, diags)
    baseline = load_baseline(bl_path)
    # identical findings are fully absorbed ...
    new, old = filter_baseline(diags, baseline)
    assert (new, len(old)) == ([], 1)
    # ... and stay absorbed when unrelated edits shift line numbers
    shifted = analyze_source("src/repro/foo.py",
                             "import os\n\n\n" + src)
    assert shifted[0].line != diags[0].line
    new, old = filter_baseline(shifted, baseline)
    assert (new, len(old)) == ([], 1)
    # a second, non-baselined finding is NEW
    two = analyze_source("src/repro/foo.py",
                         src + "def g(y):\n    assert y\n")
    new, old = filter_baseline(two, baseline)
    assert (len(new), len(old)) == (1, 1)


def test_baseline_missing_file_is_empty(tmp_path):
    assert load_baseline(str(tmp_path / "nope.json")) == {}
    assert load_baseline(None) == {}


def test_baseline_rejects_malformed(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"entries": {"k": "not-an-int"}}))
    with pytest.raises(ValueError):
        load_baseline(str(p))


# ----------------------------------------------------------------------
# registry contract
# ----------------------------------------------------------------------

def test_rule_registry_shape():
    ids = registered_rule_ids()
    assert len(ids) >= 8
    for rid in ids:
        cls = rule_class(rid)
        assert cls.rule_id == rid
        assert cls.title and cls.motivation


def test_register_rule_rejects_duplicates_and_bad_ids():
    existing = registered_rule_ids()[0]

    with pytest.raises(ValueError, match="duplicate"):
        @register_rule
        class Dup(Rule):
            rule_id = existing
            title = "t"
            motivation = "m"

    with pytest.raises(ValueError, match="kebab-case"):
        @register_rule
        class BadId(Rule):
            rule_id = "Not Kebab"
            title = "t"
            motivation = "m"

    with pytest.raises(ValueError, match="reserved"):
        @register_rule
        class Reserved(Rule):
            rule_id = PARSE_ERROR_RULE
            title = "t"
            motivation = "m"

    with pytest.raises(ValueError, match="title"):
        @register_rule
        class NoDocs(Rule):
            rule_id = "undocumented-rule"


def test_kind_names_superset_of_live_registries():
    # the linter's literal kind list (it must not import jax) can lag
    # ahead of the registries but never behind them
    from repro.core.schemes import registered_kinds
    from repro.retrieval import registered_index_kinds
    live = set(registered_kinds()) | set(registered_index_kinds())
    assert live <= SCHEME_KIND_NAMES


def test_parse_error_is_a_diagnostic_not_a_crash():
    diags = analyze_source("src/repro/foo.py", "def f(:\n")
    assert [d.rule_id for d in diags] == [PARSE_ERROR_RULE]


# ----------------------------------------------------------------------
# self-check: the committed tree is clean
# ----------------------------------------------------------------------

def test_committed_tree_has_zero_diagnostics():
    """The empty-committed-baseline invariant: every rule parses every
    src/tools file and reports nothing (fix or suppress before commit,
    never baseline new debt)."""
    diags, n_files = analyze_paths(
        [os.path.join(REPO, "src"), os.path.join(REPO, "tools")],
        root=REPO, exclude=lint_exclusions(REPO))
    assert n_files > 100           # really scanned the tree
    assert [d.format() for d in diags] == []


def test_shared_exclusion_list_matches_pyproject():
    exc = lint_exclusions(REPO)
    assert "tests/_hypothesis_compat.py" in exc
    with open(os.path.join(REPO, "pyproject.toml")) as f:
        assert "tests/_hypothesis_compat.py" in f.read()


# ----------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------

def test_cli_gate_and_baseline_flow(tmp_path, capsys):
    from repro.analysis.cli import main
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (tmp_path / "pyproject.toml").write_text("[tool.ruff]\n")
    (pkg / "bad.py").write_text("def f(x):\n    assert x\n")
    bl = str(tmp_path / "baseline.json")
    report = str(tmp_path / "report.json")

    # violation -> exit 1, diagnostic on stdout, JSON report written
    rc = main([str(tmp_path / "src"), "--root", str(tmp_path),
               "--baseline", bl, "--json", report])
    out = capsys.readouterr().out
    assert rc == 1
    assert "bare-assert" in out
    with open(report) as f:
        data = json.load(f)
    assert data["counts"] == {"new": 1, "baselined": 0}
    assert data["files_scanned"] == 1

    # accept into baseline -> gate passes, finding reported as baselined
    assert main([str(tmp_path / "src"), "--root", str(tmp_path),
                 "--baseline", bl, "--write-baseline"]) == 0
    capsys.readouterr()
    rc = main([str(tmp_path / "src"), "--root", str(tmp_path),
               "--baseline", bl, "--json", report])
    assert rc == 0
    with open(report) as f:
        assert json.load(f)["counts"] == {"new": 0, "baselined": 1}

    # fix the file -> clean even against the stale baseline
    (pkg / "bad.py").write_text(
        "def f(x):\n    if not x:\n        raise ValueError(x)\n")
    assert main([str(tmp_path / "src"), "--root", str(tmp_path),
                 "--baseline", bl]) == 0


def test_cli_list_rules_and_unknown_rule(capsys):
    from repro.analysis.cli import main
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in registered_rule_ids():
        assert rid in out
    with pytest.raises(SystemExit):
        main([str(REPO) + "/src", "--rule", "no-such-rule"])


def test_single_rule_filter():
    src = ("import jax.numpy as jnp\n"
           "X = jnp.ones(3)\n"
           "def f(x):\n    assert x\n")
    only = analyze_source("src/repro/foo.py", src,
                          rule_ids=["bare-assert"])
    assert [d.rule_id for d in only] == ["bare-assert"]
