"""Async serving front-end (launch/async_engine.py, DESIGN.md §10).

Coverage in three layers: the :class:`FlushPolicy` state machine is
driven with a FAKE clock (deterministic max-wait vs block-full trigger
ordering — no threads, no sleeps); the full threaded engine is checked
for bit-parity against the synchronous engine on the same requests
(ServingEngine and RetrievalEngine); and the shared-stats contract —
subclass properties exported by ``as_dict``, background hot-row refresh
equivalence with the synchronous refresh — is pinned end to end.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.core import Embedding, EmbeddingConfig
from repro.launch.async_engine import (AsyncEngineStats, AsyncServingEngine,
                                       FlushPolicy, drive_open_loop)
from repro.launch.engine import EngineStats, ServingEngine

# sanitizer lane: flush legs run under jax.transfer_guard('disallow')
pytestmark = pytest.mark.hot_path


def _dpq_cfg(**kw):
    return EmbeddingConfig(vocab_size=500, dim=16, kind="dpq",
                           num_subspaces=4, num_centroids=8,
                           decode_block_b=32, **kw)


def _serving_engine(**kw):
    cfg = _dpq_cfg(**{k: v for k, v in kw.items() if k in ("hot_rows",)})
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    ekw = {k: v for k, v in kw.items() if k not in ("hot_rows",)}
    return ServingEngine(emb, art, **ekw), emb, art


# ------------------------------------------------- FlushPolicy (fake clock)

def test_policy_deadline_fires_only_after_max_wait():
    p = FlushPolicy(block_rows=8, max_wait_s=1.0)
    assert p.decision(now=0.0) is None          # empty queue: never fires
    assert p.timeout(now=0.0) is None
    p.on_submit(2, now=10.0)
    assert p.decision(now=10.5) is None         # young AND not full
    assert p.timeout(now=10.5) == pytest.approx(0.5)
    assert p.decision(now=10.999) is None
    assert p.decision(now=11.0) == "deadline"   # oldest waited max_wait
    p.on_flush(now=11.0)
    assert p.decision(now=100.0) is None        # reset: empty again


def test_policy_block_full_fires_immediately_and_wins_over_deadline():
    p = FlushPolicy(block_rows=8, max_wait_s=1.0)
    p.on_submit(5, now=0.0)
    assert p.decision(now=0.0) is None
    p.on_submit(3, now=0.0)                     # rows reach the block
    assert p.decision(now=0.0) == "full"
    # both conditions true -> "full" labels the flush
    assert p.decision(now=5.0) == "full"


def test_policy_deadline_clock_starts_when_queue_goes_nonempty():
    p = FlushPolicy(block_rows=100, max_wait_s=1.0)
    p.on_submit(1, now=0.0)
    p.on_submit(1, now=50.0)                    # does NOT restart clock
    assert p.decision(now=0.5) is None
    assert p.decision(now=1.0) == "deadline"    # from the OLDEST submit
    p.on_flush(now=60.0)
    p.on_submit(1, now=60.0)                    # fresh queue, fresh clock
    assert p.decision(now=60.5) is None
    assert p.decision(now=61.0) == "deadline"


def test_policy_drain_only_when_forced_and_nonempty():
    p = FlushPolicy(block_rows=8, max_wait_s=1.0)
    assert p.decision(now=0.0, forced=True) is None     # nothing queued
    p.on_submit(1, now=0.0)
    assert p.decision(now=0.1, forced=True) == "drain"
    assert p.decision(now=0.1, forced=False) is None
    # forced never relabels a real trigger
    assert p.decision(now=1.0, forced=True) == "deadline"


def test_policy_zero_wait_makes_every_submit_flush_eligible():
    p = FlushPolicy(block_rows=8, max_wait_s=0.0)
    p.on_submit(1, now=5.0)
    assert p.decision(now=5.0) == "deadline"
    assert p.timeout(now=5.0) == 0.0


def test_policy_validates_arguments():
    with pytest.raises(ValueError):
        FlushPolicy(block_rows=0, max_wait_s=1.0)
    with pytest.raises(ValueError):
        FlushPolicy(block_rows=8, max_wait_s=-1.0)


# ----------------------------------------------------- parity with sync

def test_async_results_bit_identical_to_sync_engine():
    eng, emb, art = _serving_engine()
    ref = ServingEngine(emb, art)
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, 500, size=rng.integers(1, 9))
            for _ in range(40)]
    refs = [np.asarray(ref.lookup(r)) for r in reqs]
    with AsyncServingEngine(eng, max_wait_us=200.0) as a:
        futs = [a.submit(r) for r in reqs]
        outs = [f.result(timeout=30) for f in futs]
    for got, want in zip(outs, refs):
        np.testing.assert_array_equal(np.asarray(got), want)


def test_async_retrieval_engine_parity():
    from repro.launch.engine import RetrievalEngine
    from repro.retrieval import IndexConfig, get_index
    rng = np.random.default_rng(0)
    corpus = rng.standard_normal((256, 16)).astype(np.float32)
    index = get_index(IndexConfig(kind="flat_pq", num_subspaces=4,
                                  num_centroids=16, iters=3))
    art = index.build(jax.random.PRNGKey(0), corpus)
    qs = [rng.standard_normal((rng.integers(1, 4), 16)).astype(np.float32)
          for _ in range(10)]
    ref = RetrievalEngine(index, art, k=5, block_q=8)
    refs = [jax.tree.map(np.asarray, ref.search(q)) for q in qs]
    a_eng = RetrievalEngine(index, art, k=5, block_q=8)
    with AsyncServingEngine(a_eng, max_wait_us=200.0) as a:
        outs = [a.submit(q).result(timeout=30) for q in qs]
    for got, want in zip(outs, refs):
        got_l, want_l = jax.tree.leaves(got), jax.tree.leaves(want)
        assert len(got_l) == len(want_l)
        for g, w in zip(got_l, want_l):
            np.testing.assert_array_equal(np.asarray(g), w)


def test_lookup_is_submit_result_and_1d_query_keeps_shape():
    eng, _, _ = _serving_engine()
    with AsyncServingEngine(eng, max_wait_us=100.0) as a:
        out = a.lookup(np.asarray([1, 2, 3]))
    assert np.asarray(out).shape == (3, 16)


# ------------------------------------------------------- stats contract

def test_async_stats_export_includes_subclass_properties():
    """as_dict() must export derived metrics of SUBCLASSES through the
    property registry — the bug the registry exists to prevent was
    base-class-only hardcoded exports."""
    names = AsyncEngineStats.derived_metrics()
    assert {"p50_ms", "p99_ms", "p999_ms",
            "sustained_lookups_per_s"} <= set(names)
    assert set(EngineStats.derived_metrics()) <= set(names)
    st = AsyncEngineStats()
    d = st.as_dict()
    assert math.isnan(d["p99_ms"])              # empty stream: NaN, no crash
    assert d["sustained_lookups_per_s"] == 0.0
    assert d["latency"]["count"] == 0           # nested as_dict recursion


def test_async_counters_and_trigger_split_account_for_every_request():
    eng, _, _ = _serving_engine()
    rng = np.random.default_rng(1)
    reqs = [rng.integers(0, 500, size=4) for _ in range(30)]
    with AsyncServingEngine(eng, max_wait_us=500.0) as a:
        for f in [a.submit(r) for r in reqs]:
            f.result(timeout=30)
        a.drain()
        st = a.stats()
    assert st.submitted == 30
    assert st.requests == 30                    # inner-concat corrected
    assert st.lookups == 120
    assert st.latency.count == 30               # one sample per request
    assert (st.flushes_full + st.flushes_deadline
            + st.flushes_drain) == st.flushes
    assert st.p50_ms <= st.p99_ms or math.isnan(st.p99_ms)


def test_drive_open_loop_fills_wall_seconds_and_latency():
    eng, _, _ = _serving_engine()
    rng = np.random.default_rng(2)
    reqs = [rng.integers(0, 500, size=3) for _ in range(20)]
    arrivals = np.arange(20) * 1e-3
    with AsyncServingEngine(eng, max_wait_us=300.0) as a:
        st = drive_open_loop(a, reqs, arrivals)
    assert st.wall_seconds > 0
    assert st.sustained_lookups_per_s > 0
    assert st.latency.count == 20
    with AsyncServingEngine(eng, max_wait_us=300.0) as a:
        with pytest.raises(ValueError, match="arrival times"):
            drive_open_loop(a, reqs, arrivals[:-1])


def test_submit_after_close_raises():
    eng, _, _ = _serving_engine()
    a = AsyncServingEngine(eng, max_wait_us=100.0)
    a.close()
    with pytest.raises(RuntimeError, match="closed"):
        a.submit(np.asarray([1]))
    a.close()                                   # idempotent


# -------------------------------------------------- background refresh

def test_background_refresh_matches_sync_refresh_selection():
    """The refresher thread must install exactly the cache the
    synchronous refresh_hot_rows would: same EMA ranking, same block,
    and cached results stay bit-identical to an uncached engine."""
    eng, emb, art = _serving_engine(hot_rows=16)
    base = ServingEngine(emb, art, hot_rows=0)
    hot_ids = np.arange(100, 108)
    rng = np.random.default_rng(3)
    reqs = [np.concatenate([hot_ids, rng.integers(0, 500, size=2)])
            for _ in range(20)]
    with AsyncServingEngine(eng, max_wait_us=200.0,
                            refresh_every=5) as a:
        for f in [a.submit(r) for r in reqs]:
            f.result(timeout=30)
        a.drain()
        a.refresh_now(wait=True)                # deterministic refresh
        assert set(hot_ids) <= set(eng._hot_ids.tolist())
        # post-refresh lookups: hot hits AND bit parity
        h0 = a.stats().hot_hits
        out = a.lookup(hot_ids)
        assert a.stats().hot_hits - h0 == len(hot_ids)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(base.lookup(hot_ids)))


def test_refresh_every_requires_hot_cache():
    eng, _, _ = _serving_engine()                # hot_rows=0
    with pytest.raises(ValueError, match="hot-row"):
        AsyncServingEngine(eng, refresh_every=4)
    with AsyncServingEngine(eng) as a:
        with pytest.raises(ValueError, match="hot-row"):
            a.refresh_now()


def test_async_disables_inner_inflush_refresh():
    eng, _, _ = _serving_engine(hot_rows=8, hot_refresh_every=3)
    with AsyncServingEngine(eng, refresh_every=5) as a:
        assert eng.hot_refresh_every == 0       # cadence moved off-path
        assert eng.hot_track_freq is True
        a.lookup(np.asarray([1, 2]))


def test_reset_stats_keeps_shared_instance_wiring():
    eng, _, _ = _serving_engine()
    with AsyncServingEngine(eng, max_wait_us=100.0) as a:
        a.lookup(np.asarray([1, 2, 3]))
        assert a.stats().lookups == 3
        a.reset_stats()
        assert a.stats().lookups == 0
        assert eng.stats_ is a.stats_           # still ONE shared object
        a.lookup(np.asarray([4]))
        assert a.stats().lookups == 1
        assert a.stats().latency.count == 1
