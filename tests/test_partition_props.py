"""Property tests for frequency-based vocabulary partitioning
(core/partition.py) — the arithmetic the whole tier system rests on.

Hypothesis cases skip individually on bare installs
(tests/_hypothesis_compat.py); the plain pytest cases always run.
"""
import numpy as np
import pytest

from repro.core.partition import (frequency_boundaries, rank_by_frequency,
                                  tier_of_ids, validate_partition)

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401


# ----------------------------------------------------------------------
# rank_by_frequency
# ----------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=10_000),
                min_size=1, max_size=300))
def test_rank_remap_inverse_roundtrip(counts):
    """remap and inverse are mutually inverse permutations:
    remap[inverse] == arange == inverse-composed-with-remap."""
    counts = np.asarray(counts)
    remap, inverse = rank_by_frequency(counts)
    n = len(counts)
    assert sorted(remap.tolist()) == list(range(n))
    np.testing.assert_array_equal(remap[inverse], np.arange(n))
    np.testing.assert_array_equal(inverse[remap], np.arange(n))


@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=10_000),
                min_size=1, max_size=300))
def test_rank_orders_counts_descending_with_stable_ties(counts):
    counts = np.asarray(counts)
    remap, inverse = rank_by_frequency(counts)
    ranked = counts[inverse]
    assert np.all(ranked[:-1] >= ranked[1:])
    # ties broken by old id: equal counts keep ascending old-id order
    for i in range(len(ranked) - 1):
        if ranked[i] == ranked[i + 1]:
            assert inverse[i] < inverse[i + 1]


# ----------------------------------------------------------------------
# tier_of_ids
# ----------------------------------------------------------------------

def _boundaries_strategy():
    """(vocab_size, strictly-ascending in-range boundaries)."""
    return st.integers(min_value=2, max_value=5_000).flatmap(
        lambda v: st.tuples(
            st.just(v),
            st.lists(st.integers(min_value=1, max_value=v - 1),
                     unique=True, max_size=6).map(sorted).map(tuple)))


@settings(max_examples=200, deadline=None)
@given(_boundaries_strategy())
def test_tier_of_ids_monotone_and_bounded(vb):
    vocab, bounds = vb
    validate_partition(vocab, bounds)
    ids = np.arange(vocab)
    tiers = tier_of_ids(ids, bounds)
    # monotone non-decreasing in id, range [0, num_tiers)
    assert np.all(np.diff(tiers) >= 0)
    assert tiers[0] == 0 and tiers[-1] == len(bounds)
    # each boundary id is exactly where the tier increments
    for i, b in enumerate(bounds):
        assert tiers[b] == i + 1 and tiers[b - 1] == i
    # tier sizes telescope back to the edges
    np.testing.assert_array_equal(
        np.bincount(tiers, minlength=len(bounds) + 1),
        np.diff(np.asarray((0,) + bounds + (vocab,))))


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=10, max_value=100_000),
       st.floats(min_value=0.001, max_value=0.999))
def test_frequency_boundaries_always_validate(vocab, frac):
    bounds = frequency_boundaries(vocab, (frac,))
    validate_partition(vocab, bounds)
    assert 1 <= bounds[0] <= vocab - 1


def test_tier_of_ids_accepts_plain_lists():
    """Regression: ``ids * 0`` on a list is ``[]``, so the pre-fix code
    returned garbage (an empty array) for plain Python lists."""
    out = tier_of_ids([0, 5, 10, 99], (10,))
    np.testing.assert_array_equal(out, [0, 0, 1, 1])
    # empty-boundaries path must also survive list input
    np.testing.assert_array_equal(tier_of_ids([3, 4], ()), [0, 0])


def test_tier_of_ids_accepts_python_scalars():
    assert int(tier_of_ids(50, (10, 40))) == 2
    assert int(tier_of_ids(0, (10,))) == 0


def test_tier_of_ids_list_matches_array_path():
    ids = [0, 1, 9, 10, 11, 499, 500, 999]
    bounds = (10, 500)
    np.testing.assert_array_equal(tier_of_ids(ids, bounds),
                                  tier_of_ids(np.asarray(ids), bounds))


# ----------------------------------------------------------------------
# frequency_boundaries degenerate inputs (plain pytest — always run)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("fracs", [
    (0.0,),            # empty head tier
    (1.0,),            # head tier == whole vocab
    (1.5,),            # > 1
    (-0.1,),           # negative
    (float("nan"),),   # NaN slips through naive comparisons
    (0.5, 0.5),        # non-increasing (duplicate)
    (0.5, 0.3),        # non-increasing (descending)
    (0.2, 1.0),        # later fraction out of range
])
def test_frequency_boundaries_rejects_degenerate_fractions(fracs):
    """Regression: these used to be silently clamped into forced 1-id
    tiers instead of failing."""
    with pytest.raises(ValueError):
        frequency_boundaries(1000, fracs)


def test_frequency_boundaries_keeps_rounding_nudge():
    """The legitimate clamp survives: valid fractions that round to a
    colliding/0 id are nudged apart, and the result still validates."""
    # 0.0004 * 1000 rounds to 0 -> nudged to 1
    assert frequency_boundaries(1000, (0.0004,)) == (1,)
    # two close valid fractions rounding to the same id get separated
    bounds = frequency_boundaries(1000, (0.3001, 0.3004))
    assert bounds == (300, 301)
    validate_partition(1000, bounds)


def test_frequency_boundaries_impossible_tiny_vocab_raises():
    """Nudging cannot conjure ids that don't exist: many fractions over
    a tiny vocab must fail like any other impossible partition."""
    with pytest.raises(ValueError):
        frequency_boundaries(3, (0.2, 0.5, 0.9))


# ----------------------------------------------------------------------
# validate_partition error paths (plain pytest — always run)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("vocab,bounds", [
    (100, (0,)),        # empty first tier
    (100, (100,)),      # empty last tier
    (100, (60, 40)),    # inverted
    (100, (50, 50)),    # duplicate boundary
])
def test_validate_partition_rejects_bad_tiers(vocab, bounds):
    with pytest.raises(ValueError):
        validate_partition(vocab, bounds)


def test_validate_partition_coverage_check_is_an_exception():
    """The coverage-sum branch must raise ValueError (NOT a bare assert
    that vanishes under ``python -O``).  A NaN boundary slips past the
    pairwise ordering checks — NaN comparisons are all False — and only
    the coverage sum catches it."""
    with pytest.raises(ValueError, match="cover"):
        validate_partition(100, (float("nan"),))


def test_validate_partition_accepts_good_partitions():
    validate_partition(100, ())
    validate_partition(100, (10,))
    validate_partition(100, (10, 50, 99))
