"""Property tests for frequency-based vocabulary partitioning
(core/partition.py) — the arithmetic the whole tier system rests on.

Hypothesis cases skip individually on bare installs
(tests/_hypothesis_compat.py); the plain pytest cases always run.
"""
import numpy as np
import pytest

from repro.core.partition import (frequency_boundaries, rank_by_frequency,
                                  tier_of_ids, validate_partition)

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401


# ----------------------------------------------------------------------
# rank_by_frequency
# ----------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=10_000),
                min_size=1, max_size=300))
def test_rank_remap_inverse_roundtrip(counts):
    """remap and inverse are mutually inverse permutations:
    remap[inverse] == arange == inverse-composed-with-remap."""
    counts = np.asarray(counts)
    remap, inverse = rank_by_frequency(counts)
    n = len(counts)
    assert sorted(remap.tolist()) == list(range(n))
    np.testing.assert_array_equal(remap[inverse], np.arange(n))
    np.testing.assert_array_equal(inverse[remap], np.arange(n))


@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=10_000),
                min_size=1, max_size=300))
def test_rank_orders_counts_descending_with_stable_ties(counts):
    counts = np.asarray(counts)
    remap, inverse = rank_by_frequency(counts)
    ranked = counts[inverse]
    assert np.all(ranked[:-1] >= ranked[1:])
    # ties broken by old id: equal counts keep ascending old-id order
    for i in range(len(ranked) - 1):
        if ranked[i] == ranked[i + 1]:
            assert inverse[i] < inverse[i + 1]


# ----------------------------------------------------------------------
# tier_of_ids
# ----------------------------------------------------------------------

def _boundaries_strategy():
    """(vocab_size, strictly-ascending in-range boundaries)."""
    return st.integers(min_value=2, max_value=5_000).flatmap(
        lambda v: st.tuples(
            st.just(v),
            st.lists(st.integers(min_value=1, max_value=v - 1),
                     unique=True, max_size=6).map(sorted).map(tuple)))


@settings(max_examples=200, deadline=None)
@given(_boundaries_strategy())
def test_tier_of_ids_monotone_and_bounded(vb):
    vocab, bounds = vb
    validate_partition(vocab, bounds)
    ids = np.arange(vocab)
    tiers = tier_of_ids(ids, bounds)
    # monotone non-decreasing in id, range [0, num_tiers)
    assert np.all(np.diff(tiers) >= 0)
    assert tiers[0] == 0 and tiers[-1] == len(bounds)
    # each boundary id is exactly where the tier increments
    for i, b in enumerate(bounds):
        assert tiers[b] == i + 1 and tiers[b - 1] == i
    # tier sizes telescope back to the edges
    np.testing.assert_array_equal(
        np.bincount(tiers, minlength=len(bounds) + 1),
        np.diff(np.asarray((0,) + bounds + (vocab,))))


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=10, max_value=100_000),
       st.floats(min_value=0.001, max_value=0.999))
def test_frequency_boundaries_always_validate(vocab, frac):
    bounds = frequency_boundaries(vocab, (frac,))
    validate_partition(vocab, bounds)
    assert 1 <= bounds[0] <= vocab - 1


# ----------------------------------------------------------------------
# validate_partition error paths (plain pytest — always run)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("vocab,bounds", [
    (100, (0,)),        # empty first tier
    (100, (100,)),      # empty last tier
    (100, (60, 40)),    # inverted
    (100, (50, 50)),    # duplicate boundary
])
def test_validate_partition_rejects_bad_tiers(vocab, bounds):
    with pytest.raises(ValueError):
        validate_partition(vocab, bounds)


def test_validate_partition_coverage_check_is_an_exception():
    """The coverage-sum branch must raise ValueError (NOT a bare assert
    that vanishes under ``python -O``).  A NaN boundary slips past the
    pairwise ordering checks — NaN comparisons are all False — and only
    the coverage sum catches it."""
    with pytest.raises(ValueError, match="cover"):
        validate_partition(100, (float("nan"),))


def test_validate_partition_accepts_good_partitions():
    validate_partition(100, ())
    validate_partition(100, (10,))
    validate_partition(100, (10, 50, 99))
