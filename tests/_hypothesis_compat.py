"""Soft dependency on hypothesis.

hypothesis is a *dev* dependency (declared in pyproject's ``[dev]``
extra and installed in CI).  On a bare install the property tests skip
individually instead of erroring the whole module at collection — the
plain pytest tests in the same files still run.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised on bare installs
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e '.[dev]')"
            )(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """st.<anything>(...) placeholder; never executed (tests skip).

        Returns itself from every attribute/call so chained strategy
        builders (``st.integers(...).flatmap(...).map(...)``) evaluated
        at decoration time still collect cleanly.
        """

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return self
            return strategy

        def __call__(self, *args, **kwargs):
            return self

    st = _StrategyStub()
