"""Scheme-registry conformance suite (DESIGN.md §7).

Parametrized over the LIVE registry — every registered scheme (and
every variant it declares) is run through the full
init -> apply -> export -> serve lifecycle and checked against its own
artifact spec.  A new plugin gets this coverage the moment it
registers; nothing here lists kinds by hand.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Embedding, EmbeddingConfig
from repro.core.schemes import (ArtifactLeaf, get_scheme, registered_kinds,
                                scheme_class)


def _registry_params():
    out = []
    for kind in registered_kinds():
        cls = scheme_class(kind)
        for var in cls.variants():
            label = kind if var == "-" else f"{kind}-{var}"
            out.append(pytest.param(kind, var, id=label))
    return out


def _cfg(kind, var):
    return scheme_class(kind).probe_config(var)


def _spec_leaves(cfg):
    return get_scheme(cfg).artifact_leaves()


# ------------------------------------------------------------ lifecycle

@pytest.mark.parametrize("kind,var", _registry_params())
def test_scheme_lifecycle_roundtrip(kind, var):
    """init -> apply -> export -> serve for every registered scheme."""
    cfg = _cfg(kind, var)
    emb = Embedding(cfg)
    p = emb.init(jax.random.PRNGKey(0))
    ids = jnp.asarray([[0, 3], [cfg.vocab_size - 1, 1], [2, 2]])
    out, aux = emb.apply(p, ids)
    assert out.shape == ids.shape + (cfg.dim,)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(float(aux))
    art = emb.export(p)
    sv = emb.serve(art, ids)
    assert sv.shape == out.shape
    # post-export serving must reproduce the training-path forward for
    # every scheme whose export is lossless w.r.t. the forward (all but
    # sq, whose quantization error is bounded by its own test)
    if kind != "sq":
        np.testing.assert_allclose(np.asarray(out), np.asarray(sv),
                                   atol=1e-5)


@pytest.mark.parametrize("kind,var", _registry_params())
def test_scheme_artifact_matches_spec(kind, var):
    """The exported artifact must agree leaf-for-leaf with the scheme's
    single artifact-spec source of truth (shape, dtype, storage)."""
    cfg = _cfg(kind, var)
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    art_leaves = jax.tree.leaves(art)
    spec_leaves = _spec_leaves(cfg)
    assert len(art_leaves) == len(spec_leaves)
    for a, s in zip(art_leaves, spec_leaves):
        assert tuple(a.shape) == tuple(s.shape), (a.shape, s)
        assert jnp.asarray(a).dtype == jnp.dtype(s.dtype), (a.dtype, s)
        assert a.size * jnp.asarray(a).dtype.itemsize * 8 == s.storage_bits
    # the derived struct is the same spec viewed as ShapeDtypeStructs
    struct_leaves = jax.tree.leaves(emb.serving_artifact_struct())
    for s, st in zip(spec_leaves, struct_leaves):
        assert tuple(st.shape) == tuple(s.shape)
        assert st.dtype == jnp.dtype(s.dtype)


@pytest.mark.parametrize("kind,var", _registry_params())
def test_scheme_size_accounting_vs_artifact_nbytes(kind, var):
    """serving_size_bits() must equal the exported artifact's actual
    storage, up to code-packing rounding: code tables are *stored* at
    uint8/int32 granularity but *accounted* at their packed width, so
    accounting <= storage, with equality once the per-leaf packing
    slack (storage_bits - logical_bits, integer leaves only) is added
    back."""
    cfg = _cfg(kind, var)
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    actual_bits = sum(np.asarray(x).nbytes * 8
                      for x in jax.tree.leaves(art))
    size_bits = emb.serving_size_bits()
    assert size_bits <= actual_bits
    pack_slack = sum(leaf.storage_bits - leaf.size_bits
                     for leaf in _spec_leaves(cfg))
    assert size_bits + pack_slack == actual_bits
    # only integer (code) leaves may carry packing slack
    for leaf in _spec_leaves(cfg):
        if leaf.size_bits != leaf.storage_bits:
            assert jnp.issubdtype(jnp.dtype(leaf.dtype), jnp.integer)


@pytest.mark.parametrize("kind,var", _registry_params())
def test_scheme_sharding_specs_match_spec_placement(kind, var):
    """artifact_shard_specs derives from the same spec: rows leaves get
    P(model, ...), everything else replicated."""
    from jax.sharding import PartitionSpec as P
    cfg = _cfg(kind, var)
    scheme = get_scheme(cfg)
    if not scheme.supports_sharded_codes:
        with pytest.raises(ValueError):
            scheme.artifact_shard_specs()
        return
    spec_leaves = _spec_leaves(cfg)
    shard_leaves = jax.tree.leaves(scheme.artifact_shard_specs(),
                                   is_leaf=lambda x: isinstance(x, P))
    assert len(spec_leaves) == len(shard_leaves)
    for s, sh in zip(spec_leaves, shard_leaves):
        if s.rows:
            assert tuple(sh)[0] == "model"
        else:
            assert tuple(sh) == ()
    # every scheme must have at least one O(vocab) leaf to shard
    assert any(s.rows for s in spec_leaves)


# ----------------------------------------------------- dtype accounting

@pytest.mark.parametrize("kind,var", _registry_params())
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_scheme_size_accounting_tracks_param_dtype(kind, var, dtype):
    """Float artifact leaves must be accounted at param_dtype width —
    16 bits under bfloat16, not a hardcoded 32 (the old bug) — while
    code widths are dtype-independent.  Exported artifacts at the
    configured dtype must still match the spec exactly."""
    cfg = dataclasses.replace(_cfg(kind, var), param_dtype=dtype)
    if kind == "sq":
        # sq's lo/scale are fp32 by construction; only q counts codes
        assert cfg.serving_size_bits() == _cfg(kind, var).serving_size_bits()
        return
    width = jnp.dtype(dtype).itemsize * 8
    float_elems = sum(math.prod(leaf.shape)
                      for leaf in get_scheme(cfg).artifact_leaves()
                      if jnp.issubdtype(jnp.dtype(leaf.dtype),
                                        jnp.floating))
    f32_bits = _cfg(kind, var).serving_size_bits()
    assert cfg.serving_size_bits() == f32_bits - float_elems * (32 - width)
    # real export at this dtype agrees with the spec leaf-for-leaf
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    for a, s in zip(jax.tree.leaves(art), get_scheme(cfg).artifact_leaves()):
        assert jnp.asarray(a).dtype == jnp.dtype(s.dtype)
        assert tuple(a.shape) == tuple(s.shape)


# --------------------------------------------- hot-row cache (DESIGN §9)

@pytest.mark.parametrize("kind,var", _registry_params())
def test_scheme_hot_rows_export_matches_spec_and_serve(kind, var):
    """Every registered scheme supports the hot-row decode-ahead hook:
    export under hot_rows attaches a ``hot`` leaf that (a) matches the
    composed artifact spec leaf-for-leaf and (b) is BIT-identical to
    serving those head ids through the scheme — the cache contract."""
    cfg = dataclasses.replace(_cfg(kind, var), hot_rows=8)
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    assert "hot" in art
    leaf = get_scheme(cfg).artifact_spec()["hot"]
    assert tuple(art["hot"].shape) == leaf.shape == (8, cfg.dim)
    assert jnp.asarray(art["hot"]).dtype == jnp.dtype(leaf.dtype)
    # jitted, like every real serving path — eager XLA skips the FMA
    # fusion the compiled path uses and drifts in the last ulp
    served = jax.jit(emb.serve)(art, jnp.arange(8))
    np.testing.assert_array_equal(np.asarray(served),
                                  np.asarray(art["hot"]))
    # the derived accounting charges the cache's memory
    extra = cfg.serving_size_bits() - _cfg(kind, var).serving_size_bits()
    assert extra == 8 * cfg.dim * jnp.dtype(leaf.dtype).itemsize * 8


@pytest.mark.parametrize("kind,var", _registry_params())
def test_scheme_hot_leaf_placement_replicated(kind, var):
    """The hot block is O(hot_rows), read by every data shard — it must
    replicate (P()) while the cold code tables stay row-sharded."""
    from jax.sharding import PartitionSpec as P
    cfg = dataclasses.replace(_cfg(kind, var), hot_rows=8)
    scheme = get_scheme(cfg)
    if not scheme.supports_sharded_codes:
        return
    specs = scheme.artifact_shard_specs()
    assert tuple(specs["hot"]) == ()
    assert any(tuple(s)[:1] == ("model",)
               for s in jax.tree.leaves(specs,
                                        is_leaf=lambda x: isinstance(x, P)))


def test_hot_rows_config_validation():
    with pytest.raises(ValueError, match="hot_rows"):
        EmbeddingConfig(vocab_size=8, dim=4, hot_rows=9)
    with pytest.raises(ValueError, match="hot_rows"):
        EmbeddingConfig(vocab_size=8, dim=4, hot_rows=-1)
    # the whole vocab is a legal (if extreme) cache
    cfg = EmbeddingConfig(vocab_size=8, dim=4, hot_rows=8)
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    assert art["hot"].shape == (8, 4)


# ------------------------------------------------------------- registry

def test_unknown_kind_error_lists_registered_schemes():
    with pytest.raises(ValueError, match="registered schemes"):
        EmbeddingConfig(vocab_size=8, dim=4, kind="no-such-scheme")


def test_registry_rejects_duplicate_kind():
    from repro.core.schemes import Scheme, register_scheme
    with pytest.raises(ValueError, match="already registered"):
        @register_scheme("dpq")
        class Impostor(Scheme):
            pass


def test_optimizer_registry_rejects_unknown_kind():
    from repro.train import optimizer as opt_lib
    cfg = opt_lib.OptimizerConfig(kind="nope")
    with pytest.raises(ValueError, match="unknown optimizer"):
        opt_lib.init(cfg, {"w": jnp.zeros((2,))})


# ------------------------------------------------------------ rq extras

def test_rq_residual_stages_reduce_reconstruction_error():
    """Each additional codebook must explain residual variance: the
    quantization error of M=3 stages is below M=1 on the same table."""
    errs = {}
    for m in (1, 3):
        cfg = EmbeddingConfig(vocab_size=128, dim=16, kind="rq",
                              num_levels=m, num_centroids=16)
        emb = Embedding(cfg)
        p = emb.init(jax.random.PRNGKey(0))
        # compare decoded serving rows against the trained table rows
        art = emb.export(p)
        dec = emb.serve(art, jnp.arange(128))
        errs[m] = float(jnp.mean(jnp.square(dec - p["emb"])))
    assert errs[3] < errs[1]


def test_rq_straight_through_gradients():
    cfg = EmbeddingConfig(vocab_size=64, dim=8, kind="rq", num_levels=2,
                          num_centroids=8)
    emb = Embedding(cfg)
    p = emb.init(jax.random.PRNGKey(0))
    ids = jnp.arange(16)

    def loss(p):
        out, aux = emb.apply(p, ids)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    g_emb = np.asarray(g["emb"])
    assert np.abs(g_emb[:16]).sum() > 0       # STE reaches gathered rows
    assert np.abs(g_emb[16:]).sum() == 0      # untouched rows: no grad
    assert np.abs(np.asarray(g["codebooks"])).sum() > 0


def test_rq_codes_within_range_and_uint8():
    cfg = EmbeddingConfig(vocab_size=100, dim=8, kind="rq", num_levels=3,
                          num_centroids=16)
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(2)))
    codes = np.asarray(art["codes"])
    assert codes.dtype == np.uint8 and codes.shape == (100, 3)
    assert codes.max() < 16


def test_rq_through_serving_engine():
    """The micro-batching engine needs no rq-specific code."""
    from repro.launch.engine import ServingEngine
    cfg = EmbeddingConfig(vocab_size=200, dim=16, kind="rq", num_levels=2,
                          num_centroids=8, decode_block_b=32)
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    eng = ServingEngine(emb, art)
    ids = jnp.asarray([0, 7, 199, 7])
    out = eng.lookup(ids)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(emb.serve(art, ids)), atol=1e-6)


def test_artifact_leaf_bits():
    leaf = ArtifactLeaf((4, 8), jnp.uint8, rows=True, logical_bits=96)
    assert leaf.storage_bits == 4 * 8 * 8
    assert leaf.size_bits == 96
    assert ArtifactLeaf((2, 2), "bfloat16").size_bits == 4 * 16
