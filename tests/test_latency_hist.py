"""Latency histogram invariants (launch/latency.py, DESIGN.md §10).

The histogram is what every p99-SLO claim in the async engine rests
on, so its contract is pinned three ways: percentile readouts are
monotone in q, ``merge`` is exactly bucket-count addition (two engines'
histograms compose losslessly), and every readout upper-bounds the true
order statistic within one bucket width (the advertised resolution).

Hypothesis cases skip individually on bare installs
(tests/_hypothesis_compat.py); the plain pytest cases always run.
"""
import math

import numpy as np
import pytest

from repro.launch.latency import LatencyHistogram, percentile_exact

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401


def _samples():
    """Positive durations spanning the histogram's six decades."""
    return st.lists(st.floats(min_value=1e-7, max_value=100.0,
                              allow_nan=False, allow_infinity=False),
                    min_size=1, max_size=200)


def _hist(samples):
    h = LatencyHistogram()
    h.record_many(samples)
    return h


# ---------------------------------------------------------- properties

@settings(max_examples=200, deadline=None)
@given(_samples())
def test_percentiles_monotone_in_q(samples):
    """p50 <= p90 <= p99 <= p999 <= p100 for ANY sample stream —
    readouts walk one cumulative count, so quantile order must hold."""
    h = _hist(samples)
    qs = [0.0, 0.5, 0.9, 0.99, 0.999, 1.0]
    vals = [h.percentile(q) for q in qs]
    assert all(a <= b for a, b in zip(vals, vals[1:])), \
        list(zip(qs, vals))


@settings(max_examples=200, deadline=None)
@given(_samples(), _samples())
def test_merge_equals_histogram_of_concatenated_streams(s1, s2):
    """merge(h1, h2) has EXACTLY the bucket counts of one histogram
    fed both streams — the mergeability claim, at full precision."""
    merged = _hist(s1).merge(_hist(s2))
    both = _hist(list(s1) + list(s2))
    np.testing.assert_array_equal(merged.counts, both.counts)
    assert merged.count == len(s1) + len(s2)


@settings(max_examples=200, deadline=None)
@given(_samples(), st.floats(min_value=0.0, max_value=1.0))
def test_readout_upper_bounds_exact_within_one_bucket(samples, q):
    """percentile(q) is a conservative bound on the rank-⌈q·n⌉ sample,
    and never looser than one bucket width (a factor of ``growth``) —
    the resolution the module docstring advertises."""
    h = _hist(samples)
    got = h.percentile(q)
    ref = percentile_exact(samples, q)
    assert ref is not None
    # never optimistic: the readout is the sample's bucket upper edge
    assert got >= min(ref, h.bucket_upper(h.n_buckets - 1)) * (1 - 1e-9)
    # never looser than one bucket, unless the sample was clamped
    if h.lo < ref < h.bucket_upper(h.n_buckets - 2):
        assert got <= ref * h.growth * (1 + 1e-9)


# ------------------------------------------------------- deterministic

def test_empty_histogram_reads_nan_not_crash():
    h = LatencyHistogram()
    assert math.isnan(h.percentile(0.99))
    assert math.isnan(h.p50_ms) and math.isnan(h.p999_ms)
    assert h.count == 0
    assert "empty" in repr(h)
    d = h.as_dict()
    assert d["count"] == 0 and math.isnan(d["p99_ms"])


def test_out_of_range_quantile_raises():
    h = LatencyHistogram()
    h.record(1e-3)
    with pytest.raises(ValueError):
        h.percentile(1.5)
    with pytest.raises(ValueError):
        h.percentile(-0.1)


def test_bucket_edges_and_clamps():
    h = LatencyHistogram(lo=1e-6, growth=2.0, n_buckets=4)
    # below lo, NaN and negatives all clamp into bucket 0
    for bad in (0.0, -1.0, float("nan"), 5e-7):
        assert h.bucket_of(bad) == 0
    assert h.bucket_of(3e-6) == 1          # [2e-6, 4e-6)
    assert h.bucket_of(1.0) == 3           # beyond top edge: clamp
    h.record_many([0.0, 3e-6, 1.0, float("nan")])
    assert h.counts.tolist() == [2, 1, 0, 1]
    # conservative readout: upper edge of the holding bucket
    assert h.percentile(1.0) == pytest.approx(h.bucket_upper(3))


def test_record_many_matches_scalar_record():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-6, sigma=2, size=500)
    h1, h2 = LatencyHistogram(), LatencyHistogram()
    h1.record_many(samples)
    for s in samples:
        h2.record(float(s))
    np.testing.assert_array_equal(h1.counts, h2.counts)


def test_merge_rejects_mismatched_schemes():
    with pytest.raises(ValueError, match="bucket schemes"):
        LatencyHistogram(n_buckets=64).merge(LatencyHistogram(n_buckets=128))
    with pytest.raises(ValueError, match="bucket schemes"):
        LatencyHistogram(lo=1e-6).merge(LatencyHistogram(lo=1e-3))


def test_percentile_exact_reference():
    assert percentile_exact([], 0.5) is None
    assert percentile_exact([3.0, 1.0, 2.0], 0.5) == 2.0
    assert percentile_exact([3.0, 1.0, 2.0], 1.0) == 3.0
    assert percentile_exact([3.0, 1.0, 2.0], 0.0) == 1.0   # rank floor 1
