"""Test fixtures.  NOTE: no XLA_FLAGS here — smoke tests run on the
single real CPU device; multi-device sharding tests spawn subprocesses
with their own flags (test_sharding.py).

Sanitizer lane (DESIGN.md §15): ``pytest --sanitize`` re-runs the fast
tier under JAX's strict numerics flags —

* ``jax_numpy_rank_promotion="raise"`` turns silent broadcast-rank
  promotion (the classic (B,) vs (B, 1) recsys bug) into an error;
* ``jax_debug_nans`` fails the op that PRODUCES a NaN instead of the
  assertion that later observes it.

Both are session-wide.  ``jax.transfer_guard("disallow")`` is scoped
tighter: for tests marked ``hot_path`` (the serving-engine suites) the
guard wraps the engines' ``run_flat`` device leg — the serving
contract is ONE explicit upload and one fused call per flush, so any
*implicit* host<->device transfer inside that leg (a numpy operand
reaching a jitted call, eager scalar mixing) is a smuggled sync point
on the latency path.  Test-side assertions stay unguarded: eager
numpy/jax mixing is fine in test code.
"""
import jax
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--sanitize", action="store_true", default=False,
        help="run under jax_numpy_rank_promotion='raise' + "
             "jax_debug_nans; wrap hot_path-marked tests' engine "
             "flush legs in jax.transfer_guard('disallow') "
             "(DESIGN.md §15)")


def pytest_configure(config):
    if config.getoption("--sanitize"):
        jax.config.update("jax_numpy_rank_promotion", "raise")
        jax.config.update("jax_debug_nans", True)


@pytest.fixture(autouse=True)
def _transfer_guard(request, monkeypatch):
    """Under --sanitize, hot_path-marked tests run every engine
    ``run_flat`` (the single-upload fused-call flush leg, whichever
    thread executes it) with implicit transfers disallowed."""
    if (request.config.getoption("--sanitize")
            and request.node.get_closest_marker("hot_path")):
        from repro.launch import engine as engine_mod

        orig = engine_mod._MicroBatchEngine.run_flat

        def guarded(self, *args, **kwargs):
            with jax.transfer_guard("disallow"):
                return orig(self, *args, **kwargs)

        monkeypatch.setattr(engine_mod._MicroBatchEngine,
                            "run_flat", guarded)
    yield


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
