"""Test fixtures.  NOTE: no XLA_FLAGS here — smoke tests run on the
single real CPU device; multi-device sharding tests spawn subprocesses
with their own flags (test_sharding.py)."""
import jax
import pytest


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
