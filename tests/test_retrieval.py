"""Retrieval subsystem (DESIGN.md §8): index registry conformance,
deterministic top-k merging, ADC exactness, IVF recall, engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.retrieval import (INVALID_ID, IndexConfig, get_index,
                             index_class, merge_topk, register_index,
                             registered_index_kinds, topk_by_position)
from tests._hypothesis_compat import given, settings, st


def _corpus(n=512, d=16, seed=0):
    k = jax.random.PRNGKey(seed)
    centers = jax.random.normal(k, (16, d)) * 2.0
    assign = jax.random.randint(jax.random.PRNGKey(seed + 1), (n,), 0, 16)
    return centers[assign] + 0.1 * jax.random.normal(
        jax.random.PRNGKey(seed + 2), (n, d))


# ------------------------------------------------------------ registry

@pytest.mark.parametrize("kind", registered_index_kinds())
def test_index_conformance_build_search(kind):
    """Every registered kind: build -> batched search returns (B, k)
    descending scores with in-range (or pad) ids matching a re-scan."""
    cfg = index_class(kind).probe_config()
    index = get_index(cfg)
    vecs = _corpus()
    art = index.build(jax.random.PRNGKey(0), vecs)
    q = jax.random.normal(jax.random.PRNGKey(3), (5, vecs.shape[1]))
    s, i = index.search(art, q, 7)
    assert s.shape == (5, 7) and i.shape == (5, 7)
    s_np, i_np = np.asarray(s), np.asarray(i)
    assert (np.diff(s_np, axis=1) <= 1e-6).all(), "scores must descend"
    valid = i_np != INVALID_ID
    assert valid.all()                     # 512 candidates >> k
    assert ((i_np >= 0) & (i_np < vecs.shape[0])).all()
    # no duplicate candidates within a query's result list
    for row in i_np:
        assert len(set(row.tolist())) == row.size


def test_index_registry_errors():
    with pytest.raises(KeyError):
        IndexConfig(kind="nope")
    with pytest.raises(ValueError):
        IndexConfig(kind="ivf_pq", nprobe=0)
    with pytest.raises(ValueError):
        IndexConfig(kind="ivf_pq", nlist=4, nprobe=8)
    with pytest.raises(ValueError):       # duplicate registration
        from repro.retrieval.base import Index

        @register_index("flat_pq")
        class Impostor(Index):
            pass


def test_index_artifact_shard_specs_rows_only():
    from jax.sharding import PartitionSpec as P
    vecs = _corpus()
    for kind in registered_index_kinds():
        index = get_index(index_class(kind).probe_config())
        art = index.build(jax.random.PRNGKey(0), vecs)
        specs = index.artifact_shard_specs(art)
        assert set(specs) == set(art)
        for name, spec in specs.items():
            if name in index.rows_leaves:
                assert spec[0] == "model", (kind, name)
            else:
                assert spec == P(), (kind, name)


# -------------------------------------------------- ADC exactness (sat)

def test_flat_pq_scores_equal_decoded_lut_summation():
    """flat_pq batched scores == dense dot products against the
    DECODED corpus to 1e-5 — ADC's LUT summation is exact for the dot
    product, per subspace, up to float error."""
    from repro.kernels.mgqe_decode.ref import mgqe_decode_ref
    vecs = _corpus(n=300)
    index = get_index(IndexConfig(kind="flat_pq", num_subspaces=4,
                                  num_centroids=32, iters=5))
    art = index.build(jax.random.PRNGKey(0), vecs)
    q = jax.random.normal(jax.random.PRNGKey(3), (6, vecs.shape[1]))
    scores = np.asarray(index.scores(art, q))                 # (B, N)
    decoded = mgqe_decode_ref(art["codes"].astype(jnp.int32),
                              art["centroids"])               # (N, d)
    ref = np.asarray(q @ decoded.T)
    np.testing.assert_allclose(scores, ref, atol=1e-5)
    # and search() is exactly the top-k of that matrix
    s, i = index.search(art, q, 9)
    order = np.argsort(-scores, axis=1, kind="stable")[:, :9]
    np.testing.assert_array_equal(np.asarray(i), order)


def test_pq_score_ops_accept_stored_uint8_codes():
    """The dispatch layer takes codes at their stored dtype — no
    eager int32 upcast of the O(vocab) table on the hot path (sat)."""
    from repro.kernels.pq_score import (score_candidates,
                                        score_candidates_batched,
                                        topk_candidates)
    cent = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 4))
    codes8 = jax.random.randint(jax.random.PRNGKey(1), (100, 4), 0, 16
                                ).astype(jnp.uint8)
    q = jax.random.normal(jax.random.PRNGKey(2), (3, 16))
    for backend in ("xla", "interpret"):
        a = score_candidates(q[0], cent, codes8, backend=backend,
                             block_n=32)
        b = score_candidates(q[0], cent, codes8.astype(jnp.int32),
                             backend=backend, block_n=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
        ab = score_candidates_batched(q, cent, codes8, backend=backend,
                                      block_n=32)
        np.testing.assert_allclose(np.asarray(ab[0]), np.asarray(a),
                                   atol=1e-5)
        ts, ti = topk_candidates(q, cent, codes8, 5, backend=backend,
                                 block_n=32)
        assert ts.shape == (3, 5) and ti.dtype == jnp.int32


def test_pq_topk_kernel_matches_ref_and_pads():
    from repro.kernels.pq_score import pq_topk, pq_topk_ref
    cent_k = 16
    luts = jax.random.normal(jax.random.PRNGKey(0), (4, 6, cent_k))
    codes = jax.random.randint(jax.random.PRNGKey(1), (257, 6), 0,
                               cent_k).astype(jnp.uint8)
    ks, ki = pq_topk(luts.astype(jnp.float32), codes, 10, block_n=64,
                     interpret=True)
    rs, ri = pq_topk_ref(luts.astype(jnp.float32), codes, 10)
    np.testing.assert_allclose(np.asarray(ks), np.asarray(rs), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    # k > N: both pad with (-inf, INVALID_ID)
    ks, ki = pq_topk(luts.astype(jnp.float32), codes[:3], 5,
                     block_n=64, interpret=True)
    assert (np.asarray(ks)[:, 3:] == -np.inf).all()
    assert (np.asarray(ki)[:, 3:] == INVALID_ID).all()


# ------------------------------------------------- top-k merge property

def _reference_topk(scores, k):
    """Single-device canonical top-k: (score desc, id asc)."""
    ids = jnp.broadcast_to(jnp.arange(scores.shape[-1]), scores.shape)
    return merge_topk(scores, ids, k)


def _sharded_merge(scores, splits, k):
    """Split the candidate axis arbitrarily, local top-k per shard
    (ids global), then merge — the sharded driver's algebra."""
    parts, start = [], 0
    for size in splits:
        part = scores[..., start:start + size]
        ids = jnp.broadcast_to(
            jnp.arange(start, start + size), part.shape)
        parts.append(merge_topk(part, ids, k))
        start += size
    s_cat = jnp.concatenate([s for s, _ in parts], axis=-1)
    i_cat = jnp.concatenate([i for _, i in parts], axis=-1)
    return merge_topk(s_cat, i_cat, k)


def test_sharded_merge_equals_topk_seeded():
    """Seeded splits incl. tie-heavy inputs: merged per-shard top-k ==
    single-device top-k, bit for bit."""
    rng = np.random.default_rng(0)
    for trial in range(8):
        n = int(rng.integers(3, 60))
        k = int(rng.integers(1, n + 5))
        # half the trials draw from 4 discrete values: dense ties
        if trial % 2:
            scores = jnp.asarray(
                rng.choice([0.0, 1.0, -1.0, 0.5], size=(3, n)),
                jnp.float32)
        else:
            scores = jnp.asarray(rng.normal(size=(3, n)), jnp.float32)
        cuts = sorted(rng.choice(n + 1, size=int(rng.integers(0, 4))))
        splits = np.diff([0] + list(cuts) + [n]).astype(int)
        splits = [int(s) for s in splits if s > 0] or [n]
        ref_s, ref_i = _reference_topk(scores, k)
        out_s, out_i = _sharded_merge(scores, splits, k)
        np.testing.assert_array_equal(np.asarray(out_s),
                                      np.asarray(ref_s), err_msg=str(
                                          (trial, splits, k)))
        np.testing.assert_array_equal(np.asarray(out_i),
                                      np.asarray(ref_i))
        # lax.top_k agrees wherever it defines the same contract
        # (ids ascend along the axis -> position tiebreak == id)
        if k <= n:
            ts, _, ti = topk_by_position(scores, jnp.broadcast_to(
                jnp.arange(n), scores.shape), k)
            np.testing.assert_array_equal(np.asarray(ts),
                                          np.asarray(ref_s))
            np.testing.assert_array_equal(np.asarray(ti),
                                          np.asarray(ref_i))


@settings(deadline=None, max_examples=40)
@given(st.lists(st.floats(min_value=-100, max_value=100, width=32)
                .map(lambda x: round(x, 1)),   # rounded -> frequent ties
                min_size=1, max_size=40),
       st.integers(min_value=1, max_value=45),
       st.data())
def test_sharded_merge_equals_topk_property(values, k, data):
    """Hypothesis: for ANY scores (ties included) and ANY shard split,
    merging per-shard top-k lists == the single-device top-k."""
    n = len(values)
    cut_count = data.draw(st.integers(min_value=0, max_value=min(4, n)))
    cuts = sorted(data.draw(st.lists(
        st.integers(min_value=0, max_value=n), min_size=cut_count,
        max_size=cut_count)))
    splits = [int(s) for s in np.diff([0] + cuts + [n]) if s > 0] or [n]
    scores = jnp.asarray(values, jnp.float32)[None]
    ref = _reference_topk(scores, k)
    out = _sharded_merge(scores, splits, k)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(ref[1]))


# -------------------------------------------------------------- recall

def _recall(ids, ex_ids, k):
    ids = np.asarray(ids)
    return float(np.mean([
        len(set(ids[b].tolist()) & set(ex_ids[b].tolist())) / k
        for b in range(ids.shape[0])]))


def _recall_vs_dense(n, nlist, nprobe, k=100):
    from repro.data.synthetic import pq_clustered_corpus
    vecs_np, q_np = pq_clustered_corpus(n=n, n_clusters=nlist)
    vecs, q = jnp.asarray(vecs_np), jnp.asarray(q_np)
    ex_ids = np.argsort(-(q_np @ vecs_np.T), axis=1)[:, :k]
    out = {}
    for kind, kw in (("flat_pq", {}),
                     ("ivf_pq", dict(nlist=nlist, nprobe=nprobe))):
        index = get_index(IndexConfig(kind=kind, num_subspaces=8,
                                      num_centroids=128, iters=15,
                                      coarse_iters=15, **kw))
        art = index.build(jax.random.PRNGKey(42), vecs)
        _, ids = index.search(art, q, k)
        out[kind] = _recall(ids, ex_ids, k)
    return out


def test_retrieval_recall_vs_dense_scan():
    """flat_pq is (near-)exact on a PQ-representable corpus; ivf_pq at
    nprobe = nlist/8 keeps recall@100 >= 0.95 vs the dense scan."""
    rec = _recall_vs_dense(n=20_000, nlist=64, nprobe=8)
    assert rec["flat_pq"] >= 0.99, rec
    assert rec["ivf_pq"] >= 0.95, rec


@pytest.mark.slow
def test_retrieval_recall_100k_acceptance():
    """The acceptance-scale run: 100k-item corpus, nprobe = nlist/8."""
    rec = _recall_vs_dense(n=100_000, nlist=64, nprobe=8)
    assert rec["flat_pq"] >= 0.99, rec
    assert rec["ivf_pq"] >= 0.95, rec


# -------------------------------------------------------------- engine

def test_retrieval_engine_microbatches_and_returns_right_request():
    from repro.launch.engine import RetrievalEngine
    vecs = _corpus()
    index = get_index(IndexConfig(kind="ivf_pq", num_subspaces=4,
                                  num_centroids=16, nlist=8, nprobe=8,
                                  iters=5))
    art = index.build(jax.random.PRNGKey(0), vecs)
    eng = RetrievalEngine(index, art, k=10, block_q=8)
    rng = np.random.default_rng(0)
    q_a = rng.normal(size=(3, 16)).astype(np.float32)
    q_b = rng.normal(size=(16,)).astype(np.float32)   # 1-D request
    h_a = eng.submit(q_a)
    s_b, i_b = eng.search(q_b)        # queue non-empty: must return b's
    assert s_b.shape == (1, 10)
    ref_s, ref_i = index.search(art, jnp.asarray(q_b)[None], 10)
    np.testing.assert_array_equal(np.asarray(i_b), np.asarray(ref_i))
    # h_a was flushed in the same micro-batch
    assert eng.pending == 0
    st_ = eng.stats()
    assert st_.requests == 2 and st_.lookups == 4 and st_.flushes == 1
    assert st_.padded_lookups % eng.pad_multiple == 0
    ref_a = index.search(art, jnp.asarray(q_a), 10)
    h_c = eng.submit(q_a)
    outs = eng.flush()
    np.testing.assert_array_equal(np.asarray(outs[h_c][1]),
                                  np.asarray(ref_a[1]))
    del h_a


def test_engine_stats_zero_guard():
    """Empty/instant streams report 0.0 lookups/s, never divide by
    zero (sat)."""
    from repro.launch.engine import EngineStats
    st_ = EngineStats()
    assert st_.lookups_per_s == 0.0
    assert st_.as_dict()["lookups_per_s"] == 0.0
    st_.lookups, st_.seconds = 100, 0.0     # instant stream
    assert st_.lookups_per_s == 0.0


def test_retrieval_engine_rejects_bad_mesh_configs():
    from repro.launch.engine import RetrievalEngine
    vecs = _corpus(n=96)
    index = get_index(IndexConfig(kind="flat_pq", num_subspaces=4,
                                  num_centroids=16, iters=3))
    art = index.build(jax.random.PRNGKey(0), vecs)
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="model"):
        RetrievalEngine(index, art, k=5, mesh=mesh)


# ------------------------------------------------------- two-tower wire

def test_two_tower_retrieval_topk_matches_dense_order():
    from repro.configs.registry import get_arch
    from repro.models.recsys.two_tower import TwoTower
    _, cfg = get_arch("two-tower-retrieval", smoke=True)
    model = TwoTower(cfg)
    params = model.init(jax.random.PRNGKey(0))
    item_ids = jnp.arange(400, dtype=jnp.int32)
    index, art = model.build_index(
        jax.random.PRNGKey(1), params, item_ids,
        IndexConfig(kind="flat_pq", num_subspaces=8, num_centroids=64,
                    iters=10))
    users = jnp.arange(4, dtype=jnp.int32)
    scores, ids = model.retrieval_topk(params, index, art, users, 20)
    assert scores.shape == (4, 20) and ids.shape == (4, 20)
    # high overlap with the exact dense scan (quantization-limited)
    vecs = model.encode_items(params, item_ids)
    u, _ = model.user_vec(params, users)
    ex = np.argsort(-np.asarray(u @ vecs.T), axis=1)[:, :20]
    assert _recall(ids, ex, 20) >= 0.5
    # and the single-query compat path still serves
    s1 = model.retrieval_scores_adc(params, art, users[:1])
    assert s1.shape == (400,)
