"""Per-recsys-arch smoke tests + the paper's backbone recommenders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.models.recsys.autoint import AutoInt
from repro.models.recsys.backbones import GMF, NeuMF, SASRec, BackboneConfig
from repro.models.recsys.bst import BST
from repro.models.recsys.deepfm import DeepFM
from repro.models.recsys.fields import embedding_bag_padded
from repro.models.recsys.two_tower import TwoTower

B = 8


def _ctr_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    ids = np.stack([rng.integers(0, v, B) for v in cfg.field_vocab_sizes], 1)
    return {"sparse_ids": jnp.asarray(ids, jnp.int32),
            "label": jnp.asarray(rng.random(B) < 0.3, jnp.float32)}


@pytest.mark.parametrize("arch,cls", [("autoint", AutoInt),
                                      ("deepfm", DeepFM)])
@pytest.mark.slow
def test_ctr_smoke_train_and_serve(arch, cls):
    _, cfg = get_arch(arch, smoke=True)
    m = cls(cfg)
    p = m.init(jax.random.PRNGKey(0))
    batch = _ctr_batch(cfg)
    loss, metrics = m.loss(p, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda pp: m.loss(pp, batch)[0])(p)
    assert all(np.all(np.isfinite(np.asarray(x))) for x in jax.tree.leaves(g))
    # serving path: quantized artifacts, logits match training forward
    arts = m.fields.export(p["fields"])
    s_train, _ = m.apply(p, batch)
    s_serve = m.serve(p, arts, batch)
    np.testing.assert_allclose(np.asarray(s_train), np.asarray(s_serve),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_bst_smoke_and_serve():
    _, cfg = get_arch("bst", smoke=True)
    m = BST(cfg)
    p = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"hist_ids": jnp.asarray(
                 rng.integers(0, cfg.n_items, (B, cfg.seq_len)), jnp.int32),
             "target_id": jnp.asarray(
                 rng.integers(0, cfg.n_items, B), jnp.int32),
             "label": jnp.asarray(rng.random(B) < 0.3, jnp.float32)}
    loss, _ = m.loss(p, batch)
    assert np.isfinite(float(loss))
    art = m.item_emb.export(p["item_emb"])
    s_train, _ = m.apply(p, batch)
    s_serve = m.serve(p, art, batch)
    np.testing.assert_allclose(np.asarray(s_train), np.asarray(s_serve),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_two_tower_smoke_and_adc():
    _, cfg = get_arch("two-tower-retrieval", smoke=True)
    m = TwoTower(cfg)
    p = m.init(jax.random.PRNGKey(0))
    batch = {"user_ids": jnp.arange(B), "item_ids": jnp.arange(B),
             "item_logq": jnp.zeros(B)}
    loss, _ = m.loss(p, batch)
    assert np.isfinite(float(loss))
    # ADC corpus scoring approximates exact dot products
    ids = jnp.arange(512, dtype=jnp.int32)
    corpus = m.build_adc_corpus(jax.random.PRNGKey(1), p, ids,
                                num_subspaces=16, num_centroids=64)
    user = jnp.zeros((1,), jnp.int32)
    s_adc = np.asarray(m.retrieval_scores_adc(p, corpus, user))
    vecs = m.encode_items(p, ids)
    s_exact = np.asarray(m.retrieval_scores(p, user, vecs))
    corr = np.corrcoef(s_adc, s_exact)[0, 1]
    assert corr > 0.9, corr


def test_embedding_bag_padded_mean():
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    ids = jnp.asarray([[1, 2, -1], [3, -1, -1]])
    out = embedding_bag_padded(table, ids, mode="mean")
    np.testing.assert_allclose(np.asarray(out),
                               [[(2 + 4) / 2, (3 + 5) / 2], [6, 7]])


# ----------------------------------------------------- paper's backbones

def _bb_cfg(model, kind="mgqe"):
    return BackboneConfig(model=model, n_users=100, n_items=80, dim=16,
                          embed_kind=kind, num_subspaces=4,
                          num_centroids=16, tier_tail_centroids=8,
                          mlp_dims=(16, 8), maxlen=10, n_blocks=1)


@pytest.mark.parametrize("model,cls", [("gmf", GMF), ("neumf", NeuMF)])
@pytest.mark.parametrize("kind", ["full", "dpq", "mgqe", "lrf", "sq"])
def test_backbone_pointwise(model, cls, kind):
    cfg = _bb_cfg(model, kind)
    m = cls(cfg)
    p = m.init(jax.random.PRNGKey(0))
    batch = {"user_ids": jnp.arange(B) % 100,
             "item_ids": jnp.arange(B) % 80,
             "label": jnp.asarray([1, 0, 1, 0, 1, 0, 1, 0], jnp.float32)}
    loss, _ = m.loss(p, batch)
    assert np.isfinite(float(loss)), (model, kind)
    g = jax.grad(lambda pp: m.loss(pp, batch)[0])(p)
    assert all(np.all(np.isfinite(np.asarray(x)))
               for x in jax.tree.leaves(g))


@pytest.mark.parametrize("kind", ["full", "mgqe"])
def test_backbone_sasrec(kind):
    cfg = _bb_cfg("sasrec", kind)
    m = SASRec(cfg)
    p = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    seqs = jnp.asarray(rng.integers(0, 80, (B, cfg.maxlen)), jnp.int32)
    pos = jnp.asarray(rng.integers(0, 80, (B, cfg.maxlen)), jnp.int32)
    neg = jnp.asarray(rng.integers(0, 80, (B, cfg.maxlen)), jnp.int32)
    batch = {"seq": seqs, "pos": pos, "neg": neg}
    loss, _ = m.loss(p, batch)
    assert np.isfinite(float(loss)), kind


def test_backbone_training_reduces_loss():
    """A few steps of GMF+MGQE on a learnable toy task reduce the loss
    (the paper's convergence claim, in miniature)."""
    from repro.train import optimizer as opt_lib
    cfg = _bb_cfg("gmf", "mgqe")
    m = GMF(cfg)
    ocfg = opt_lib.OptimizerConfig(kind="adam", lr=5e-2, grad_clip=None)
    state = opt_lib.TrainState.create(
        ocfg, m.init(jax.random.PRNGKey(0)))
    step = jax.jit(opt_lib.make_step_fn(ocfg, m.loss))
    rng = np.random.default_rng(1)
    losses = []
    for i in range(30):
        u = rng.integers(0, 100, 32)
        it = rng.integers(0, 80, 32)
        y = ((u + it) % 2).astype(np.float32)    # learnable parity-ish rule
        batch = {"user_ids": jnp.asarray(u), "item_ids": jnp.asarray(it),
                 "label": jnp.asarray(y)}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
