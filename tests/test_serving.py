"""Serving-path invariants: artifact structs, size accounting, ADC."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Embedding, EmbeddingConfig
from repro.core import adc
from repro.core.serving import format_size_table, size_table


def _cfgs():
    return [
        EmbeddingConfig(vocab_size=96, dim=16),
        EmbeddingConfig(vocab_size=96, dim=16, kind="sq", sq_bits=8),
        EmbeddingConfig(vocab_size=96, dim=16, kind="dpq",
                        num_subspaces=4, num_centroids=16),
        EmbeddingConfig(vocab_size=96, dim=16, kind="mgqe",
                        num_subspaces=4, num_centroids=16,
                        tier_boundaries=(10,),
                        tier_num_centroids=(16, 4)),
        EmbeddingConfig(vocab_size=96, dim=16, kind="mgqe",
                        mgqe_variant="private_k",
                        num_subspaces=4, num_centroids=16,
                        tier_boundaries=(10,),
                        tier_num_centroids=(16, 4)),
        EmbeddingConfig(vocab_size=96, dim=16, kind="mgqe",
                        mgqe_variant="private_d",
                        num_subspaces=4, num_centroids=16,
                        tier_boundaries=(10,),
                        tier_num_subspaces=(4, 2)),
        EmbeddingConfig(vocab_size=96, dim=16, kind="rq",
                        num_levels=3, num_centroids=16),
    ]


@pytest.mark.parametrize("cfg", _cfgs(), ids=lambda c: c.kind)
def test_artifact_struct_matches_real_export(cfg):
    """The dry-run lowers serving from serving_artifact_struct();
    it must agree exactly with what export() really produces."""
    emb = Embedding(cfg)
    params = emb.init(jax.random.PRNGKey(0))
    art = emb.export(params)
    struct = emb.serving_artifact_struct()
    real = jax.tree.map(lambda x: (x.shape, jnp.asarray(x).dtype), art)
    want = jax.tree.map(lambda s: (s.shape, s.dtype), struct)
    assert jax.tree.all(jax.tree.map(lambda a, b: a == b, real, want)), \
        (real, want)


def test_size_table_normalization():
    rows = size_table(_cfgs())
    assert rows[0]["pct_of_full"] == 100.0
    # every quantized scheme strictly smaller than full at this scale
    assert rows[1]["bits"] < rows[0]["bits"]
    txt = format_size_table(rows)
    assert "mgqe" in txt and "100.00" in txt


def test_lm_serve_params_struct_drops_table():
    """The serving cells must lower WITHOUT the full embedding table
    (paper Fig. 1: discarded at serving)."""
    from repro.launch.cells import _strip_embed_table
    from repro.configs.registry import get_arch
    from repro.models import lm
    _, cfg = get_arch("stablelm-3b", smoke=True)
    struct = jax.eval_shape(lambda k: lm.model_init(k, cfg),
                            jax.random.PRNGKey(0))
    stripped = _strip_embed_table(struct)
    assert "emb" not in stripped["embed"]
    assert "centroids" in stripped["embed"]


def test_adc_topk_recall_on_clustered_corpus():
    k = jax.random.PRNGKey(0)
    centers = jax.random.normal(k, (32, 64)) * 2.0
    assign = jax.random.randint(jax.random.PRNGKey(1), (4096,), 0, 32)
    vecs = centers[assign] + 0.1 * jax.random.normal(
        jax.random.PRNGKey(2), (4096, 64))
    art = adc.build_corpus_artifact(k, vecs, num_subspaces=8,
                                    num_centroids=64, iters=15)
    q = jax.random.normal(jax.random.PRNGKey(3), (64,))
    s_adc = np.asarray(adc.adc_scores(art, q))
    s_ex = np.asarray(vecs @ q)
    assert np.corrcoef(s_adc, s_ex)[0, 1] > 0.99


def test_adc_reconstruction_beats_random():
    k = jax.random.PRNGKey(0)
    vecs = jax.random.normal(k, (1024, 32))
    art = adc.build_corpus_artifact(k, vecs, num_subspaces=8,
                                    num_centroids=32, iters=10)
    mse = float(adc.reconstruction_mse(art, vecs))
    assert mse < float(jnp.var(vecs))  # better than predicting the mean


def test_serving_engine_microbatches_and_returns_right_request():
    """Queued requests decode in one flush; each handle gets ITS rows,
    including lookup() racing a non-empty queue."""
    from repro.launch.engine import ServingEngine
    cfg = EmbeddingConfig(vocab_size=200, dim=16, kind="dpq",
                          num_subspaces=4, num_centroids=8,
                          decode_block_b=32)
    emb = Embedding(cfg)
    params = emb.init(jax.random.PRNGKey(0))
    art = emb.export(params)
    eng = ServingEngine(emb, art)

    ids_a, ids_b = jnp.arange(5), jnp.asarray([7, 3])
    eng.submit(ids_a)
    out_b = eng.lookup(ids_b)          # queue non-empty: must return b's rows
    np.testing.assert_allclose(np.asarray(out_b),
                               np.asarray(emb.serve(art, ids_b)), atol=1e-6)

    h1 = eng.submit(jnp.asarray([0]))
    h2 = eng.submit(jnp.arange(40))
    outs = eng.flush()
    assert outs[h1].shape == (1, 16) and outs[h2].shape == (40, 16)
    np.testing.assert_allclose(
        np.asarray(outs[h2]), np.asarray(emb.serve(art, jnp.arange(40))),
        atol=1e-6)
    st = eng.stats()
    assert st.lookups == 5 + 2 + 1 + 40
    assert st.padded_lookups % cfg.decode_block_b == 0
    assert st.flushes == 2 and st.requests == 4


def test_fit_pq_corpus_smaller_than_codebook():
    """n < K must fall back to with-replacement seeding, not crash."""
    vecs = jax.random.normal(jax.random.PRNGKey(0), (10, 8))
    art = adc.build_corpus_artifact(jax.random.PRNGKey(1), vecs,
                                    num_subspaces=4, num_centroids=16,
                                    iters=3)
    assert art["codes"].shape == (10, 4)
    assert float(adc.reconstruction_mse(art, vecs)) < float(jnp.var(vecs))


def test_mgqe_decode_kernel_serves_same_as_jnp_path():
    """The Pallas mgqe_decode kernel (interpret mode) must reproduce the
    framework serving lookup exactly."""
    from repro.kernels.mgqe_decode import mgqe_decode
    cfg = EmbeddingConfig(vocab_size=200, dim=32, kind="mgqe",
                          num_subspaces=8, num_centroids=16,
                          tier_boundaries=(20,),
                          tier_num_centroids=(16, 8))
    emb = Embedding(cfg)
    params = emb.init(jax.random.PRNGKey(0))
    art = emb.export(params)
    ids = jnp.arange(64)
    ref = emb.serve(art, ids)
    codes = jnp.take(art["codes"], ids, axis=0)
    out = mgqe_decode(codes, art["centroids"], block_b=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
