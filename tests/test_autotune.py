"""Block-size autotune harness (dispatch.tune, DESIGN.md §11).

Properties held:

  * determinism — for a fixed timer-seed and shape bucket, the sweep
    picks the same winner every run (ties resolve to the declared
    DEFAULT combo, never dict/hash order);
  * the default is never regressed — the pinned/default combo is always
    among the swept candidates (even when absent from the candidate
    grid) and a challenger must strictly beat it, so
    ``tuned_vs_pinned_speedup`` can never fall below 1 for a fixed
    timer (the sharded_decode 0.875x regression);
  * JSON cache round-trip — save_tune_cache -> fresh process state ->
    the file seeds tuned_params with identical entries;
  * tuning is a PERFORMANCE layer — every candidate block geometry is
    bit-identical to the default on the interpret backend (the real
    kernel body), so a wrong cache can never change model outputs;
  * a corrupt/invalid cache file degrades to declared defaults with a
    RuntimeWarning, never an exception;
  * dispatch injection — a tuned value applies exactly when the caller
    leaves the kwarg unset/None; an explicit value always pins.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # noqa: F401
from repro.kernels import dispatch
from repro.kernels.dispatch import Tunable


@pytest.fixture(autouse=True)
def _clean_tune_state(monkeypatch):
    """Each test starts with an empty in-process cache and no cache
    file configured (tests opt in via monkeypatch.setenv)."""
    monkeypatch.delenv(dispatch.TUNE_CACHE_ENV, raising=False)
    dispatch.clear_tune_cache()
    yield
    dispatch.clear_tune_cache()


def _seeded_timer(seed):
    """Deterministic fake timer: the sweep calls it once per candidate
    in a fixed order (default combo first, then declaration order), so
    a fixed seed fixes the whole time series (and therefore the winner)
    without running any kernel twice."""
    rng = np.random.default_rng(seed)

    def timer(thunk, iters):
        thunk()                           # still execute the candidate
        return float(rng.random())
    return timer


def _example_args(b=64):
    k = jax.random.PRNGKey(0)
    codes = jax.random.randint(k, (b, 3), 0, 8).astype(jnp.uint8)
    cbs = jax.random.normal(k, (3, 8, 16))
    return codes, cbs


# ------------------------------------------------------------ determinism

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_tune_deterministic_for_fixed_seed(seed):
    args = _example_args()
    dispatch.clear_tune_cache()
    w1 = dispatch.tune("rq_decode_stages", [args], backend="xla",
                       timer=_seeded_timer(seed), save=False)
    dispatch.clear_tune_cache()
    w2 = dispatch.tune("rq_decode_stages", [args], backend="xla",
                       timer=_seeded_timer(seed), save=False)
    assert w1 == w2
    (bucket, params), = w1.items()
    spec = dispatch.op_tunables("rq_decode_stages")
    assert set(params) == set(spec)
    for p, v in params.items():
        assert v in spec[p].candidates


def test_tune_tie_keeps_default():
    """A constant timer ties every candidate; the winner must be the
    declared DEFAULT combo — a challenger has to strictly beat it
    (the sharded_decode tuned_vs_pinned_speedup=0.875 regression)."""
    out = dispatch.tune("rq_decode_stages", [_example_args()],
                        backend="xla", timer=lambda th, it: 1.0,
                        save=False)
    (params,) = out.values()
    spec = dispatch.op_tunables("rq_decode_stages")
    assert params == {p: t.default for p, t in spec.items()}


def test_tune_sweeps_default_absent_from_candidates():
    """The pinned/default value is always among the swept combos, even
    when the candidate grid does not list it — and it wins ties."""
    seen = []
    dispatch.register_op(
        "autotune_default_probe",
        pallas=lambda x, block=7: (seen.append(block), x)[1],
        xla=lambda x, block=7: (seen.append(block), x)[1],
        tunables={"block": Tunable(7, (2, 4))},   # 7 not a candidate
    )
    out = dispatch.tune("autotune_default_probe", [jnp.arange(4.0)],
                        backend="xla", timer=lambda th, it: (th(), 1.0)[1],
                        save=False)
    (params,) = out.values()
    assert seen[0] == 7 and set(seen) == {7, 2, 4}
    assert params == {"block": 7}


def test_tune_challenger_must_strictly_beat_default():
    """A strictly faster candidate still wins the sweep (the default
    only protects against ties and losses, not real improvements)."""
    import itertools
    spec = dispatch.op_tunables("rq_decode_stages")
    n_total = len(list(itertools.product(*(t.candidates
                                           for t in spec.values()))))
    # the sweep times the default combo first, then the rest of the
    # grid in declaration order — make only the LAST combo faster
    times = iter([1.0] * (n_total - 1) + [0.5])

    def timer(th, it):
        th()
        return next(times)
    out = dispatch.tune("rq_decode_stages", [_example_args()],
                        backend="xla", timer=timer, save=False)
    (params,) = out.values()
    assert params == {p: t.candidates[-1] for p, t in spec.items()}


def test_tune_cache_hit_skips_resweep():
    calls = []

    def timer(th, it):
        calls.append(1)
        th()
        return float(len(calls))
    args = _example_args()
    first = dispatch.tune("rq_decode_stages", [args], backend="xla",
                          timer=timer, save=False)
    n = len(calls)
    again = dispatch.tune("rq_decode_stages", [args], backend="xla",
                          timer=timer, save=False)
    assert again == first
    assert len(calls) == n                # cache hit: no timing at all


# ----------------------------------------------------- shape buckets

@settings(max_examples=100, deadline=None)
@given(b=st.integers(1, 5000))
def test_shape_bucket_rounds_to_next_pow2(b):
    x = np.zeros((b, 4), np.uint8)
    up = 1 << (b - 1).bit_length()
    assert dispatch.shape_bucket(x) == f"uint8[{up}x4]"
    # idempotent: the bucket of the rounded shape is the same bucket
    assert dispatch.shape_bucket(np.zeros((up, 4), np.uint8)) \
        == dispatch.shape_bucket(x)


def test_shape_bucket_mixed_args():
    x = jnp.zeros((100, 8), jnp.float32)
    assert dispatch.shape_bucket(x, 5, None) == "float32[128x8],5,None"


# ------------------------------------------------- JSON cache file

def test_tune_cache_json_round_trip(tmp_path, monkeypatch):
    path = str(tmp_path / "tune.json")
    monkeypatch.setenv(dispatch.TUNE_CACHE_ENV, path)
    args = _example_args()
    won = dispatch.tune("rq_decode_stages", [args], backend="xla",
                        timer=_seeded_timer(7))      # save=True default
    (bucket, params), = won.items()
    raw = json.load(open(path))
    assert raw["rq_decode_stages"]["xla"][bucket] == params
    # wipe process state: the file alone must reconstruct the entry
    dispatch.clear_tune_cache()
    assert dispatch.tuned_params("rq_decode_stages", args,
                                 backend="xla") == params


def test_in_process_entries_win_over_file(tmp_path, monkeypatch):
    path = str(tmp_path / "tune.json")
    args = _example_args()
    dispatch.tune("rq_decode_stages", [args], backend="xla",
                  timer=lambda th, it: 1.0, save=False)   # default combo
    dispatch.save_tune_cache(path)
    # file now holds the tie-kept default winner; seed the process
    # with a DIFFERENT winner and check the file does not clobber it
    spec = dispatch.op_tunables("rq_decode_stages")
    other = {p: t.candidates[-1] for p, t in spec.items()}
    dispatch.clear_tune_cache()
    bucket = dispatch.shape_bucket(*args)
    dispatch._TUNED[("rq_decode_stages", "xla", bucket)] = dict(other)
    monkeypatch.setenv(dispatch.TUNE_CACHE_ENV, path)
    assert dispatch.tuned_params("rq_decode_stages", args,
                                 backend="xla") == other


@pytest.mark.parametrize("payload", [
    "{not json",                                   # unparseable
    '["a", "list"]',                               # wrong top-level type
    '{"rq_decode_stages": {"cuda": {"b": {}}}}',   # unknown backend
    '{"rq_decode_stages": {"xla": {"b": 3}}}',     # params not a dict
])
def test_invalid_cache_file_warns_and_defaults(tmp_path, monkeypatch,
                                               payload):
    path = str(tmp_path / "tune.json")
    with open(path, "w") as f:
        f.write(payload)
    monkeypatch.setenv(dispatch.TUNE_CACHE_ENV, path)
    args = _example_args()
    with pytest.warns(RuntimeWarning, match="invalid kernel tune cache"):
        tuned = dispatch.tuned_params("rq_decode_stages", args,
                                      backend="xla")
    assert tuned == {}                    # declared defaults apply
    # and the op still runs end-to-end through dispatch
    out = dispatch.dispatch("rq_decode_stages", *args, backend="xla")
    assert out.shape == (args[0].shape[0], 16)


def test_missing_cache_file_is_silent(tmp_path, monkeypatch):
    monkeypatch.setenv(dispatch.TUNE_CACHE_ENV,
                       str(tmp_path / "never_written.json"))
    import warnings as w
    with w.catch_warnings():
        w.simplefilter("error")
        assert dispatch.tuned_params("rq_decode_stages", _example_args(),
                                     backend="xla") == {}


# ------------------------------------- tuned == default (bit-identity)

@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 300), seed=st.integers(0, 1000))
def test_every_candidate_block_geometry_bit_identical(b, seed):
    """Candidates only change the schedule: on the interpret backend
    (the real kernel body) every block_b/block_d candidate must produce
    the exact same bits as the declared default."""
    from repro.kernels.mgqe_decode import decode_stages
    k = jax.random.PRNGKey(seed)
    codes = jax.random.randint(k, (b, 2), 0, 8).astype(jnp.uint8)
    cbs = jax.random.normal(k, (2, 8, 16))
    spec = dispatch.op_tunables("rq_decode_stages")
    base = np.asarray(decode_stages(codes, cbs, backend="interpret"))
    for bb in spec["block_b"].candidates:
        for bd in spec["block_d"].candidates:
            out = decode_stages(codes, cbs, block_b=bb, block_d=bd,
                                backend="interpret")
            np.testing.assert_array_equal(np.asarray(out), base)


def test_tuned_dispatch_bit_identical_to_default(monkeypatch):
    """Whatever winner lands in the cache, the dispatched op's output
    must not move."""
    from repro.kernels.mgqe_decode import decode_stages
    args = _example_args(b=97)            # ragged on purpose
    base = np.asarray(decode_stages(*args, backend="interpret"))
    dispatch.tune("rq_decode_stages", [args], backend="interpret",
                  timer=_seeded_timer(3), save=False)
    tuned = np.asarray(decode_stages(*args, backend="interpret"))
    np.testing.assert_array_equal(tuned, base)


# --------------------------------------------- dispatch injection

def _probe_op():
    """Throwaway op recording the block value each call receives."""
    seen = []
    dispatch.register_op(
        "autotune_probe",
        pallas=lambda x, block=2: (seen.append(block), x)[1],
        xla=lambda x, block=2: (seen.append(block), x)[1],
        tunables={"block": Tunable(2, (2, 4, 8))},
    )
    return seen


def test_dispatch_injects_tuned_value_only_when_unset():
    seen = _probe_op()
    x = jnp.arange(4.0)
    bucket = dispatch.shape_bucket(x)
    dispatch._TUNED[("autotune_probe", "xla", bucket)] = {"block": 8}
    dispatch.dispatch("autotune_probe", x, backend="xla")
    dispatch.dispatch("autotune_probe", x, block=None, backend="xla")
    dispatch.dispatch("autotune_probe", x, block=4, backend="xla")
    assert seen == [8, 8, 4]              # unset/None resolve, 4 pins


def test_dispatch_falls_back_to_declared_default():
    seen = _probe_op()
    x = jnp.arange(5.0)                   # bucket never tuned
    dispatch.dispatch("autotune_probe", x, backend="xla")
    assert seen == [2]


def test_tune_unknown_op_raises():
    with pytest.raises(KeyError):
        dispatch.tune("not_an_op", [jnp.zeros(3)])
