"""Backend dispatch layer: resolution rules + pallas(interpret) == xla
parity for every serving hot-path op, across dtypes and ragged
(non-multiple-of-block) batch sizes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Embedding, EmbeddingConfig
from repro.kernels import dispatch

PARITY = dict(rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ resolution

def test_registry_has_all_serving_ops():
    ops = dispatch.registered_ops()
    for name in ("mgqe_decode", "embedding_bag", "pq_score", "dpq_assign",
                 "flash_attention"):
        assert name in ops
        assert set(ops[name]) == {"pallas", "xla", "interpret"}


def test_resolve_precedence(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    on_cpu = jax.default_backend() != "tpu"
    # auto falls back to xla off-TPU; so does an unfulfillable pallas ask
    if on_cpu:
        assert dispatch.resolve_backend() == "xla"
        assert dispatch.resolve_backend("pallas") == "xla"
    assert dispatch.resolve_backend("interpret") == "interpret"
    assert dispatch.resolve_backend("xla") == "xla"
    # env var overrides "auto"/unset but not an explicit concrete choice
    monkeypatch.setenv(dispatch.ENV_VAR, "interpret")
    assert dispatch.resolve_backend() == "interpret"
    assert dispatch.resolve_backend("auto") == "interpret"
    assert dispatch.resolve_backend("xla") == "xla"
    monkeypatch.delenv(dispatch.ENV_VAR)
    # process default is lowest precedence; "auto" arg defers to it
    with dispatch.use_backend("interpret"):
        assert dispatch.resolve_backend() == "interpret"
        assert dispatch.resolve_backend("auto") == "interpret"
        assert dispatch.resolve_backend("xla") == "xla"


def test_resolve_rejects_unknown():
    with pytest.raises(ValueError):
        dispatch.resolve_backend("cuda")
    with pytest.raises(ValueError):
        dispatch.set_default_backend("nope")
    with pytest.raises(KeyError):
        dispatch.get_impl("not_an_op")


def test_config_rejects_unknown_backend():
    with pytest.raises(ValueError):
        EmbeddingConfig(vocab_size=10, dim=4, kernel_backend="cuda")


# ------------------------------------------------- mgqe_decode parity

@pytest.mark.parametrize("b", [1, 37, 64, 257])   # ragged + exact blocks
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mgqe_decode_backend_parity(b, dtype):
    from repro.kernels.mgqe_decode import decode
    k = jax.random.PRNGKey(b)
    codes = jax.random.randint(k, (b, 4), 0, 16).astype(jnp.uint8)
    cent = jax.random.normal(k, (4, 16, 8)).astype(dtype)
    out_i = decode(codes, cent, block_b=64, backend="interpret")
    out_x = decode(codes, cent, block_b=64, backend="xla")
    assert out_i.shape == out_x.shape == (b, 32)
    np.testing.assert_allclose(np.asarray(out_i, np.float32),
                               np.asarray(out_x, np.float32), **PARITY)


# ----------------------------------------------- embedding_bag parity

@pytest.mark.parametrize("nnz,bags", [(7, 5), (64, 64), (201, 13)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("weighted", [False, True])
def test_embedding_bag_backend_parity(nnz, bags, dtype, weighted):
    from repro.kernels.embedding_bag import bag
    rng = np.random.default_rng(nnz)
    table = jnp.asarray(rng.normal(size=(50, 8))).astype(dtype)
    ids = jnp.asarray(rng.integers(0, 50, nnz), jnp.int32)
    seg = jnp.asarray(np.sort(rng.integers(0, bags, nnz)), jnp.int32)
    w = (jnp.asarray(rng.uniform(0.5, 2.0, nnz)).astype(dtype)
         if weighted else None)
    out_i = bag(table, ids, seg, bags, w, backend="interpret")
    out_x = bag(table, ids, seg, bags, w, backend="xla")
    assert out_i.shape == out_x.shape == (bags, 8)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else PARITY
    np.testing.assert_allclose(np.asarray(out_i, np.float32),
                               np.asarray(out_x, np.float32), **tol)


# --------------------------------------------------- pq_score parity

@pytest.mark.parametrize("n", [1, 33, 512, 1025])  # ragged + exact blocks
@pytest.mark.parametrize("cdtype", [jnp.uint8, jnp.int32])
def test_pq_score_backend_parity(n, cdtype):
    from repro.kernels import dispatch as dp
    k = jax.random.PRNGKey(n)
    codes = jax.random.randint(k, (n, 8), 0, 32).astype(cdtype)
    lut = jax.random.normal(k, (8, 32))
    out_i = dp.dispatch("pq_score", lut, codes.astype(jnp.int32),
                        block_n=512, backend="interpret")
    out_x = dp.dispatch("pq_score", lut, codes.astype(jnp.int32),
                        block_n=512, backend="xla")
    assert out_i.shape == out_x.shape == (n,)
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_x),
                               **PARITY)


def test_score_candidates_backend_kwarg():
    from repro.kernels.pq_score import score_candidates
    k = jax.random.PRNGKey(0)
    cent = jax.random.normal(k, (4, 8, 4))
    codes = jax.random.randint(k, (100, 4), 0, 8)
    q = jax.random.normal(jax.random.PRNGKey(1), (16,))
    a = score_candidates(q, cent, codes, block_n=32, backend="interpret")
    b = score_candidates(q, cent, codes, block_n=32, backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **PARITY)


# -------------------------------------------------- dpq_assign parity

@pytest.mark.parametrize("b", [1, 100, 513])
def test_dpq_assign_backend_parity(b):
    from repro.kernels.dpq_assign import assign
    k = jax.random.PRNGKey(b)
    e = jax.random.normal(k, (b, 4, 8))
    cent = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 8))
    klim = jax.random.randint(jax.random.PRNGKey(2), (b,), 1, 17)
    for lim in (None, klim):
        out_i = assign(e, cent, lim, block_b=128, backend="interpret")
        out_x = assign(e, cent, lim, block_b=128, backend="xla")
        np.testing.assert_array_equal(np.asarray(out_i), np.asarray(out_x))


# ------------------------------------------- Embedding.serve invariance

def _serve_cfgs():
    common = dict(vocab_size=120, dim=16, num_subspaces=4, num_centroids=8,
                  decode_block_b=32)
    return [
        EmbeddingConfig(kind="dpq", **common),
        EmbeddingConfig(kind="mgqe", tier_boundaries=(12,),
                        tier_num_centroids=(8, 4), **common),
        EmbeddingConfig(kind="mgqe", mgqe_variant="private_k",
                        tier_boundaries=(12,), tier_num_centroids=(8, 4),
                        **common),
        EmbeddingConfig(kind="mgqe", mgqe_variant="private_d",
                        tier_boundaries=(12,), tier_num_subspaces=(4, 2),
                        **common),
    ]


@pytest.mark.parametrize("cfg", _serve_cfgs(),
                         ids=lambda c: f"{c.kind}-{c.mgqe_variant}")
def test_embedding_serve_invariant_across_backends(cfg):
    """serve() output must be bitwise-comparable (1e-5) under every
    backend — the dispatch layer must never change model outputs."""
    ids = jnp.asarray([[0, 5, 11], [12, 63, 119]])   # ragged B=6 decode
    outs = {}
    for be in ("xla", "interpret", "auto", "pallas"):
        emb = Embedding(dataclasses.replace(cfg, kernel_backend=be))
        params = emb.init(jax.random.PRNGKey(0))
        art = emb.export(params)
        outs[be] = np.asarray(emb.serve(art, ids))
        assert outs[be].shape == (2, 3, 16)
    for be, out in outs.items():
        np.testing.assert_allclose(out, outs["xla"], err_msg=be, **PARITY)


def test_embedding_serve_respects_env_override(monkeypatch):
    """REPRO_KERNEL_BACKEND must steer a default ("auto") config
    end-to-end through Embedding.serve."""
    calls = {}
    orig = dispatch.get_impl

    def spy(name, backend=None):
        calls.setdefault(name, []).append(dispatch.resolve_backend(backend))
        return orig(name, backend)

    cfg = _serve_cfgs()[0]                       # kernel_backend="auto"
    emb = Embedding(cfg)
    params = emb.init(jax.random.PRNGKey(0))
    art = emb.export(params)
    monkeypatch.setenv(dispatch.ENV_VAR, "interpret")
    monkeypatch.setattr(dispatch, "get_impl", spy)
    emb.serve(art, jnp.arange(8))
    assert "interpret" in calls.get("mgqe_decode", [])


# ---------------------------------------- fields bag through dispatch

@pytest.mark.parametrize("mode", ["sum", "mean", "max"])
def test_fields_embedding_bag_backend_parity(mode):
    from repro.models.recsys.fields import embedding_bag
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.normal(size=(30, 8)), jnp.float32)
    nnz = 23                                          # ragged
    ids = jnp.asarray(rng.integers(0, 30, nnz), jnp.int32)
    seg = jnp.asarray(np.sort(rng.integers(0, 7, nnz)), jnp.int32)
    a = embedding_bag(table, ids, seg, 7, mode=mode, backend="interpret")
    b = embedding_bag(table, ids, seg, 7, mode=mode, backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **PARITY)
