"""Launcher-level tests: microbatch equivalence, registry coverage."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, SHAPE_SKIPS, all_cells, shapes_for


def test_registry_covers_assignment():
    assert len(ARCHS) == 10
    total = sum(len(shapes_for(a)) for a in ARCHS)
    assert total == 40                         # 40 assigned cells
    runnable = list(all_cells())
    assert len(runnable) == 38                 # 2 documented skips
    assert set(SHAPE_SKIPS) == {("stablelm-3b", "long_500k"),
                                ("qwen3-moe-30b-a3b", "long_500k")}


@pytest.mark.slow
def test_grad_accumulation_matches_full_batch():
    """The microbatchN train step must produce the same update as the
    full-batch step (linearity of gradients)."""
    from repro.configs.registry import get_arch
    from repro.models import lm
    from repro.train import optimizer as opt_lib
    from repro.train.optimizer import TrainState

    _, cfg = get_arch("stablelm-3b", smoke=True)
    ocfg = opt_lib.OptimizerConfig(kind="adamw", lr=1e-3, grad_clip=None)
    loss_fn = functools.partial(lm.loss_fn, cfg=cfg)
    params = lm.model_init(jax.random.PRNGKey(0), cfg)

    k = jax.random.PRNGKey(1)
    toks = jax.random.randint(k, (4, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # full-batch reference
    ref_state = TrainState.create(ocfg, params)
    (ref_loss, _), ref_grads = jax.value_and_grad(
        loss_fn, has_aux=True)(ref_state.params, batch)

    # microbatch=2 accumulation (mirrors cells.lm_train_cell)
    def accum(params, batch, m):
        split = jax.tree.map(
            lambda v: v.reshape((m, v.shape[0] // m) + v.shape[1:]), batch)

        def one(carry, mb):
            gsum, lsum = carry
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (gsum, lsum), _ = jax.lax.scan(one, (zeros, jnp.float32(0)), split)
        return (jax.tree.map(lambda g: g / m, gsum), lsum / m)

    grads2, loss2 = accum(ref_state.params, batch, 2)
    np.testing.assert_allclose(float(ref_loss), float(loss2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(ref_grads), jax.tree.leaves(grads2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)
