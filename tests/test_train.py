"""Training substrate: optimizers, checkpoint/restore, fault tolerance,
straggler detection, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck
from repro.train import optimizer as opt_lib
from repro.train.compression import (dequantize, init_error_state,
                                     quantize_int8)
from repro.train.loop import LoopConfig, fit
from repro.train.optimizer import TrainState
from repro.train.resilience import (FailureInjector, SimulatedFailure,
                                    StragglerDetector)


def _quad_problem():
    params = {"w": jnp.asarray([2.0, -3.0]), "b": jnp.asarray(1.0)}

    def loss_fn(p, batch):
        l = jnp.sum(p["w"] ** 2) + p["b"] ** 2
        return l, {"loss": l}
    return params, loss_fn


def test_adam_matches_reference_formula():
    params, loss_fn = _quad_problem()
    cfg = opt_lib.OptimizerConfig(kind="adam", lr=0.1, grad_clip=None)
    state = TrainState.create(cfg, params)
    step = opt_lib.make_step_fn(cfg, loss_fn)
    new_state, _ = step(state, {})
    # reference: g = 2w; m=(1-b1)g; v=(1-b2)g^2; update = lr*mhat/(sqrt(vhat)+eps)
    g = 2 * np.asarray([2.0, -3.0])
    mhat = g
    vhat = g ** 2
    expected = np.asarray([2.0, -3.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_state.params["w"]), expected,
                               rtol=1e-5)


def test_grad_clip_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}           # norm 5
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               rtol=1e-5)


@pytest.mark.parametrize("kind", ["adam", "adamw", "adagrad", "sgd"])
def test_optimizers_descend(kind):
    params, loss_fn = _quad_problem()
    cfg = opt_lib.OptimizerConfig(kind=kind, lr=0.05)
    state = TrainState.create(cfg, params)
    step = jax.jit(opt_lib.make_step_fn(cfg, loss_fn))
    l0 = float(loss_fn(state.params, {})[0])
    for _ in range(120):
        state, _ = step(state, {})
    assert float(loss_fn(state.params, {})[0]) < l0 * 0.5


def test_lr_schedule_warmup_cosine():
    cfg = opt_lib.OptimizerConfig(lr=1.0, schedule="linear_warmup_cosine",
                                  warmup_steps=10, total_steps=100,
                                  min_lr_frac=0.1)
    lr0 = float(opt_lib.schedule_lr(cfg, jnp.asarray(0)))
    lr9 = float(opt_lib.schedule_lr(cfg, jnp.asarray(9)))
    lr_end = float(opt_lib.schedule_lr(cfg, jnp.asarray(99)))
    assert lr0 < lr9 <= 1.0
    assert abs(lr_end - 0.1) < 0.02


# ------------------------------------------------------------ checkpoint

def test_checkpoint_roundtrip(tmp_path):
    params, loss_fn = _quad_problem()
    cfg = opt_lib.OptimizerConfig(kind="adam", lr=0.1)
    state = TrainState.create(cfg, params)
    ck.save(str(tmp_path), 7, state, keep=2)
    restored, step = ck.restore_latest(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_policy(tmp_path):
    params, _ = _quad_problem()
    cfg = opt_lib.OptimizerConfig()
    state = TrainState.create(cfg, params)
    for s in (1, 2, 3, 4):
        ck.save(str(tmp_path), s, state, keep=2)
    _, step = ck.restore_latest(str(tmp_path), state)
    assert step == 4
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) == 2


def test_checkpoint_corruption_detected(tmp_path):
    params, _ = _quad_problem()
    cfg = opt_lib.OptimizerConfig()
    state = TrainState.create(cfg, params)
    ck.save(str(tmp_path), 1, state, keep=2)
    ck.save(str(tmp_path), 2, state, keep=2)
    # corrupt the newest checkpoint payload
    d2 = os.path.join(tmp_path, "step_00000002")
    for f in os.listdir(d2):
        if f.endswith(".npz"):
            with open(os.path.join(d2, f), "wb") as fh:
                fh.write(b"garbage")
    restored, step = ck.restore_latest(str(tmp_path), state)
    assert step == 1                     # falls back to the older valid one


# -------------------------------------------------------- fault tolerance

def test_crash_restart_resumes_and_converges(tmp_path):
    """Inject a crash mid-run; a relaunch must resume from the last
    checkpoint and reach the same final state as an uninterrupted run."""
    params, loss_fn = _quad_problem()
    ocfg = opt_lib.OptimizerConfig(kind="sgd", lr=0.05, grad_clip=None)
    step_fn = opt_lib.make_step_fn(ocfg, loss_fn)

    def data():
        while True:
            yield {}

    lcfg = LoopConfig(total_steps=20, log_every=100, ckpt_every=5,
                      ckpt_dir=str(tmp_path))
    fresh = lambda: TrainState.create(       # donation-safe: new arrays
        ocfg, jax.tree.map(jnp.array, params))
    # run 1: crash at step 12 (after the step-10 checkpoint)
    inj = FailureInjector(fail_at_steps=[12])
    with pytest.raises(SimulatedFailure):
        fit(fresh(), step_fn, data(), lcfg, injector=inj)
    # run 2: auto-resume to completion
    final, hist = fit(fresh(), step_fn, data(), lcfg)
    assert int(final.step) == 20
    # uninterrupted reference
    ref = fresh()
    jit_step = jax.jit(step_fn)
    for _ in range(20):
        ref, _ = jit_step(ref, {})
    np.testing.assert_allclose(np.asarray(final.params["w"]),
                               np.asarray(ref.params["w"]), rtol=1e-5)


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(num_hosts=4, threshold=1.8, patience=5)
    rng = np.random.default_rng(0)
    reports = []
    for _ in range(50):
        for h in range(4):
            dt = 1.0 + 0.01 * rng.standard_normal()
            if h == 2:
                dt *= 3.0                        # host 2 is slow
            det.record(h, dt)
        reports = det.check()
    assert [r.host for r in reports] == [2]
    assert reports[0].ratio > 1.8


def test_straggler_detector_recovers():
    det = StragglerDetector(num_hosts=4, threshold=1.5, patience=2)
    for _ in range(20):
        for h in range(4):
            det.record(h, 5.0 if h == 3 else 1.0)
        det.check()
    assert [r.host for r in det.check()] == [3]
    for _ in range(60):                          # host 3 recovers
        for h in range(4):
            det.record(h, 1.0)
        det.check()
    assert det.check() == []


# ----------------------------------------------------- grad compression

def test_int8_compression_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)) * 0.01, jnp.float32)
    q, scale = quantize_int8(g)
    d = dequantize(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(d - g))) <= float(scale) + 1e-8


def test_error_feedback_residual_unbiased():
    """Error feedback: the time-average of dequantized sends converges
    to the true gradient even when one step's quantization is biased."""
    g = jnp.full((64,), 0.003, jnp.float32)
    err = init_error_state(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        g32 = g + err
        q, scale = quantize_int8(g32)
        deq = dequantize(q, scale)
        err = g32 - deq
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc) / 50, np.asarray(g),
                               rtol=0.02)


def test_compressed_psum_mean_single_device():
    """shard_map'd compressed all-reduce on a 1-device mesh: the mean
    must equal the (dequantized) local gradient."""
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P
    from repro.sharding.compat import shard_map
    from repro.train.compression import compressed_psum_mean

    mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(16,)),
                          jnp.float32)}
    err = init_error_state(g)

    def f(g, e):
        return compressed_psum_mean(g, e, "dp")

    out, new_err = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check=False))(g, err)
    q, scale = quantize_int8(g["w"])
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(dequantize(q, scale)), rtol=1e-6)
