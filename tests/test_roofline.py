"""Roofline machinery: loop-weighted HLO analysis + term computation,
parser edge cases (malformed/partial HLO text must degrade, never
raise), and the per-kernel achieved-vs-peak helper the bench gate
uses (DESIGN.md §11)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import (PEAK_FLOPS_BF16, analyze, kernel_roofline,
                            terms_from_hlo)


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_weighted_by_trip_count():
    W = jnp.ones((8, 128, 128))
    x0 = jnp.ones((4, 128))

    def scanned(x, W):
        def body(c, w):
            return c @ w, None
        return jax.lax.scan(body, x, W)[0]

    def unrolled(x, W):
        for i in range(8):
            x = x @ W[i]
        return x

    cs = analyze(_compiled_text(scanned, x0, W))
    cu = analyze(_compiled_text(unrolled, x0, W))
    expected = 8 * 2 * 4 * 128 * 128
    assert abs(cs.flops - expected) / expected < 0.01
    assert abs(cu.flops - expected) / expected < 0.01
    assert not cs.warnings


def test_nested_scan_weighting():
    W = jnp.ones((3, 4, 64, 64))
    x0 = jnp.ones((2, 64))

    def nested(x, W):
        def outer(c, w_group):
            def inner(cc, w):
                return cc @ w, None
            return jax.lax.scan(inner, c, w_group)[0], None
        return jax.lax.scan(outer, x, W)[0]

    c = analyze(_compiled_text(nested, x0, W))
    expected = 12 * 2 * 2 * 64 * 64
    assert abs(c.flops - expected) / expected < 0.02


def test_dot_general_batched_flops():
    a = jnp.ones((8, 32, 16))
    b = jnp.ones((8, 16, 24))
    c = analyze(_compiled_text(lambda a, b: jnp.einsum("bik,bkj->bij", a, b),
                               a, b))
    expected = 2 * 8 * 32 * 24 * 16
    assert abs(c.flops - expected) / expected < 0.01


def test_collective_bytes_from_handwritten_hlo():
    hlo = """
HloModule test

ENTRY %main (p0: f32[128,4]) -> f32[128,4] {
  %p0 = f32[128,4]{1,0} parameter(0)
  %ar = f32[128,4]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%sum
  ROOT %cp = f32[128,4]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    c = analyze(hlo)
    assert c.collective_by_kind.get("all-reduce") == 128 * 4 * 4
    assert c.collective_by_kind.get("collective-permute") == 128 * 4 * 4
    assert c.collective_counts == {"all-reduce": 1, "collective-permute": 1}


def test_collectives_inside_while_weighted():
    """A psum inside a scanned body must count once per iteration."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.sharding.compat import shard_map

    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))

    def inner(xs):
        def body(c, x):
            return c + jax.lax.psum(x, "x"), None
        return jax.lax.scan(body, jnp.zeros((64,)), xs)[0]

    # check=False: the scan carry's replication type flips under psum,
    # which strict replication checking rejects on a 1-device mesh
    f = shard_map(inner, mesh=mesh, in_specs=P(None, None), out_specs=P(),
                  check=False)
    txt = jax.jit(f).lower(jnp.ones((5, 64))).compile().as_text()
    c = analyze(txt)
    # 5 iterations x 64 f32 = 1280 bytes (if XLA keeps the psum; on a
    # 1-device mesh it may elide it — accept 0 or the weighted value)
    ar = c.collective_by_kind.get("all-reduce", 0)
    assert ar in (0, 5 * 64 * 4)


def test_terms_and_dominance():
    class FakeCost:
        flops = 197e12          # exactly 1s of compute on one chip
        bytes = 819e9 / 2       # 0.5s of HBM
        collective_bytes = 50e9 * 2   # 2s of ICI
        collective_by_kind = {}
        collective_counts = {}
        warnings = []

    t = terms_from_hlo(FakeCost(), chips=1, model_flops=197e12 / 2)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 0.5) < 1e-9
    assert abs(t.collective_s - 2.0) < 1e-9
    assert t.dominant == "collective"
    assert abs(t.useful_fraction - 0.5) < 1e-9
    # roofline fraction: ideal 0.5s of useful compute / 2s bound = 0.25
    assert abs(t.roofline_fraction - 0.25) < 1e-9


def test_tpu_fusion_mode_drops_convert_fusions():
    """analyze(tpu_fusion=True) must charge convert-only fusions zero
    (CPU backend emulates bf16 in f32; TPU is native)."""
    x = jnp.ones((256, 256), jnp.bfloat16)

    def f(x):
        return (x.astype(jnp.float32) @ x.astype(jnp.float32).T
                ).astype(jnp.bfloat16)

    txt = _compiled_text(f, x)
    raw = analyze(txt)
    cal = analyze(txt, tpu_fusion=True)
    assert cal.bytes <= raw.bytes
    assert cal.flops == raw.flops           # flops unaffected


# ------------------------------------------------ parser edge cases

@pytest.mark.parametrize("text", [
    "",                                        # empty module text
    "HloModule empty\n",                       # header, no computations
    "not hlo at all\n{}\nrandom noise",        # garbage
])
def test_analyze_empty_or_garbage_text_degrades(text):
    """No computations -> zero cost + a warning, never an exception."""
    c = analyze(text)
    assert c.flops == 0 and c.bytes == 0 and c.collective_bytes == 0
    assert c.warnings == ["no entry computation found"]


def test_analyze_unparseable_shape_strings_skipped():
    """Ops whose type strings don't parse (opaque/token/custom dtypes)
    contribute zero bytes instead of crashing the sweep."""
    hlo = """
HloModule weird

ENTRY %main (p0: f32[8,4]) -> f32[8,4] {
  %p0 = f32[8,4]{1,0} parameter(0)
  %tok = token[] after-all()
  %oc = opaque[] custom-call(%p0), custom_call_target="noop"
  %strange = mystery[8,?]{1,0} add(%p0, %p0)
  ROOT %out = f32[8,4]{1,0} add(%p0, %p0)
}
"""
    c = analyze(hlo)                 # must not raise
    # the well-formed root add still counts: 2 operands + 1 output
    assert c.bytes >= 3 * 8 * 4 * 4
    assert c.flops >= 8 * 4


def test_analyze_fusion_with_multiply_shapes():
    """A fusion op charges operands + outputs once (innards excluded),
    including tuple-shaped fusion outputs."""
    hlo = """
HloModule fused

%fused_computation (a: f32[16,8], b: f32[16,8]) -> f32[16,8] {
  %a = f32[16,8]{1,0} parameter(0)
  %b = f32[16,8]{1,0} parameter(1)
  %m = f32[16,8]{1,0} multiply(%a, %b)
  ROOT %s = f32[16,8]{1,0} add(%m, %b)
}

ENTRY %main (p0: f32[16,8], p1: f32[16,8]) -> (f32[16,8], f32[16,8]) {
  %p0 = f32[16,8]{1,0} parameter(0)
  %p1 = f32[16,8]{1,0} parameter(1)
  %f = f32[16,8]{1,0} fusion(%p0, %p1), kind=kLoop, calls=%fused_computation
  ROOT %t = (f32[16,8]{1,0}, f32[16,8]{1,0}) tuple(%f, %p1)
}
"""
    c = analyze(hlo)
    n = 16 * 8 * 4
    # fusion: 2 operand reads + 1 output write; tuple is free; the
    # multiply/add INSIDE the fusion body add flops but no bytes
    assert c.bytes == 3 * n
    assert c.flops == 2 * 16 * 8


def test_analyze_while_without_trip_count_warns_once():
    hlo = """
HloModule loopy

%body (x: f32[4]) -> f32[4] {
  ROOT %x = f32[4]{0} parameter(0)
}

%cond (x: f32[4]) -> pred[] {
  %x = f32[4]{0} parameter(0)
  ROOT %p = pred[] constant(false)
}

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  ROOT %w = f32[4]{0} while(%p0), condition=%cond, body=%body
}
"""
    c = analyze(hlo)
    assert any("unknown trip count" in w for w in c.warnings)


# ------------------------------------- kernel_roofline (bench gate)

def test_kernel_roofline_fraction_in_unit_interval():
    x = jnp.ones((256, 64))
    txt = jax.jit(lambda x: x @ x.T).lower(x).compile().as_text()
    out = kernel_roofline(txt, measured_s=1e-3)
    f = out["roofline_fraction"]
    assert f is not None and 0.0 < f <= 1.0
    assert out["bound_ms"] > 0
    assert out["bound_kind"] in ("compute", "memory", "collective")


def test_kernel_roofline_clamps_at_one():
    """A measured time below the hardware bound clamps to exactly 1.0
    (the gate treats >1 as a measurement artifact, not an achievement)."""
    x = jnp.ones((512, 512))
    txt = jax.jit(lambda x: x @ x).lower(x).compile().as_text()
    assert kernel_roofline(txt, measured_s=1e-12)["roofline_fraction"] == 1.0


def test_kernel_roofline_degenerate_inputs():
    assert kernel_roofline("", measured_s=1e-3)["roofline_fraction"] is None
    x = jnp.ones((16, 16))
    txt = jax.jit(lambda x: x + x).lower(x).compile().as_text()
    assert kernel_roofline(txt, measured_s=0.0)["roofline_fraction"] is None


def test_bench_entries_carry_roofline_fraction():
    """Quick-mode bench functions must attach roofline_fraction ∈ (0, 1]
    to every kernel entry — the invariant the bench exit code gates."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "benchmarks"))
    try:
        import kernel_bench
    finally:
        sys.path.pop(0)
    results = {}
    # tiny shapes: seconds, not the bench's minutes
    kernel_bench.bench_rq_decode(results, n=2048, d=16, M=2, K=8,
                                 batch=256)
    kernel_bench.bench_adc(results, d=16, D=4, K=8, n_cand=2048)
    kernel_bench.bench_dpq_assign(results, d=16, D=4, K=8, b=512)
    for name in ("rq_decode", "adc", "dpq_assign"):
        f = results[name]["roofline_fraction"]
        assert f is not None and 0.0 < f <= 1.0, (name, f)
    assert results["rq_decode"]["parity_ok"]
    assert "speedup_ok" in results["rq_decode"]
    assert results["rq_decode"]["tuned_block_b"] in (64, 128, 256, 512)


def test_remat_recompute_visible_in_flops():
    """jax.checkpoint recompute inside a scan shows up as extra counted
    FLOPs (what useful_frac is designed to catch).  The scan stops XLA
    from CSE-ing the recompute away."""
    W = jnp.ones((4, 64, 64))
    x = jnp.ones((32, 64))

    def make(remat):
        def body(c, w):
            f = lambda c: jnp.tanh(c @ w) @ w
            if remat:
                f = jax.checkpoint(f)
            return f(c), None

        def loss(x, W):
            y, _ = jax.lax.scan(body, x, W)
            return jnp.sum(y)
        return loss

    g_plain = analyze(_compiled_text(jax.grad(make(False)), x, W))
    g_remat = analyze(_compiled_text(jax.grad(make(True)), x, W))
    assert g_remat.flops >= g_plain.flops * 1.1
