"""Property tests for the attention substrate (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.nn import attention as attn
from repro.nn.rope import apply_rope


@given(
    s=st.sampled_from([8, 16, 24, 32]),
    n_kv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2]),
    window=st.sampled_from([4, 8, 1 << 30]),
    block=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=25, deadline=None)
def test_chunked_equals_dense_attention(s, n_kv, g, window, block, seed):
    """Online-softmax KV chunking is exact for every (window, block)."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    hd = 8
    q = jax.random.normal(ks[0], (2, s, n_kv * g, hd))
    kk = jax.random.normal(ks[1], (2, s, n_kv, hd))
    v = jax.random.normal(ks[2], (2, s, n_kv, hd))
    pos = jnp.arange(s)
    dense = attn.dense_attention(q, kk, v, pos, pos, jnp.int32(window))
    chunk = attn.chunked_attention(q, kk, v, pos, pos, jnp.int32(window),
                                   block=block)
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(chunk, np.float32),
                               rtol=2e-4, atol=2e-5)


@given(seed=st.integers(0, 10_000),
       theta=st.sampled_from([1e4, 1e6]))
@settings(max_examples=20, deadline=None)
def test_rope_preserves_norm_and_identity_at_zero(seed, theta):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (1, 4, 2, 16))
    pos = jnp.arange(4)
    y = apply_rope(x, pos, jnp.float32(theta))
    # rotation preserves per-pair norms -> whole-vector norm
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # position 0 -> identity
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(x[:, 0]),
                               atol=1e-6)


@given(seed=st.integers(0, 10_000),
       cache_len=st.sampled_from([4, 8]),
       n_steps=st.integers(1, 12))
@settings(max_examples=20, deadline=None)
def test_ring_cache_holds_last_window(seed, cache_len, n_steps):
    """After n writes, the ring cache holds exactly the last
    min(n, cache_len) tokens at slot token%cache_len."""
    k_cache = jnp.zeros((1, cache_len, 1, 4))
    v_cache = jnp.zeros((1, cache_len, 1, 4))
    kpos = jnp.full((1, cache_len), -1, jnp.int32)
    rng = jax.random.PRNGKey(seed)
    written = {}
    for t in range(n_steps):
        rng, sub = jax.random.split(rng)
        k_new = jax.random.normal(sub, (1, 1, 1, 4))
        k_cache, v_cache, kpos = attn.cache_update(
            k_cache, v_cache, kpos, k_new, k_new, jnp.int32(t))
        written[t] = np.asarray(k_new[0, 0])
    live = [t for t in range(n_steps) if t >= n_steps - cache_len]
    for t in live:
        slot = t % cache_len
        assert int(kpos[0, slot]) == t
        np.testing.assert_allclose(np.asarray(k_cache[0, slot]),
                                   written[t], atol=1e-6)


@given(seed=st.integers(0, 10_000), s=st.sampled_from([6, 10, 16]),
       cache_len=st.sampled_from([4, 8, 16]))
@settings(max_examples=20, deadline=None)
def test_cache_from_prefill_layout(seed, s, cache_len):
    """cache_from_prefill lays token t at slot t % cache_len and keeps
    only the newest cache_len tokens."""
    k = jax.random.normal(jax.random.PRNGKey(seed), (1, s, 1, 4))
    pos = jnp.arange(s)
    k_c, v_c, kp = attn.cache_from_prefill(k, k, pos, cache_len)
    assert k_c.shape[1] == cache_len
    for t in range(max(0, s - cache_len), s):
        slot = t % cache_len
        assert int(kp[0, slot]) == t
        np.testing.assert_allclose(np.asarray(k_c[0, slot]),
                                   np.asarray(k[0, t]), atol=1e-6)
