"""Per-LM-arch smoke tests (reduced configs) + decode/prefill parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_arch
from repro.models import lm

LM_ARCHS = [a for a, (fam, _) in ARCHS.items() if fam == "lm"]


def _batch(cfg, b=2, s=32, seed=0):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (b, s + 1), 0, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    _, cfg = get_arch(arch, smoke=True)
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = lm.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    grads = jax.grad(lambda p: lm.loss_fn(p, batch, cfg)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat), arch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_output_shapes(arch):
    _, cfg = get_arch(arch, smoke=True)
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    h, aux, _ = lm.forward(params, jnp.zeros((b, s), jnp.int32), cfg)
    assert h.shape == (b, s, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, np.float32)))


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_decode_matches_forward(arch):
    """Greedy decode after prefill must match the full-sequence forward
    logits at every position (cache correctness).

    MoE archs: capacity raised so no token drops — capacity-based
    dispatch is batch-dependent by design, which would make forward
    (24 competing tokens) and decode (1 token) legitimately differ."""
    import dataclasses
    _, cfg = get_arch(arch, smoke=True)
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=64.0)
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    b, s_prompt, s_total = 2, 8, 12
    k = jax.random.PRNGKey(1)
    toks = jax.random.randint(k, (b, s_total), 0, cfg.vocab_size)

    # reference: full forward over s_total tokens
    h, _, _ = lm.forward(params, toks, cfg)
    ref_logits = np.asarray(
        (h @ params["lm_head"].astype(h.dtype)).astype(jnp.float32))

    # prefill on the prompt, then feed tokens one by one
    cache, logits = lm.prefill(params, toks[:, :s_prompt], cfg,
                               max_seq=s_total)
    np.testing.assert_allclose(np.asarray(logits),
                               ref_logits[:, s_prompt - 1], rtol=2e-2,
                               atol=2e-2)
    for t in range(s_prompt, s_total):
        cache, logits = lm.decode_step(params, cache, toks[:, t], cfg)
        np.testing.assert_allclose(np.asarray(logits), ref_logits[:, t],
                                   rtol=2e-2, atol=2e-2)


def test_lm_sliding_window_restricts_attention():
    """A token beyond the window must not influence the current logits
    in a SINGLE-layer windowed model (multi-layer receptive fields grow
    by one window per layer, so depth must be 1 for a sharp test)."""
    import dataclasses
    _, cfg = get_arch("mixtral-8x7b", smoke=True)
    if cfg.sliding_window is None:
        pytest.skip("smoke config lost its window")
    # depth 1 for a sharp receptive field; huge MoE capacity so expert
    # slot competition can't couple tokens across the window
    cfg = dataclasses.replace(cfg, num_layers=1, moe_capacity_factor=64.0)
    w = cfg.sliding_window
    s = w + 4
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    t1 = jnp.zeros((1, s), jnp.int32)
    t2 = t1.at[0, 0].set(1)
    h1, _, _ = lm.forward(params, t1, cfg)
    h2, _, _ = lm.forward(params, t2, cfg)
    # last position attends [s-w, s): token 0 invisible
    np.testing.assert_allclose(np.asarray(h1[:, -1], np.float32),
                               np.asarray(h2[:, -1], np.float32),
                               rtol=1e-4, atol=1e-4)


def test_lm_serving_embed_artifact_path():
    """Decode with the quantized artifact (paper Fig 1) stays close to
    the training-path decode (STE forward == decode by construction)."""
    _, cfg = get_arch("stablelm-3b", smoke=True)
    from repro.core import Embedding
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    emb = Embedding(cfg.embedding)
    artifact = emb.export(params["embed"])
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                              cfg.vocab_size)
    c1, l1 = lm.prefill(params, toks, cfg, max_seq=10)
    c2, l2 = lm.prefill(params, toks, cfg, max_seq=10,
                        embed_artifact=artifact)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-2,
                               atol=2e-2)


def test_gemma3_pattern_layout():
    """5:1 pattern stacks: L layers -> g groups of (5 loc + 1 glob) +
    (L mod 6) remainder local layers."""
    _, cfg = get_arch("gemma3-4b", smoke=True)
    p = cfg.local_global_pattern
    g, r = cfg.num_layers // (p + 1), cfg.num_layers % (p + 1)
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    assert params["loc"]["wq"].shape[:2] == (g, p)
    assert params["glob"]["wq"].shape[0] == g
    if r:
        assert params["rem"]["wq"].shape[0] == r


@pytest.mark.slow
def test_split_cache_decode_matches_uniform_cache():
    """Beyond-paper split local/global cache must be numerically
    identical to the uniform max-length cache."""
    import dataclasses
    _, cfg = get_arch("gemma3-4b", smoke=True)
    cfg_split = dataclasses.replace(cfg, split_local_global_cache=True)
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    s_prompt, s_total = 10, 14
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, s_total), 0,
                              cfg.vocab_size)
    c1, l1 = lm.prefill(params, toks[:, :s_prompt], cfg, max_seq=s_total)
    c2, l2 = lm.prefill(params, toks[:, :s_prompt], cfg_split,
                        max_seq=s_total)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-3,
                               atol=1e-3)
    for t in range(s_prompt, s_total):
        c1, l1 = lm.decode_step(params, c1, toks[:, t], cfg)
        c2, l2 = lm.decode_step(params, c2, toks[:, t], cfg_split)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-3, atol=1e-3)


def test_chunked_attention_matches_dense():
    import dataclasses
    _, cfg = get_arch("stablelm-3b", smoke=True)
    cfg_d = dataclasses.replace(cfg, attention_impl="dense")
    cfg_c = dataclasses.replace(cfg, attention_impl="chunked",
                                attention_block=8)
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 24), 0,
                              cfg.vocab_size)
    h1, _, _ = lm.forward(params, toks, cfg_d)
    h2, _, _ = lm.forward(params, toks, cfg_c)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_moe_capacity_drops_tokens_gracefully():
    """With a tiny capacity factor most tokens drop — outputs must stay
    finite and the dropped tokens contribute zero (not garbage)."""
    import dataclasses
    from repro.nn import moe as moe_lib
    p = moe_lib.moe_init(jax.random.PRNGKey(0), 16, 32, 4)
    # enough tokens that the min-capacity floor (8) actually drops most
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    out_lo, aux = moe_lib.moe_ffn(p, x, top_k=2, capacity_factor=0.01)
    assert np.all(np.isfinite(np.asarray(out_lo)))
    out_hi, _ = moe_lib.moe_ffn(p, x, top_k=2, capacity_factor=64.0)
    # tiny capacity must zero out more of the output mass
    assert float(jnp.sum(jnp.abs(out_lo))) < float(jnp.sum(jnp.abs(out_hi)))


def test_kv_repeat_forward_identical():
    """KV-head replication is a pure layout change — forward values
    must be bit-identical."""
    import dataclasses
    _, cfg = get_arch("mixtral-8x7b", smoke=True)
    cfg2 = dataclasses.replace(cfg, attn_kv_repeat=True)
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    h1, _, _ = lm.forward(params, toks, cfg)
    h2, _, _ = lm.forward(params, toks, cfg2)
    np.testing.assert_array_equal(np.asarray(h1, np.float32),
                                  np.asarray(h2, np.float32))


@pytest.mark.slow
def test_group_remat_matches_layer_remat():
    """Remat granularity changes memory, never values or gradients."""
    import dataclasses
    _, cfg = get_arch("stablelm-3b", smoke=True)
    cfg_l = dataclasses.replace(cfg, remat=True, remat_granularity="layer")
    cfg_g = dataclasses.replace(cfg, remat=True, remat_granularity="group",
                                remat_block=2)
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, b=2, s=16)
    l1, _ = lm.loss_fn(params, batch, cfg_l)
    l2, _ = lm.loss_fn(params, batch, cfg_g)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    g1 = jax.grad(lambda p: lm.loss_fn(p, batch, cfg_l)[0])(params)
    g2 = jax.grad(lambda p: lm.loss_fn(p, batch, cfg_g)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_moe_aux_loss_and_balance():
    _, cfg = get_arch("qwen3-moe-30b-a3b", smoke=True)
    params = lm.model_init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, b=2, s=16)
    loss, metrics = lm.loss_fn(params, batch, cfg)
    # Switch load-balance loss >= 1 (equality at perfect balance)
    assert float(metrics["aux"]) >= 0.9
