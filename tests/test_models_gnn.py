"""MACE: smoke + physical invariants (translation/rotation/permutation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.models.gnn import so3
from repro.models.gnn.mace import MACE, bessel_basis


def _graph(n=12, e=30, n_species=10, seed=0, d_feat=0):
    rng = np.random.default_rng(seed)
    g = {
        "positions": jnp.asarray(rng.normal(size=(n, 3)) * 2, jnp.float32),
        "edge_index": jnp.asarray(
            np.stack([rng.integers(0, n, e), rng.integers(0, n, e)]),
            jnp.int32),
        "species": jnp.asarray(rng.integers(0, n_species, n), jnp.int32),
        "graph_id": jnp.zeros(n, jnp.int32),
        "n_graphs": 1,
        "energy": jnp.ones(1, jnp.float32),
    }
    if d_feat:
        g["node_feats"] = jnp.asarray(rng.normal(size=(n, d_feat)),
                                      jnp.float32)
    return g


@pytest.mark.slow
def test_mace_smoke_energy_and_grads():
    _, cfg = get_arch("mace", smoke=True)
    m = MACE(cfg)
    p = m.init(jax.random.PRNGKey(0))
    g = _graph(n_species=cfg.num_species)
    loss, metrics = m.energy_loss(p, g)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda pp: m.energy_loss(pp, g)[0])(p)
    assert all(np.all(np.isfinite(np.asarray(x)))
               for x in jax.tree.leaves(grads))


def test_mace_translation_invariance():
    _, cfg = get_arch("mace", smoke=True)
    m = MACE(cfg)
    p = m.init(jax.random.PRNGKey(0))
    g = _graph(n_species=cfg.num_species)
    e1 = m.apply(p, g)["energy"]
    g2 = dict(g, positions=g["positions"] + jnp.asarray([[5.0, -3.0, 1.0]]))
    e2 = m.apply(p, g2)["energy"]
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4)


def test_mace_rotation_invariance():
    """E(3) equivariance: global rotation leaves the energy unchanged."""
    _, cfg = get_arch("mace", smoke=True)
    m = MACE(cfg)
    p = m.init(jax.random.PRNGKey(0))
    g = _graph(n_species=cfg.num_species)
    # rotation about z then x
    a, b = 0.7, -1.2
    rz = np.array([[np.cos(a), -np.sin(a), 0], [np.sin(a), np.cos(a), 0],
                   [0, 0, 1]])
    rx = np.array([[1, 0, 0], [0, np.cos(b), -np.sin(b)],
                   [0, np.sin(b), np.cos(b)]])
    r = jnp.asarray(rz @ rx, jnp.float32)
    e1 = m.apply(p, g)["energy"]
    g2 = dict(g, positions=g["positions"] @ r.T)
    e2 = m.apply(p, g2)["energy"]
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-3,
                               atol=1e-4)


@pytest.mark.slow
def test_mace_permutation_equivariance():
    _, cfg = get_arch("mace", smoke=True)
    m = MACE(cfg)
    p = m.init(jax.random.PRNGKey(0))
    g = _graph(n=10, e=20, n_species=cfg.num_species)
    perm = np.random.default_rng(3).permutation(10)
    inv = np.argsort(perm)
    g2 = {
        "positions": g["positions"][perm],
        "species": g["species"][perm],
        "edge_index": jnp.asarray(inv)[g["edge_index"]],
        "graph_id": g["graph_id"],
        "n_graphs": 1,
        "energy": g["energy"],
    }
    out1 = m.apply(p, g)["node_out"]
    out2 = m.apply(p, g2)["node_out"]
    np.testing.assert_allclose(np.asarray(out1)[perm], np.asarray(out2),
                               rtol=1e-3, atol=1e-4)


def test_mace_node_classification_path():
    _, cfg = get_arch("mace", smoke=True)
    m = MACE(cfg)
    p = m.init(jax.random.PRNGKey(0), n_feat=8)
    g = _graph(n_species=cfg.num_species, d_feat=8)
    g["labels"] = jnp.zeros(12, jnp.int32)
    g["label_mask"] = jnp.ones(12, jnp.float32)
    loss, metrics = m.node_class_loss(p, g)
    assert np.isfinite(float(loss)) and 0 <= float(metrics["acc"]) <= 1


def test_bessel_basis_cutoff():
    r = jnp.asarray([0.5, 2.0, 4.99, 5.0, 6.0])
    rb = bessel_basis(r, 4, 5.0)
    assert rb.shape == (5, 4)
    assert np.abs(np.asarray(rb[3:])).max() < 1e-6     # zero beyond cutoff


def test_spherical_harmonics_orthogonality():
    """Real SH up to l_max=2: rows orthogonal under uniform sphere
    sampling (Monte-Carlo, loose tolerance)."""
    rng = np.random.default_rng(0)
    v = rng.normal(size=(200_000, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    y = np.asarray(so3.spherical_harmonics(2, jnp.asarray(v, jnp.float32)))
    gram = (y.T @ y) / len(v)
    diag = np.diag(gram).copy()
    assert (diag > 1e-3).all()                         # non-degenerate
    off = gram - np.diag(diag)
    assert np.abs(off).max() < 2e-2


def test_neighbor_sampler_shapes():
    from repro.data.graph import CSRGraph, NeighborSampler, random_graph
    g = random_graph(500, 4000, d_feat=16, seed=0)
    csr = CSRGraph.from_edge_index(np.asarray(g["edge_index"]), 500)
    sampler = NeighborSampler(csr, fanout=(5, 3), seed=0)
    sub = sampler.sample(np.arange(32))
    assert sub["edge_index"].shape[0] == 2
    n_local = len(sub["node_ids"])
    assert sub["edge_index"].max() < n_local
    assert sub["n_seeds"] == 32
    # seeds occupy local ids [0, 32) and map back to themselves
    np.testing.assert_array_equal(sub["node_ids"][:32], np.arange(32))
    # expected edge count: seeds*f0 + seeds*f0*f1
    assert sub["edge_index"].shape[1] == 32 * 5 + 32 * 5 * 3
