"""Hot-row decode-ahead cache in the ServingEngine (DESIGN.md §9).

The scheme-level hook (export attaches the `hot` leaf, spec/placement/
size all derived) is covered registry-wide in test_schemes.py; this
file covers the ENGINE: hot/cold flush splitting, bit-parity of cached
lookups against the uncached fused decode, EngineStats accounting
across mixed / fully-cached / single-request flushes, and the
adaptive refresh loop.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import Embedding, EmbeddingConfig
from repro.core.schemes import registered_kinds, scheme_class
from repro.launch.engine import ServingEngine, drive_zipf_stream

# sanitizer lane: flush legs run under jax.transfer_guard('disallow')
pytestmark = pytest.mark.hot_path


def _dpq_cfg(**kw):
    return EmbeddingConfig(vocab_size=500, dim=16, kind="dpq",
                           num_subspaces=4, num_centroids=8,
                           decode_block_b=32, **kw)


def _engine_pair(cfg, hot_rows, **hot_kw):
    """(cached engine, uncached engine) over one exported artifact."""
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    return (ServingEngine(emb, art, hot_rows=hot_rows, **hot_kw),
            ServingEngine(emb, art, hot_rows=0), emb, art)


# -------------------------------------------------------------- parity

def _registry_params():
    return [pytest.param(kind, var,
                         id=kind if var == "-" else f"{kind}-{var}")
            for kind in registered_kinds()
            for var in scheme_class(kind).variants()]


@pytest.mark.parametrize("kind,var", _registry_params())
def test_cached_lookups_bit_identical_every_scheme(kind, var):
    """Cached rows must be BIT-identical to the uncached fused decode
    for every registered scheme — mixed hot/cold probe batch."""
    cfg = dataclasses.replace(scheme_class(kind).probe_config(var),
                              hot_rows=8)
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    hot_eng = ServingEngine(emb, art)              # hot_rows from cfg
    cold_eng = ServingEngine(emb, art, hot_rows=0)
    ids = np.asarray([0, 7, 3, 8, cfg.vocab_size - 1, 0, 20 %
                      cfg.vocab_size])
    out = hot_eng.lookup(ids)
    ref = cold_eng.lookup(ids)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert hot_eng.stats().hot_hits > 0


def test_cached_lookup_bit_identical_with_backend_override():
    """A backend override rebuilds the config — the engine must then
    re-decode the hot block through its OWN serve path so parity holds
    on that backend too."""
    cfg = _dpq_cfg(hot_rows=64)
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    eng = ServingEngine(emb, art, backend="interpret")
    base = ServingEngine(emb, art, backend="interpret", hot_rows=0)
    ids = np.asarray([0, 63, 64, 499, 5])
    np.testing.assert_array_equal(np.asarray(eng.lookup(ids)),
                                  np.asarray(base.lookup(ids)))


# ---------------------------------------------------------- EngineStats

def test_stats_mixed_hot_cold_flush():
    eng, base, emb, art = _engine_pair(_dpq_cfg(), hot_rows=100)
    ids = np.asarray([0, 5, 99, 100, 499, 3, 200])      # 4 hot, 3 cold
    eng.lookup(ids)
    st = eng.stats()
    assert st.lookups == 7 and st.requests == 1 and st.flushes == 1
    assert st.hot_hits == 4
    assert st.hit_rate == pytest.approx(4 / 7)
    # flush padded to block_b; only the cold remainder hit the decode
    assert st.padded_lookups == 32
    assert st.decoded_lookups == 32      # 3 cold ids padded to block_b
    assert st.lookups_per_s >= 0.0


def test_stats_fully_cached_flush_zero_kernel_work():
    """A flush whose real ids are all cached must do ZERO fused-decode
    work, and the stats must stay consistent (hit_rate 1.0, finite
    throughput, padded_lookups still accounted)."""
    eng, base, emb, art = _engine_pair(_dpq_cfg(), hot_rows=100)
    ids = np.arange(40)                                 # all hot
    out = eng.lookup(ids)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(base.lookup(ids)))
    st = eng.stats()
    assert st.decoded_lookups == 0
    assert st.hot_hits == 40 and st.lookups == 40
    assert st.hit_rate == 1.0
    assert st.padded_lookups == 64      # ceil(40 / 32) * 32
    assert st.seconds > 0 and np.isfinite(st.lookups_per_s)
    d = st.as_dict()
    assert d["hit_rate"] == 1.0 and d["decoded_lookups"] == 0


def test_stats_single_request_no_concatenate_path():
    """n_req == 1 skips the concatenate in flush(); the hot split and
    stats must behave identically on that path."""
    eng, base, emb, art = _engine_pair(_dpq_cfg(), hot_rows=100)
    h = eng.submit(np.asarray([1, 2, 450]))
    outs = eng.flush()                                  # single request
    np.testing.assert_array_equal(
        np.asarray(outs[h]), np.asarray(base.lookup([1, 2, 450])))
    st = eng.stats()
    assert st.requests == 1 and st.lookups == 3
    assert st.hot_hits == 2 and st.hit_rate == pytest.approx(2 / 3)
    assert st.decoded_lookups == 32


def test_stats_accumulate_across_mixed_flushes():
    eng, base, emb, art = _engine_pair(_dpq_cfg(), hot_rows=100)
    eng.lookup(np.arange(10))            # fully cached
    eng.lookup(np.asarray([400, 450]))   # fully cold
    eng.lookup(np.asarray([0, 400]))     # mixed
    st = eng.stats()
    assert st.flushes == 3 and st.lookups == 14
    assert st.hot_hits == 10 + 0 + 1
    assert st.decoded_lookups == 0 + 32 + 32
    assert st.hit_rate == pytest.approx(11 / 14)


# ------------------------------------------------------------- refresh

def test_refresh_hot_rows_tracks_observed_traffic():
    """With frequency tracking on, refresh re-points the cache at the
    observed-hottest ids — and parity still holds afterwards."""
    eng, base, emb, art = _engine_pair(_dpq_cfg(), hot_rows=16,
                                       hot_track_freq=True)
    hot_segment = np.arange(300, 316)    # tail ids, hammered
    for _ in range(3):
        eng.lookup(np.concatenate([hot_segment, hot_segment]))
    new_ids = eng.refresh_hot_rows()
    np.testing.assert_array_equal(new_ids, hot_segment)
    assert eng.stats().hot_refreshes == 1
    # the refreshed cache now serves that segment without decoding
    before = eng.stats().decoded_lookups
    out = eng.lookup(hot_segment)
    assert eng.stats().decoded_lookups == before
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(base.lookup(hot_segment)))


def test_refresh_with_explicit_ids_keeps_parity():
    eng, base, emb, art = _engine_pair(_dpq_cfg(), hot_rows=32)
    eng.refresh_hot_rows(np.arange(200, 232))
    ids = np.asarray([0, 201, 231, 499])
    np.testing.assert_array_equal(np.asarray(eng.lookup(ids)),
                                  np.asarray(base.lookup(ids)))
    assert eng.stats().hot_hits == 2     # 201, 231


def test_refresh_before_traffic_keeps_head_set():
    eng, *_ = _engine_pair(_dpq_cfg(), hot_rows=16, hot_track_freq=True)
    np.testing.assert_array_equal(eng.refresh_hot_rows(), np.arange(16))


def test_refresh_disabled_raises():
    eng, *_ = _engine_pair(_dpq_cfg(), hot_rows=0)
    with pytest.raises(ValueError, match="hot"):
        eng.refresh_hot_rows()


def test_auto_refresh_every_n_flushes():
    eng, *_ = _engine_pair(_dpq_cfg(), hot_rows=16, hot_refresh_every=2)
    for i in range(4):
        eng.lookup(np.asarray([300, 301, 302]))
    assert eng.stats().hot_refreshes == 2
    # EMA counters ranked the hammered tail ids into the cache
    assert set([300, 301, 302]) <= set(eng._hot_ids.tolist())


def test_engine_hot_rows_cap():
    cfg = _dpq_cfg()
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="hot_rows"):
        ServingEngine(emb, art, hot_rows=cfg.vocab_size + 1)


# ---------------------------------------------------------- zipf driver

def test_drive_zipf_stream_hits_head():
    cfg = _dpq_cfg(hot_rows=64)
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    eng = ServingEngine(emb, art, max_queue=256)
    st = drive_zipf_stream(eng, cfg.vocab_size, n_requests=30,
                           req_batch=16, zipf_a=1.2, seed=5)
    assert st.lookups > 0 and st.flushes >= 1
    # power-law traffic against the head cache: most lookups hit
    assert st.hit_rate > 0.4
    assert st.decoded_lookups < st.padded_lookups


def test_exported_hot_block_is_used_when_config_matches():
    """No backend/mesh override: the engine must reuse the artifact's
    export-time pre-decoded block verbatim (the deployment story)."""
    cfg = _dpq_cfg(hot_rows=64)
    emb = Embedding(cfg)
    art = emb.export(emb.init(jax.random.PRNGKey(0)))
    eng = ServingEngine(emb, art)
    np.testing.assert_array_equal(np.asarray(eng._hot_block),
                                  np.asarray(art["hot"]))
