"""Shared experiment harness for the paper-reproduction benchmarks.

Trains a backbone (GMF / NeuMF / SASRec) with a chosen embedding scheme
on the ML-1M-like synthetic set (personalized + sequential tasks) or an
AAR-like relevance set (item-to-item task), and evaluates HR@10 / RMSE
exactly as the paper does (§3.5): for HR@10, rank the withheld test
item against 100 sampled negatives per user.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.sampler import PointwiseSampler, SequenceSampler
from repro.data.synthetic import InteractionData
from repro.models.recsys.backbones import (GMF, BackboneConfig, SASRec,
                                           make_backbone)
from repro.train import optimizer as opt_lib
from repro.train.optimizer import TrainState


@dataclasses.dataclass
class RunResult:
    scheme: str
    metric: float            # HR@10 (higher better) or RMSE (lower better)
    size_bits: int
    size_pct: float          # % of full-embedding size
    losses: List[float]
    seconds: float


# ----------------------------------------------------------------------
# evaluation (paper §3.5: HR@10 vs 100 sampled negatives)
# ----------------------------------------------------------------------

def hr_at_10_pointwise(model, params, data: InteractionData,
                       n_users_eval: int = 500, n_neg: int = 100,
                       seed: int = 7) -> float:
    rng = np.random.default_rng(seed)
    users = rng.choice(data.n_users, min(n_users_eval, data.n_users),
                       replace=False)
    cand = np.concatenate(
        [data.test_item[users][:, None],
         rng.integers(0, data.n_items, (len(users), n_neg))], axis=1)
    u_rep = np.repeat(users, n_neg + 1)
    scores, _ = jax.jit(model.score)(params, jnp.asarray(u_rep),
                                     jnp.asarray(cand.reshape(-1)))
    scores = np.asarray(scores).reshape(len(users), n_neg + 1)
    rank = (scores[:, 1:] >= scores[:, :1]).sum(axis=1)
    return float((rank < 10).mean())


def hr_at_10_sasrec(model: SASRec, params, data: InteractionData,
                    maxlen: int, n_users_eval: int = 500,
                    n_neg: int = 100, seed: int = 7) -> float:
    rng = np.random.default_rng(seed)
    users = rng.choice(data.n_users, min(n_users_eval, data.n_users),
                       replace=False)
    seqs = np.zeros((len(users), maxlen), np.int64)
    for i, u in enumerate(users):
        s = data.train_seqs[u][-maxlen:] + 1          # shift: 0 = pad
        seqs[i, maxlen - len(s):] = s
    hidden, _ = jax.jit(model.trunk)(params, jnp.asarray(seqs))
    last = np.asarray(hidden[:, -1])                  # (U, d)
    cand = np.concatenate(
        [data.test_item[users][:, None] + 1,
         rng.integers(1, data.n_items + 1, (len(users), n_neg))], axis=1)
    e, _ = model.item_emb.apply(params["item_emb"],
                                jnp.asarray(cand.reshape(-1)))
    e = np.asarray(e).reshape(len(users), n_neg + 1, -1)
    scores = np.einsum("ud,ukd->uk", last, e)
    rank = (scores[:, 1:] >= scores[:, :1]).sum(axis=1)
    return float((rank < 10).mean())


# ----------------------------------------------------------------------
# training drivers
# ----------------------------------------------------------------------

def _fit(model, params, loss_fn, data_iter, steps: int, lr: float,
         log_every: int = 0) -> Tuple[TrainState, List[float]]:
    ocfg = opt_lib.OptimizerConfig(kind="adam", lr=lr, grad_clip=None)
    state = TrainState.create(ocfg, params)
    step = jax.jit(opt_lib.make_step_fn(ocfg, loss_fn))
    losses = []
    for i in range(steps):
        batch = next(data_iter)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step(state, batch)
        if log_every and (i % log_every == 0 or i == steps - 1):
            losses.append(float(metrics["bce" if "bce" in metrics
                                        else "loss"]))
    return state, losses


def run_pointwise(model_name: str, scheme_cfg: BackboneConfig,
                  data: InteractionData, steps: int = 400,
                  lr: float = 2e-3, eval_users: int = 500) -> RunResult:
    """Task 1 (personalized): GMF / NeuMF on ML-like implicit feedback."""
    t0 = time.time()
    model = make_backbone(scheme_cfg)
    params = model.init(jax.random.PRNGKey(0))
    sampler = iter(PointwiseSampler(data, batch_pos=512, n_neg=4))
    state, losses = _fit(model, params, model.loss, sampler, steps, lr,
                         log_every=max(steps // 40, 1))
    hr = hr_at_10_pointwise(model, state.params, data,
                            n_users_eval=eval_users)
    full_bits = 32 * scheme_cfg.dim * (
        scheme_cfg.n_users + scheme_cfg.n_items) * (
        2 if model_name == "neumf" else 1)
    bits = make_backbone(scheme_cfg).serving_size_bits()
    return RunResult(scheme_cfg.embed_kind, hr, bits,
                     100.0 * bits / full_bits, losses, time.time() - t0)


def run_sasrec(scheme_cfg: BackboneConfig, data: InteractionData,
               steps: int = 400, lr: float = 1e-3,
               eval_users: int = 500) -> RunResult:
    """Task 2 (sequential): SASRec next-item prediction."""
    t0 = time.time()
    model = SASRec(scheme_cfg)
    params = model.init(jax.random.PRNGKey(0))
    sampler = iter(SequenceSampler(data, batch=128,
                                   maxlen=scheme_cfg.maxlen))
    state, losses = _fit(model, params, model.loss, sampler, steps, lr,
                         log_every=max(steps // 40, 1))
    hr = hr_at_10_sasrec(model, state.params, data, scheme_cfg.maxlen,
                         n_users_eval=eval_users)
    full_bits = 32 * scheme_cfg.dim * (scheme_cfg.n_items + 1)
    bits = model.serving_size_bits()
    return RunResult(scheme_cfg.embed_kind, hr, bits,
                     100.0 * bits / full_bits, losses, time.time() - t0)


def run_item2item(scheme_cfg: BackboneConfig, aar: Dict,
                  steps: int = 400, lr: float = 2e-3) -> RunResult:
    """Task 3 (item-to-item): GMF-style regressor on relevance scores.
    Reports RMSE (lower better), scores normalized to [-1, 1]."""
    t0 = time.time()
    model = GMF(scheme_cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n = len(aar["train_a"])

    def data_iter():
        while True:
            idx = rng.integers(0, n, 1024)
            yield {"user_ids": aar["train_a"][idx],
                   "item_ids": aar["train_b"][idx],
                   "label": aar["train_y"][idx] / 100.0}

    state, losses = _fit(model, params, model.mse_loss, data_iter(),
                         steps, lr, log_every=max(steps // 40, 1))
    pred, _ = jax.jit(model.score)(state.params, jnp.asarray(aar["eval_a"]),
                                   jnp.asarray(aar["eval_b"]))
    rmse = float(np.sqrt(np.mean(
        (np.asarray(pred) - aar["eval_y"] / 100.0) ** 2))) * 100.0
    full_bits = 32 * scheme_cfg.dim * (scheme_cfg.n_users
                                       + scheme_cfg.n_items)
    bits = model.serving_size_bits()
    return RunResult(scheme_cfg.embed_kind, rmse, bits,
                     100.0 * bits / full_bits, losses, time.time() - t0)


# ----------------------------------------------------------------------
# scheme sweeps (paper Fig. 2 x-axis: model size)
# ----------------------------------------------------------------------

def scheme_grid(n_users: int, n_items: int, model: str = "gmf",
                dim: int = 64) -> Dict[str, List[BackboneConfig]]:
    """Configs per scheme, swept the way the paper sweeps sizes:
    FE -> dimension, SQ -> bits, LRF -> rank, DPQ/MGQE -> subspaces D."""
    base = dict(model=model, n_users=n_users, n_items=n_items, dim=dim)
    grid = {
        "full": [BackboneConfig(embed_kind="full", **dict(base, dim=d))
                 for d in (64, 16, 8, 4)],
        "sq": [BackboneConfig(embed_kind="sq", sq_bits=b, **base)
               for b in (8, 4)],
        "lrf": [BackboneConfig(embed_kind="lrf", lrf_rank=r, **base)
                for r in (16, 8, 4)],
        "dpq": [BackboneConfig(embed_kind="dpq", num_subspaces=D, **base)
                for D in (16, 8, 4)],
        "mgqe": [BackboneConfig(embed_kind="mgqe", num_subspaces=D, **base)
                 for D in (16, 8, 4)],
    }
    return grid
