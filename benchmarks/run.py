"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Runs every paper-table/figure benchmark in quick mode:
  1. §3.5 serving-size table          (analytic)
  2. Fig. 2 quality-vs-size curves    (trains small backbones)
  3. Fig. 3 convergence MGQE vs FE    (trains small backbones)
  4. kernel micro-bench               (CPU reference paths)
Pass --full for the paper-scale protocol (hours on CPU).
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip-training", action="store_true",
                    help="only the analytic + kernel benches")
    a = ap.parse_args(argv)
    t0 = time.time()
    os.makedirs("results", exist_ok=True)

    from benchmarks import size_table
    size_table.main()
    print()

    from benchmarks import kernel_bench
    kernel_bench.main()
    print()

    if not a.skip_training:
        from benchmarks import compression_curves
        compression_curves.main(quick=not a.full,
                                out_json="results/fig2.json")
        print()

        from benchmarks import convergence
        convergence.main(quick=not a.full, out_json="results/fig3.json")

    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
