"""Paper Fig. 3: training-loss trajectories of MGQE vs full embeddings
on the backbone models — MGQE must track FE closely (same default
hyper-parameters, no retuning)."""
from __future__ import annotations

import argparse
import json


from benchmarks.common import run_pointwise, run_sasrec
from repro.data.synthetic import movielens_like
from repro.models.recsys.backbones import BackboneConfig


def main(quick: bool = True, out_json: str = ""):
    n_users, n_items = (1200, 800) if quick else (6040, 3416)
    steps = 200 if quick else 2000
    ml = movielens_like(n_users=n_users, n_items=n_items, seed=0)
    print("== Fig.3 reproduction: convergence MGQE vs FE ==")
    curves = {}
    for model in ("gmf", "neumf", "sasrec"):
        for kind in ("full", "mgqe"):
            cfg = BackboneConfig(model=model, n_users=n_users,
                                 n_items=n_items, dim=64, embed_kind=kind)
            if model == "sasrec":
                r = run_sasrec(cfg, ml, steps=steps, eval_users=100)
            else:
                r = run_pointwise(model, cfg, ml, steps=steps,
                                  eval_users=100)
            curves[f"{model}/{kind}"] = r.losses
            print(f"  {model:6s}/{kind:4s}: loss "
                  f"{r.losses[0]:.3f} -> {r.losses[-1]:.3f} "
                  f"({r.seconds:.0f}s)")
    # the Fig.3 claim: final losses within a small gap
    for model in ("gmf", "neumf", "sasrec"):
        fe = curves[f"{model}/full"][-1]
        mg = curves[f"{model}/mgqe"][-1]
        gap = abs(mg - fe) / max(abs(fe), 1e-9)
        verdict = "TRACKS" if gap < 0.25 else "DIVERGES"
        print(f"  {model}: final FE={fe:.3f} MGQE={mg:.3f} "
              f"rel-gap={gap:.1%} -> {verdict}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(curves, f, indent=1)
    return curves


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", default="")
    a = ap.parse_args()
    main(quick=not a.full, out_json=a.json)
