"""Render the dry-run JSON rows into the §Roofline markdown table."""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List


def fmt_row(r: Dict) -> str:
    uf = r.get("useful_frac")
    rf = r.get("roofline_frac")
    return ("| {arch} | {shape} | {mesh} | {c:.2f} | {m:.2f} | {k:.2f} | "
            "{dom} | {uf} | {rf} | {peak:.1f} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        c=r["compute_ms"], m=r["memory_ms"], k=r["collective_ms"],
        dom=r["dominant"],
        uf="-" if uf is None else f"{uf:.3f}",
        rf="-" if rf is None else f"{rf:.3f}",
        peak=r["peak_gb"])


HEADER = ("| arch | shape | mesh | compute ms | memory ms | collective ms "
          "| bound | useful | roofline | peak GB/dev |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def render(paths: List[str]) -> str:
    rows = []
    for p in paths:
        if os.path.exists(p):
            with open(p) as f:
                rows.extend(json.load(f))
    lines = [HEADER] + [fmt_row(r) for r in rows]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="*",
                    default=["results/dryrun_single.json",
                             "results/dryrun_multi.json"])
    a = ap.parse_args()
    print(render(a.paths))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
